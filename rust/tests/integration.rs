//! Integration tests: the full three-layer stack (artifacts → PJRT →
//! coordinator) on the tiny preset. Require `make artifacts` to have run.

use std::sync::Arc;

use celu_vfl::config::{Algorithm, RunConfig, WanProfile};
use celu_vfl::coordinator::{run_party_a, run_party_b};
use celu_vfl::coordinator::run_training;
use celu_vfl::coordinator::trainer::{load_data, load_set};
use celu_vfl::data::batcher::{gather_a, gather_b};
use celu_vfl::runtime::{PartyARuntime, PartyBRuntime};
use celu_vfl::transport::tcp::TcpTransport;
use celu_vfl::transport::Transport;

fn tiny_cfg() -> RunConfig {
    let mut cfg = RunConfig::quick();
    cfg.size = "tiny".into();
    cfg.train_instances = 20_000;
    cfg.test_instances = 4_000;
    cfg.max_rounds = 200;
    cfg.eval_every = 25;
    cfg
}

/// True when the full stack is actually runnable: compiled artifact
/// sets on disk AND a `--features pjrt` build (the default build's
/// stub backend errors at artifact load). When either is missing the
/// tests below SKIP (with a note) rather than fail, so `cargo test`
/// stays meaningful on dependency-free checkouts and in CI.
fn full_stack_available() -> bool {
    cfg!(feature = "pjrt")
        && std::path::Path::new("artifacts/wdl_criteo_tiny/manifest.json")
            .exists()
}

macro_rules! require_artifacts {
    () => {
        if !full_stack_available() {
            eprintln!(
                "skipping artifact-gated test (run `make artifacts` and \
                 build with --features pjrt to enable)"
            );
            return;
        }
    };
}

// -- runtime numerics -------------------------------------------------------

#[test]
fn initial_loss_is_ln2() {
    // Near-zero initial logits (small-scale init) ⇒ BCE ≈ ln 2.
    require_artifacts!();
    let cfg = tiny_cfg();
    let set = load_set(&cfg).unwrap();
    let data = load_data(&cfg, &set).unwrap();
    let a = PartyARuntime::new(set.clone(), 7, 0.05, 0.5, true).unwrap();
    let mut b = PartyBRuntime::new(set.clone(), 7, 0.05, 0.5, true).unwrap();
    let idx: Vec<u32> = (0..set.manifest.batch as u32).collect();
    let xa = gather_a(&data.train_a, &idx);
    let (xb, y) = gather_b(&data.train_b, &idx);
    let za = a.forward(&xa).unwrap();
    let (_dza, loss) = b.exact_step(&xb, &y, &za).unwrap();
    assert!((loss - 0.6931472).abs() < 5e-3, "initial loss {loss}");
}

#[test]
fn a_local_with_fresh_stats_equals_a_upd() {
    // Two identical Party-A runtimes; one takes the exact update, the
    // other the local update with stale==fresh statistics and ξ=180°.
    // The resulting parameters must match bit-for-bit through PJRT.
    require_artifacts!();
    let cfg = tiny_cfg();
    let set = load_set(&cfg).unwrap();
    let data = load_data(&cfg, &set).unwrap();
    let mut a1 = PartyARuntime::new(set.clone(), 9, 0.05, -1.0, true)
        .unwrap();
    let mut a2 = PartyARuntime::new(set.clone(), 9, 0.05, -1.0, true)
        .unwrap();
    let mut b = PartyBRuntime::new(set.clone(), 9, 0.05, -1.0, true)
        .unwrap();
    let idx: Vec<u32> = (0..set.manifest.batch as u32).collect();
    let xa = gather_a(&data.train_a, &idx);
    let (xb, y) = gather_b(&data.train_b, &idx);
    let za = a1.forward(&xa).unwrap();
    let (dza, _) = b.exact_step(&xb, &y, &za).unwrap();

    a1.exact_update(&xa, &dza).unwrap();
    let ws = a2.local_update(&xa, &za, &dza).unwrap();
    // All cosines are exactly 1 (identical stale/fresh activations).
    assert!((ws[6] - 1.0).abs() < 1e-5, "mean cos {ws:?}");
    for (p1, p2) in a1.state.params.iter().zip(a2.state.params.iter()) {
        let v1 = p1.to_vec::<f32>().unwrap();
        let v2 = p2.to_vec::<f32>().unwrap();
        for (x1, x2) in v1.iter().zip(v2.iter()) {
            assert!((x1 - x2).abs() <= 1e-6, "param divergence {x1} {x2}");
        }
    }
}

#[test]
fn eval_outputs_are_probabilities() {
    require_artifacts!();
    let cfg = tiny_cfg();
    let set = load_set(&cfg).unwrap();
    let data = load_data(&cfg, &set).unwrap();
    let a = PartyARuntime::new(set.clone(), 3, 0.05, 0.5, true).unwrap();
    let b = PartyBRuntime::new(set.clone(), 3, 0.05, 0.5, true).unwrap();
    let idx: Vec<u32> = (0..set.manifest.batch as u32).collect();
    let xa = gather_a(&data.test_a, &idx);
    let (xb, _y) = gather_b(&data.test_b, &idx);
    let za = a.forward(&xa).unwrap();
    let yhat = b.eval(&xb, &za).unwrap();
    assert_eq!(yhat.len(), set.manifest.batch);
    assert!(yhat.iter().all(|p| (0.0..=1.0).contains(p)));
}

// -- full training ----------------------------------------------------------

#[test]
fn vanilla_training_learns() {
    require_artifacts!();
    let mut cfg = tiny_cfg();
    cfg.algorithm = Algorithm::Vanilla;
    cfg.max_rounds = 400;
    let rec = run_training(&cfg).unwrap().record;
    assert_eq!(rec.comm_rounds, 400);
    assert!(rec.best_auc() > 0.65, "vanilla AUC {}", rec.best_auc());
    assert_eq!(rec.local_updates, 0);
    // Loss decreased from ln 2.
    let last = rec.series.last().unwrap();
    assert!(last.loss < 0.68, "loss {}", last.loss);
}

#[test]
fn vanilla_is_deterministic() {
    require_artifacts!();
    let mut cfg = tiny_cfg();
    cfg.algorithm = Algorithm::Vanilla;
    cfg.max_rounds = 100;
    let r1 = run_training(&cfg).unwrap().record;
    let r2 = run_training(&cfg).unwrap().record;
    let a1: Vec<f64> = r1.series.iter().map(|p| p.auc).collect();
    let a2: Vec<f64> = r2.series.iter().map(|p| p.auc).collect();
    assert_eq!(a1, a2, "vanilla runs with one seed must be identical");
}

#[test]
fn celu_training_beats_vanilla_at_equal_rounds() {
    require_artifacts!();
    let mut v = tiny_cfg();
    v.algorithm = Algorithm::Vanilla;
    v.max_rounds = 300;
    let mut c = v.clone();
    c.algorithm = Algorithm::CeluVfl;
    c.r_local = 3;
    c.w_workset = 3;
    c.xi_degrees = 60.0;
    let rv = run_training(&v).unwrap().record;
    let rc = run_training(&c).unwrap().record;
    assert!(rc.local_updates > 100, "local updates {}", rc.local_updates);
    assert!(
        rc.best_auc() > rv.best_auc() - 0.005,
        "celu {:.4} should be ≥ vanilla {:.4} at equal rounds",
        rc.best_auc(),
        rv.best_auc()
    );
    // Identical communication volume at equal rounds.
    assert_eq!(rc.comm_rounds, rv.comm_rounds);
}

#[test]
fn fedbcd_local_updates_bounded_by_r() {
    require_artifacts!();
    let mut cfg = tiny_cfg();
    cfg.algorithm = Algorithm::FedBcd;
    cfg.r_local = 4;
    cfg.max_rounds = 100;
    let rec = run_training(&cfg).unwrap().record;
    assert!(rec.local_updates <= 4 * rec.comm_rounds,
            "{} > 4×{}", rec.local_updates, rec.comm_rounds);
    assert!(rec.local_updates > 0);
}

#[test]
fn celu_cosine_telemetry_recorded() {
    require_artifacts!();
    let mut cfg = tiny_cfg();
    cfg.algorithm = Algorithm::CeluVfl;
    cfg.r_local = 3;
    cfg.w_workset = 3;
    cfg.max_rounds = 100;
    let rec = run_training(&cfg).unwrap().record;
    assert!(!rec.cosine.rows.is_empty(), "party A telemetry missing");
    assert!(!rec.cosine_b.rows.is_empty(), "party B telemetry missing");
    let summary = rec.cosine.summary().unwrap();
    // Quantiles are ordered and most similarities should be high (paper
    // Fig 5d: >90% of cosines above 0.5).
    assert!(summary.windows(2).take(5).all(|w| w[0] <= w[1] + 1e-9));
    assert!(summary[3] > 0.5, "median cosine {summary:?}");
}

#[test]
fn target_auc_stops_early() {
    require_artifacts!();
    let mut cfg = tiny_cfg();
    cfg.algorithm = Algorithm::CeluVfl;
    cfg.max_rounds = 2_000;
    cfg.target_auc = 0.60;
    let out = run_training(&cfg).unwrap();
    assert_eq!(out.stop_reason,
               celu_vfl::coordinator::label_party::StopReason::TargetAuc);
    assert!(out.record.comm_rounds < 2_000);
}

#[test]
fn wan_sim_accounts_bytes_and_busy_time() {
    require_artifacts!();
    let mut cfg = tiny_cfg();
    cfg.algorithm = Algorithm::Vanilla;
    cfg.max_rounds = 50;
    cfg.eval_every = 100; // no eval traffic in 50 rounds
    cfg.wan = WanProfile { bandwidth_mbps: 50.0, rtt_ms: 4.0,
                           gateway_ms: 0.0 };
    let rec = run_training(&cfg).unwrap().record;
    let msg = (64 * 16 * 4) as u64; // B×z×4 bytes payload
    assert!(rec.bytes_to_label() >= 50 * msg);
    assert!(rec.bytes_from_label() >= 50 * msg);
    // Two-party runs report exactly one link per direction.
    assert_eq!(rec.links.len(), 2);
    assert!(rec.comm_busy.as_secs_f64() > 0.1, "busy {:?}", rec.comm_busy);
    assert!(rec.comm_fraction() > 0.3, "comm fraction {}",
            rec.comm_fraction());
}

// -- TCP deployment ---------------------------------------------------------

#[test]
fn tcp_run_matches_inproc_vanilla() {
    require_artifacts!();
    let mut cfg = tiny_cfg();
    cfg.algorithm = Algorithm::Vanilla;
    cfg.max_rounds = 75;
    let inproc = run_training(&cfg).unwrap().record;

    let set = load_set(&cfg).unwrap();
    let data = load_data(&cfg, &set).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);

    let cfg_a = cfg.clone();
    let set_a = set.clone();
    let train_a = Arc::new(data.train_a.clone());
    let test_a = Arc::new(data.test_a.clone());
    let addr_a = addr.clone();
    let a = std::thread::spawn(move || {
        // Party B binds first; connect() retries until it is up.
        let t: Arc<dyn Transport> = Arc::new(
            TcpTransport::connect(&addr_a, WanProfile::instant()).unwrap());
        run_party_a(&cfg_a, set_a, train_a, test_a, t).unwrap()
    });
    let t: Arc<dyn Transport> = Arc::new(
        TcpTransport::listen(&addr, WanProfile::instant()).unwrap());
    let report = run_party_b(&cfg, set, Arc::new(data.train_b.clone()),
                             Arc::new(data.test_b.clone()), t).unwrap();
    let a_report = a.join().unwrap();

    assert_eq!(report.comm_rounds, 75);
    assert_eq!(a_report.comm_rounds, 75);
    let tcp_aucs: Vec<f64> = report.series.iter().map(|p| p.auc).collect();
    let in_aucs: Vec<f64> = inproc.series.iter().map(|p| p.auc).collect();
    assert_eq!(tcp_aucs, in_aucs,
               "TCP and in-proc vanilla runs must agree exactly");
}

#[test]
fn tcp_bootstrap_session_matches_inproc_vanilla() {
    // The listener-based bootstrap end-to-end: a two-party session
    // assembled through SessionListener/SessionDialer (Join handshake
    // on the raw socket, v1 training frames) must reproduce the
    // in-proc AUC series exactly — the full-trainer analogue of the
    // artifact-free byte-parity smoke in examples/tcp_mesh_k3.rs.
    use celu_vfl::session::bootstrap::{SessionDialer, SessionListener};
    use celu_vfl::session::{PartyId, SessionBuilder};

    require_artifacts!();
    let mut cfg = tiny_cfg();
    cfg.algorithm = Algorithm::Vanilla;
    cfg.max_rounds = 75;
    let inproc = run_training(&cfg).unwrap().record;

    let set = load_set(&cfg).unwrap();
    let data = load_data(&cfg, &set).unwrap();
    let listener = SessionListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let cfg_a = cfg.clone();
    let set_a = set.clone();
    let train_a = Arc::new(data.train_a.clone());
    let test_a = Arc::new(data.test_a.clone());
    let a = std::thread::spawn(move || {
        let session = SessionBuilder::from_bootstrap(
            &cfg_a,
            SessionDialer::new(&addr, PartyId(1)),
        )
        .unwrap();
        session.run_feature(set_a, train_a, test_a).unwrap()
    });
    let session = SessionBuilder::from_bootstrap(&cfg, listener).unwrap();
    let report = session
        .run_label(set, Arc::new(data.train_b.clone()),
                   Arc::new(data.test_b.clone()))
        .unwrap();
    let a_report = a.join().unwrap();

    assert_eq!(report.comm_rounds, 75);
    assert_eq!(a_report.comm_rounds, 75);
    let tcp_aucs: Vec<f64> = report.series.iter().map(|p| p.auc).collect();
    let in_aucs: Vec<f64> = inproc.series.iter().map(|p| p.auc).collect();
    assert_eq!(tcp_aucs, in_aucs,
               "bootstrap TCP and in-proc vanilla runs must agree");
}

#[test]
fn dssm_trains_through_pjrt() {
    // The DSSM model family end-to-end (the other Fig. 6 architecture).
    require_artifacts!();
    let mut cfg = tiny_cfg();
    cfg.model = "dssm".into();
    cfg.algorithm = Algorithm::CeluVfl;
    cfg.r_local = 3;
    cfg.w_workset = 3;
    cfg.max_rounds = 150;
    let rec = run_training(&cfg).unwrap().record;
    assert_eq!(rec.comm_rounds, 150);
    assert!(rec.local_updates > 50);
    // DSSM converges slower than WDL at tiny scale; just require learning
    // signal beyond chance.
    assert!(rec.best_auc() > 0.52, "dssm AUC {}", rec.best_auc());
}

#[test]
fn all_exported_artifact_sets_load_and_execute() {
    // Every set in artifacts/ must compile and run one forward pass —
    // catches ABI drift across models × datasets × sizes (the 'big' set
    // is skipped for time; its shapes equal 'small' modulo dims).
    require_artifacts!();
    for tag in ["wdl_criteo_tiny", "dssm_criteo_tiny", "wdl_avazu_small",
                "dssm_d3_small"] {
        let mut cfg = tiny_cfg();
        let parts: Vec<&str> = tag.split('_').collect();
        cfg.model = parts[0].into();
        cfg.dataset = parts[1].into();
        cfg.size = parts[2].into();
        let set = load_set(&cfg).unwrap();
        let data = load_data(&cfg, &set).unwrap();
        let a = PartyARuntime::new(set.clone(), 1, 0.05, 0.5, true)
            .unwrap();
        let idx: Vec<u32> = (0..set.manifest.batch as u32).collect();
        let xa = gather_a(&data.train_a, &idx);
        let za = a.forward(&xa).unwrap();
        assert_eq!(za.shape, vec![set.manifest.batch, set.manifest.z_dim],
                   "bad Z_A shape for {tag}");
        assert!(za.as_f32().unwrap().iter().all(|x| x.is_finite()),
                "non-finite Z_A for {tag}");
    }
}

// -- K-party sessions -------------------------------------------------------

#[test]
fn two_party_session_is_deterministic_with_per_link_records() {
    // `--parties 2` through the session API: deterministic end-to-end
    // (same AUC series and byte counts across reruns) with exactly one
    // per-link record per direction. The wire format itself is pinned
    // byte-for-byte by the protocol golden fixtures.
    require_artifacts!();
    let mut cfg = tiny_cfg();
    cfg.algorithm = Algorithm::Vanilla;
    cfg.max_rounds = 75;
    cfg.parties = 2;
    let r1 = run_training(&cfg).unwrap().record;
    let r2 = run_training(&cfg).unwrap().record;
    let a1: Vec<f64> = r1.series.iter().map(|p| p.auc).collect();
    let a2: Vec<f64> = r2.series.iter().map(|p| p.auc).collect();
    assert_eq!(a1, a2);
    assert_eq!(r1.wire_bytes_total(), r2.wire_bytes_total());
    assert_eq!(r1.links.len(), 2);
}

/// True when an artifact set compiled for the K-party feature slice is
/// on disk (the bottom-model input width must match the vertical
/// split — see `trainer::run_training`).
fn k3_artifacts_available(cfg: &RunConfig) -> bool {
    if !full_stack_available() {
        return false;
    }
    let set = match load_set(cfg) {
        Ok(s) => s,
        Err(_) => return false,
    };
    // criteo's 26 A-side fields split 13/13 across two feature parties.
    let slice = celu_vfl::data::dataset_fields(&cfg.dataset)
        .map(|(fa, _)| fa / 2)
        .unwrap_or(0);
    set.manifest.fields_a == slice
}

#[test]
fn k3_training_learns_with_local_updates_on_every_feature_party() {
    // The acceptance run: 2 feature parties + 1 label party, in-proc,
    // with local updates active everywhere. Requires artifacts whose
    // bottom model matches the 13-field slice; skips (like every
    // artifact-gated test) otherwise. The artifact-free session smoke
    // (`examples/mesh_k3.rs`) covers the protocol path in CI.
    let mut cfg = tiny_cfg();
    cfg.parties = 3;
    cfg.algorithm = Algorithm::CeluVfl;
    cfg.r_local = 3;
    cfg.w_workset = 3;
    cfg.max_rounds = 150;
    if !k3_artifacts_available(&cfg) {
        eprintln!(
            "skipping K=3 e2e (needs --features pjrt plus artifacts \
             compiled for the per-party feature slice)"
        );
        return;
    }
    let rec = run_training(&cfg).unwrap().record;
    assert_eq!(rec.comm_rounds, 150);
    assert!(rec.best_auc() > 0.55, "K=3 AUC {}", rec.best_auc());
    assert!(rec.local_updates > 50, "label local updates {}",
            rec.local_updates);
    // Local updates active on EVERY feature party.
    assert_eq!(rec.feature_local_updates.len(), 2);
    assert!(rec.feature_local_updates.iter().all(|&u| u > 0),
            "idle feature party: {:?}", rec.feature_local_updates);
    // Four directed links: 1→0, 2→0, 0→1, 0→2, all busy.
    assert_eq!(rec.links.len(), 4);
    assert!(rec.links.iter().all(|l| l.bytes > 0));
}

#[test]
fn fedbcd_equals_celu_with_consecutive_unweighted_config() {
    // FedBCD is definitionally CELU with W=1 + consecutive + no weights;
    // the config layer must map it that way.
    let mut f = tiny_cfg();
    f.algorithm = Algorithm::FedBcd;
    f.r_local = 5;
    f.w_workset = 99; // ignored for FedBCD
    assert_eq!(f.effective_w(), 1);
    assert_eq!(f.sampling(), celu_vfl::config::Sampling::Consecutive);
    assert!(!f.weighting_enabled());
    assert_eq!(f.effective_r(), 5);
}
