//! Chunked CSV reader: `key,label,f0,…,f{F-1}` → hashed token rows.
//!
//! Layout contract (documented in DESIGN.md §12): column 0 is the
//! opaque alignment key (the PSI join key), column 1 the binary label,
//! then exactly `fields` raw feature strings — Party A's columns first,
//! Party B's last, mirroring `SynthDataset`'s `(fa, fb)` split. Every
//! party reads the same file (or an identically-ordered vertical
//! export of it) and slices its own columns after hashing, so the
//! reader itself is party-agnostic.
//!
//! Raw values are hashed with [`feature_token`](super::feature_token) —
//! there is no vocabulary file; unseen strings land in the same id
//! space the embedding tables were compiled for. Hostile rows
//! (truncated lines, non-numeric labels, wrong arity) fail with the
//! line and column spelled out.

use std::fs::File;
use std::io::{BufRead, BufReader, Seek, SeekFrom};
use std::path::Path;

use anyhow::{bail, Result};

use super::{feature_token, parse_label, DatasetSource, RowChunk};

/// Streaming CSV source over any seekable buffered reader (a file in
/// production, an in-memory cursor in tests and fixtures).
pub struct CsvSource<R> {
    reader: R,
    fields: usize,
    vocab: usize,
    /// 1-based line number of the next line to be read.
    line: u64,
    /// Global ordinal of the next row to be yielded.
    row: u64,
}

impl CsvSource<BufReader<File>> {
    /// Open an on-disk CSV with `fields` feature columns hashed into
    /// `vocab` ids.
    pub fn open(path: &Path, fields: usize, vocab: usize) -> Result<Self> {
        let file = File::open(path).map_err(
            |e| anyhow::anyhow!("open csv {}: {e}", path.display()))?;
        Ok(CsvSource::from_reader(BufReader::new(file), fields, vocab))
    }
}

impl<R: BufRead + Seek> CsvSource<R> {
    pub fn from_reader(reader: R, fields: usize, vocab: usize) -> Self {
        assert!(fields > 0 && vocab > 0);
        CsvSource { reader, fields, vocab, line: 1, row: 0 }
    }

    fn parse_line(&self, raw: &str) -> Result<(String, f32, Vec<i32>)> {
        let line = self.line;
        let cols: Vec<&str> = raw.split(',').collect();
        let want = self.fields + 2;
        if cols.len() != want {
            bail!(
                "line {line}: expected {want} columns (key + label + {} \
                 features), got {}",
                self.fields,
                cols.len()
            );
        }
        let key = cols[0].trim();
        if key.is_empty() {
            bail!("line {line}, column 1: empty alignment key");
        }
        let label = parse_label(cols[1], line, 2).map_err(|e| {
            if line == 1 {
                anyhow::anyhow!(
                    "{e} (is the first line a header? the reader expects \
                     raw rows)"
                )
            } else {
                e
            }
        })?;
        let tokens = cols[2..]
            .iter()
            .enumerate()
            .map(|(f, raw)| feature_token(f, raw.trim(), self.vocab))
            .collect();
        Ok((key.to_string(), label, tokens))
    }
}

impl<R: BufRead + Seek> DatasetSource for CsvSource<R> {
    fn fields(&self) -> usize {
        self.fields
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn next_chunk(&mut self, max_rows: usize) -> Result<Option<RowChunk>> {
        assert!(max_rows > 0, "chunk size must be positive");
        let mut chunk = RowChunk {
            keys: Vec::new(),
            labels: Vec::new(),
            tokens: Vec::new(),
            fields: self.fields,
            base: self.row,
        };
        let mut buf = String::new();
        while chunk.rows() < max_rows {
            buf.clear();
            let n = self.reader.read_line(&mut buf)?;
            if n == 0 {
                break; // end of stream
            }
            let trimmed = buf.trim_end_matches(['\n', '\r']);
            if trimmed.is_empty() {
                self.line += 1;
                continue; // blank separators are tolerated
            }
            let (key, label, tokens) = self.parse_line(trimmed)?;
            chunk.keys.push(key);
            chunk.labels.push(label);
            chunk.tokens.extend(tokens);
            self.line += 1;
            self.row += 1;
        }
        if chunk.rows() == 0 {
            return Ok(None);
        }
        Ok(Some(chunk))
    }

    fn rewind(&mut self) -> Result<()> {
        self.reader.seek(SeekFrom::Start(0))?;
        self.line = 1;
        self.row = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use std::io::Cursor;

    use super::*;

    fn src(text: &str, fields: usize) -> CsvSource<Cursor<&[u8]>> {
        CsvSource::from_reader(Cursor::new(text.as_bytes()), fields, 97)
    }

    #[test]
    fn golden_chunk_layout() {
        let text = "u1,1,ad3,site9\nu2,0,ad3,site4\nu3,1,ad7,site9\n";
        let mut s = src(text, 2);
        let c = s.next_chunk(2).unwrap().unwrap();
        assert_eq!(c.rows(), 2);
        assert_eq!(c.base, 0);
        assert_eq!(c.keys, vec!["u1", "u2"]);
        assert_eq!(c.labels, vec![1.0, 0.0]);
        // Same raw string, same column → same token across rows.
        assert_eq!(c.tokens[0], feature_token(0, "ad3", 97));
        assert_eq!(c.tokens[2], feature_token(0, "ad3", 97));
        assert_eq!(c.tokens[1], feature_token(1, "site9", 97));
        let tail = s.next_chunk(8).unwrap().unwrap();
        assert_eq!(tail.rows(), 1);
        assert_eq!(tail.base, 2);
        assert_eq!(tail.keys, vec!["u3"]);
        assert!(s.next_chunk(8).unwrap().is_none());
    }

    #[test]
    fn rewind_replays_identically() {
        let text = "u1,1,a,b\nu2,0,c,d\nu3,1,e,f\n";
        let mut s = src(text, 2);
        let first = s.next_chunk(10).unwrap().unwrap();
        s.rewind().unwrap();
        let again = s.next_chunk(10).unwrap().unwrap();
        assert_eq!(first, again);
    }

    #[test]
    fn truncated_line_names_line_and_arity() {
        let text = "u1,1,a,b\nu2,0,c\n";
        let mut s = src(text, 2);
        let err = s.next_chunk(10).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("expected 4 columns"), "{err}");
        assert!(err.contains("got 3"), "{err}");
    }

    #[test]
    fn non_numeric_label_names_line_and_column() {
        let text = "u1,1,a,b\nu2,clicked,c,d\n";
        let mut s = src(text, 2);
        let err = s.next_chunk(10).unwrap_err().to_string();
        assert!(err.contains("line 2, column 2"), "{err}");
    }

    #[test]
    fn header_row_gets_a_hint() {
        let text = "key,label,f0,f1\nu1,1,a,b\n";
        let mut s = src(text, 2);
        let err = s.next_chunk(10).unwrap_err().to_string();
        assert!(err.contains("line 1, column 2"), "{err}");
        assert!(err.contains("header"), "{err}");
    }

    #[test]
    fn empty_key_rejected() {
        let text = ",1,a,b\n";
        let err = src(text, 2).next_chunk(4).unwrap_err().to_string();
        assert!(err.contains("line 1, column 1"), "{err}");
    }

    #[test]
    fn blank_lines_are_skipped_but_counted() {
        let text = "u1,1,a,b\n\nu2,bad,c,d\n";
        let mut s = src(text, 2);
        let err = s.next_chunk(10).unwrap_err().to_string();
        assert!(err.contains("line 3"), "{err}");
    }
}
