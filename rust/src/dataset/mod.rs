//! Streaming dataset ingestion + limited-overlap data plane (DESIGN.md §12).
//!
//! Everything before this module trained on a fully-aligned synthetic
//! matrix materialized in RAM. This module generalizes the data plane
//! along the two axes the ROADMAP names:
//!
//! 1. **Streaming ingestion** — a [`DatasetSource`] trait with CSV
//!    ([`csv::CsvSource`]) and libsvm ([`libsvm::LibsvmSource`]) readers
//!    that yield fixed-size [`RowChunk`]s in constant memory, hashing
//!    raw field strings into the embedding vocabulary so criteo-scale
//!    files run without ever materializing the full matrix. The
//!    existing generator flows through the same trait via
//!    [`synthetic::SyntheticSource`].
//! 2. **Limited overlap** — an [`align::AlignmentMap`] splits each
//!    party's rows into PSI-aligned rows (which flow through the
//!    existing CELU cache/local-update path unchanged) and unaligned
//!    rows, on which feature parties run self-supervised denoising
//!    updates with zero wire traffic ([`feed`]).
//!
//! Hostile inputs are first-class: every parse error names the line
//! (and column/token where one exists) so a truncated or mangled row in
//! a multi-gigabyte file is findable. Chunks are bounded by the
//! caller's `max_rows` (`--chunk-rows`), which is the module's memory
//! contract: no reader holds more than one chunk of rows at a time.

use anyhow::{bail, Result};

pub mod align;
pub mod csv;
pub mod feed;
pub mod libsvm;
pub mod synthetic;

pub use align::{split_synthetic, subset_a, subset_b, AlignmentMap};
pub use csv::CsvSource;
pub use feed::{corrupt_tokens, slice_rows_a, slice_rows_b, FeatureFeed,
               FeedShare, LabelFeed};
pub use libsvm::LibsvmSource;
pub use synthetic::SyntheticSource;

/// A bounded run of consecutive rows from a [`DatasetSource`]: hashed
/// feature tokens for every field (row-major `[rows, fields]`), one
/// label per row, and the row keys used for alignment.
#[derive(Debug, Clone, PartialEq)]
pub struct RowChunk {
    /// Per-row alignment keys (CSV key column; libsvm row ordinals).
    pub keys: Vec<String>,
    /// Per-row binary labels in `{0, 1}` (f32 to match the label party).
    pub labels: Vec<f32>,
    /// Row-major hashed token ids, `rows * fields` long.
    pub tokens: Vec<i32>,
    /// Feature fields per row (the full table width, all parties).
    pub fields: usize,
    /// Global ordinal of the chunk's first row within the stream.
    pub base: u64,
}

impl RowChunk {
    pub fn rows(&self) -> usize {
        self.keys.len()
    }

    /// Append every row of `other` (same width) onto `self`.
    pub fn extend(&mut self, other: RowChunk) {
        assert_eq!(self.fields, other.fields, "chunk width mismatch");
        self.keys.extend(other.keys);
        self.labels.extend(other.labels);
        self.tokens.extend(other.tokens);
    }
}

/// A restartable, chunked row stream. Implementations must be
/// deterministic: the same file yields the same chunks after every
/// [`rewind`](DatasetSource::rewind), which is what lets K parties
/// reading vertical slices of one table agree on window boundaries
/// without exchanging a byte.
pub trait DatasetSource {
    /// Feature fields per row (full table width).
    fn fields(&self) -> usize;

    /// Embedding vocabulary the tokens were hashed into.
    fn vocab(&self) -> usize;

    /// Next chunk of at most `max_rows` rows; `Ok(None)` at end of
    /// stream. Never buffers more than `max_rows` rows.
    fn next_chunk(&mut self, max_rows: usize) -> Result<Option<RowChunk>>;

    /// Restart the stream from the first row.
    fn rewind(&mut self) -> Result<()>;
}

/// Hash a raw field string into the embedding vocabulary. FNV-1a over
/// the field index then the bytes — deliberately not `DefaultHasher`,
/// whose output may change across std releases and would invalidate
/// golden fixtures. The field index is mixed in first so the same raw
/// string in two columns maps to independent tokens.
pub fn feature_token(field: usize, raw: &str, vocab: usize) -> i32 {
    debug_assert!(vocab > 0);
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in (field as u64).to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    for &b in raw.as_bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % vocab as u64) as i32
}

/// Parse a `{0, 1}` label, naming the line/column on hostile input.
pub(crate) fn parse_label(raw: &str, line: u64, column: usize) -> Result<f32> {
    let v: f32 = match raw.trim().parse() {
        Ok(v) => v,
        Err(_) => bail!(
            "line {line}, column {column}: label '{raw}' is not a number"
        ),
    };
    if v != 0.0 && v != 1.0 {
        bail!(
            "line {line}, column {column}: label '{raw}' must be 0 or 1"
        );
    }
    Ok(v)
}

/// Materialize the first `rows` rows of a source as one chunk, reading
/// `chunk_rows` at a time so the transient buffer honours the chunk
/// bound. Used to reserve a bounded evaluation prefix before training
/// streams the remainder.
pub fn read_prefix(
    source: &mut dyn DatasetSource,
    rows: usize,
    chunk_rows: usize,
) -> Result<RowChunk> {
    let mut out: Option<RowChunk> = None;
    let mut got = 0usize;
    while got < rows {
        let want = (rows - got).min(chunk_rows.max(1));
        match source.next_chunk(want)? {
            Some(chunk) => {
                got += chunk.rows();
                match &mut out {
                    Some(acc) => acc.extend(chunk),
                    None => out = Some(chunk),
                }
            }
            None => bail!(
                "dataset ends after {got} rows — need {rows} for the \
                 evaluation prefix (eval_batches × batch); shrink \
                 eval_batches or supply more data"
            ),
        }
    }
    Ok(out.expect("rows > 0 guaranteed by caller"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_are_stable_and_field_salted() {
        // Golden values: changing the hash silently would desynchronize
        // features across re-ingestions of the same file.
        assert_eq!(feature_token(0, "a", 1000), feature_token(0, "a", 1000));
        assert_ne!(feature_token(0, "a", 100_000),
                   feature_token(1, "a", 100_000));
        assert_ne!(feature_token(3, "a", 100_000),
                   feature_token(3, "b", 100_000));
        let t = feature_token(2, "widget", 50);
        assert!((0..50).contains(&t));
    }

    #[test]
    fn labels_must_be_binary_numbers() {
        assert_eq!(parse_label("1", 1, 2).unwrap(), 1.0);
        assert_eq!(parse_label("0", 1, 2).unwrap(), 0.0);
        let err = parse_label("click", 7, 2).unwrap_err().to_string();
        assert!(err.contains("line 7, column 2"), "{err}");
        let err = parse_label("0.5", 9, 2).unwrap_err().to_string();
        assert!(err.contains("must be 0 or 1"), "{err}");
    }
}
