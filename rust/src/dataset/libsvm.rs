//! Chunked libsvm reader: `label idx:val …` → hashed token rows.
//!
//! libsvm rows are sparse and unkeyed, while the model wants a dense
//! `fields`-wide categorical row and the alignment plane wants a key.
//! The mapping, fixed so every party derives it identically:
//!
//! - **key** — the global row ordinal (libsvm exports of a PSI-sorted
//!   table are row-aligned across parties, so the ordinal *is* the
//!   join key).
//! - **slot** — each `idx:val` pair lands in field `idx % fields`; the
//!   slot's token is [`feature_token`](super::feature_token) of the
//!   canonical `"idx:val"` string, so distinct (index, value) pairs
//!   stay distinguishable after folding. When several pairs fold into
//!   one slot the last pair wins; a slot no pair reaches holds the
//!   hashed `"<missing>"` marker rather than a magic id.
//!
//! Hostile rows (malformed pairs, non-numeric labels or indices) fail
//! with line and token position named.

use std::fs::File;
use std::io::{BufRead, BufReader, Seek, SeekFrom};
use std::path::Path;

use anyhow::{bail, Result};

use super::{feature_token, parse_label, DatasetSource, RowChunk};

/// Streaming libsvm source over any seekable buffered reader.
pub struct LibsvmSource<R> {
    reader: R,
    fields: usize,
    vocab: usize,
    line: u64,
    row: u64,
}

impl LibsvmSource<BufReader<File>> {
    pub fn open(path: &Path, fields: usize, vocab: usize) -> Result<Self> {
        let file = File::open(path).map_err(
            |e| anyhow::anyhow!("open libsvm {}: {e}", path.display()))?;
        Ok(LibsvmSource::from_reader(BufReader::new(file), fields, vocab))
    }
}

impl<R: BufRead + Seek> LibsvmSource<R> {
    pub fn from_reader(reader: R, fields: usize, vocab: usize) -> Self {
        assert!(fields > 0 && vocab > 0);
        LibsvmSource { reader, fields, vocab, line: 1, row: 0 }
    }

    fn parse_line(&self, raw: &str) -> Result<(f32, Vec<i32>)> {
        let line = self.line;
        let mut parts = raw.split_ascii_whitespace();
        let label_raw = parts.next().expect("caller skips blank lines");
        let label = parse_label(label_raw, line, 1)?;
        let mut tokens: Vec<i32> = (0..self.fields)
            .map(|f| feature_token(f, "<missing>", self.vocab))
            .collect();
        for (pos, pair) in parts.enumerate() {
            // `pos` is 0-based over the pairs; humans count the label
            // as token 1, so pair i is token i + 2.
            let token_pos = pos + 2;
            let Some((idx_raw, val_raw)) = pair.split_once(':') else {
                bail!(
                    "line {line}, token {token_pos}: malformed 'index:value' \
                     pair '{pair}'"
                );
            };
            let idx: u64 = idx_raw.parse().map_err(|_| {
                anyhow::anyhow!(
                    "line {line}, token {token_pos}: feature index \
                     '{idx_raw}' is not an integer"
                )
            })?;
            if val_raw.parse::<f64>().is_err() {
                bail!(
                    "line {line}, token {token_pos}: feature value \
                     '{val_raw}' is not a number"
                );
            }
            let slot = (idx % self.fields as u64) as usize;
            tokens[slot] =
                feature_token(slot, &format!("{idx}:{val_raw}"), self.vocab);
        }
        Ok((label, tokens))
    }
}

impl<R: BufRead + Seek> DatasetSource for LibsvmSource<R> {
    fn fields(&self) -> usize {
        self.fields
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn next_chunk(&mut self, max_rows: usize) -> Result<Option<RowChunk>> {
        assert!(max_rows > 0, "chunk size must be positive");
        let mut chunk = RowChunk {
            keys: Vec::new(),
            labels: Vec::new(),
            tokens: Vec::new(),
            fields: self.fields,
            base: self.row,
        };
        let mut buf = String::new();
        while chunk.rows() < max_rows {
            buf.clear();
            let n = self.reader.read_line(&mut buf)?;
            if n == 0 {
                break;
            }
            let trimmed = buf.trim();
            if trimmed.is_empty() {
                self.line += 1;
                continue;
            }
            let (label, tokens) = self.parse_line(trimmed)?;
            chunk.keys.push(self.row.to_string());
            chunk.labels.push(label);
            chunk.tokens.extend(tokens);
            self.line += 1;
            self.row += 1;
        }
        if chunk.rows() == 0 {
            return Ok(None);
        }
        Ok(Some(chunk))
    }

    fn rewind(&mut self) -> Result<()> {
        self.reader.seek(SeekFrom::Start(0))?;
        self.line = 1;
        self.row = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use std::io::Cursor;

    use super::*;

    fn src(text: &str, fields: usize) -> LibsvmSource<Cursor<&[u8]>> {
        LibsvmSource::from_reader(Cursor::new(text.as_bytes()), fields, 97)
    }

    #[test]
    fn golden_chunk_layout() {
        let text = "1 0:3 5:1\n0 1:2\n";
        let mut s = src(text, 4);
        let c = s.next_chunk(8).unwrap().unwrap();
        assert_eq!(c.rows(), 2);
        assert_eq!(c.keys, vec!["0", "1"]);
        assert_eq!(c.labels, vec![1.0, 0.0]);
        assert_eq!(c.fields, 4);
        // Row 0: index 0 → slot 0, index 5 → slot 1 (5 % 4).
        assert_eq!(c.tokens[0], feature_token(0, "0:3", 97));
        assert_eq!(c.tokens[1], feature_token(1, "5:1", 97));
        // Untouched slots carry the hashed missing marker.
        assert_eq!(c.tokens[2], feature_token(2, "<missing>", 97));
        assert_eq!(c.tokens[3], feature_token(3, "<missing>", 97));
        // Row 1: only slot 1 is set.
        assert_eq!(c.tokens[4], feature_token(0, "<missing>", 97));
        assert_eq!(c.tokens[5], feature_token(1, "1:2", 97));
    }

    #[test]
    fn ordinal_keys_survive_chunk_boundaries_and_rewind() {
        let text = "1 0:1\n0 1:1\n1 2:1\n";
        let mut s = src(text, 3);
        assert_eq!(s.next_chunk(2).unwrap().unwrap().keys, vec!["0", "1"]);
        assert_eq!(s.next_chunk(2).unwrap().unwrap().keys, vec!["2"]);
        s.rewind().unwrap();
        assert_eq!(s.next_chunk(3).unwrap().unwrap().keys,
                   vec!["0", "1", "2"]);
    }

    #[test]
    fn malformed_pair_names_line_and_token() {
        let text = "1 0:1\n0 0:1 borked\n";
        let err = src(text, 3).next_chunk(8).unwrap_err().to_string();
        assert!(err.contains("line 2, token 3"), "{err}");
        assert!(err.contains("borked"), "{err}");
    }

    #[test]
    fn non_numeric_index_and_value_rejected() {
        let err = src("1 x:1\n", 3).next_chunk(8).unwrap_err().to_string();
        assert!(err.contains("line 1, token 2"), "{err}");
        assert!(err.contains("not an integer"), "{err}");
        let err = src("1 0:nan-ish\n", 3)
            .next_chunk(8).unwrap_err().to_string();
        assert!(err.contains("not a number"), "{err}");
    }

    #[test]
    fn bad_label_names_position() {
        let err = src("yes 0:1\n", 3).next_chunk(8).unwrap_err().to_string();
        assert!(err.contains("line 1, column 1"), "{err}");
    }
}
