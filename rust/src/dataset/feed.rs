//! Batch feeds: one interface between the party loops and the data
//! plane, with two implementations.
//!
//! - **In-memory** — wraps the historical `(table, BatchCursor)` pair
//!   and reproduces its index sequence verbatim, so fully-materialized
//!   runs (synthetic, or synthetic with an overlap split) stay
//!   byte-identical on the wire to the pre-feed code.
//! - **Streaming** — consumes a [`DatasetSource`] in *windows* of
//!   `chunk_rows` raw rows. Within a window the aligned rows (per the
//!   shared [`AlignmentMap`]) form the training set: `aligned / batch`
//!   communication rounds are scheduled over them with the same
//!   seeded [`BatchSchedule`] on every party, then the window is
//!   dropped and the next chunk read — constant memory, deterministic
//!   lockstep, zero coordination traffic. Windows with fewer than
//!   `batch` aligned rows are skipped identically everywhere; end of
//!   stream rewinds (an epoch); a full pass with no usable window is
//!   an error. Unaligned rows of the current window pool into the
//!   feed's SSL reservoir for label-free local updates.
//!
//! Local-update workers observe the feed through a [`FeedShare`]: a
//! `(table, floor)` snapshot where `floor` is the first round served
//! from the live window. Workset entries below the floor refer to a
//! retired window and must be skipped (the comm loop also calls
//! `MeshWorkset::retire_below` so they stop being sampled at all).

use std::ops::Range;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::data::batcher::{
    gather_a_with, gather_b_with, BatchCursor, BatchSchedule, GatherScratch,
};
use crate::data::{PartyAData, PartyBData};
use crate::tensor::Tensor;
use crate::util::rng::Pcg;

use super::align::AlignmentMap;
use super::{DatasetSource, RowChunk};

/// Pcg stream for SSL reservoir sampling + denoising corruption —
/// disjoint from batch/data/align/kill streams.
const SSL_STREAM: u64 = 0x55e1_0e11_ab5e_ed01;

/// Table handle shared between a feed (writer) and local-update
/// workers (readers). `snapshot()` returns the live table plus the
/// `floor`: the first communication round whose cached statistics were
/// computed against this table. Entries with `round < floor` belong
/// to a retired window and must not be gathered against the new one.
#[derive(Debug)]
pub struct FeedShare<T> {
    inner: Mutex<(Arc<T>, u64)>,
}

impl<T> FeedShare<T> {
    fn new(data: Arc<T>) -> Arc<Self> {
        Arc::new(FeedShare { inner: Mutex::new((data, 0)) })
    }

    /// Consistent (table, floor) pair.
    pub fn snapshot(&self) -> (Arc<T>, u64) {
        let g = self.inner.lock().unwrap();
        (g.0.clone(), g.1)
    }

    pub fn floor(&self) -> u64 {
        self.inner.lock().unwrap().1
    }

    fn publish(&self, data: Arc<T>, floor: u64) {
        *self.inner.lock().unwrap() = (data, floor);
    }
}

/// Deterministic usable-window iterator over a chunked source, shared
/// by the feature and label feeds so their window boundaries agree.
struct ChunkWindows {
    source: Box<dyn DatasetSource + Send>,
    align: AlignmentMap,
    chunk_rows: usize,
    /// Evaluation-prefix rows skipped after every rewind.
    skip_rows: usize,
    batch: usize,
    /// Raw training chunks consumed — the window ordinal, which seeds
    /// the per-window batch schedule on every party identically.
    chunk_ord: u64,
    usable_seen: bool,
}

impl ChunkWindows {
    fn new(
        mut source: Box<dyn DatasetSource + Send>,
        align: AlignmentMap,
        batch: usize,
        chunk_rows: usize,
        skip_rows: usize,
    ) -> Result<Self> {
        assert!(batch > 0);
        if chunk_rows < batch {
            bail!(
                "chunk_rows ({chunk_rows}) must be at least the batch \
                 size ({batch}) — no window could ever hold a full batch"
            );
        }
        skip(source.as_mut(), skip_rows, chunk_rows)?;
        Ok(ChunkWindows {
            source,
            align,
            chunk_rows,
            skip_rows,
            batch,
            chunk_ord: 0,
            usable_seen: false,
        })
    }

    /// Next window holding at least one full aligned batch: the raw
    /// chunk, its aligned and unaligned row offsets, and the window
    /// ordinal. Rewinds at end of stream; errors if a complete pass
    /// yields nothing usable.
    fn next_window(&mut self) -> Result<(RowChunk, Vec<u32>, Vec<u32>, u64)> {
        loop {
            match self.source.next_chunk(self.chunk_rows)? {
                None => {
                    if !self.usable_seen {
                        bail!(
                            "no window of {} rows holds {} aligned rows at \
                             overlap {} — grow --chunk-rows or the overlap",
                            self.chunk_rows,
                            self.batch,
                            self.align.overlap()
                        );
                    }
                    self.usable_seen = false;
                    self.source.rewind()?;
                    skip(self.source.as_mut(), self.skip_rows,
                         self.chunk_rows)?;
                }
                Some(chunk) => {
                    let ord = self.chunk_ord;
                    self.chunk_ord += 1;
                    let (aligned, unaligned) = self.align.split(&chunk.keys);
                    if aligned.len() < self.batch {
                        continue; // skipped identically on every party
                    }
                    self.usable_seen = true;
                    return Ok((chunk, aligned, unaligned, ord));
                }
            }
        }
    }
}

/// Discard `rows` rows in bounded pieces (the evaluation prefix).
fn skip(
    source: &mut dyn DatasetSource,
    rows: usize,
    chunk_rows: usize,
) -> Result<()> {
    let mut left = rows;
    while left > 0 {
        let want = left.min(chunk_rows);
        match source.next_chunk(want)? {
            Some(c) => left = left.saturating_sub(c.rows()),
            None => bail!(
                "dataset ends inside the {rows}-row evaluation prefix"
            ),
        }
    }
    Ok(())
}

/// This party's columns of the chunk's selected rows, as an A table.
pub fn slice_rows_a(chunk: &RowChunk, rows: &[u32], cols: &Range<usize>)
    -> PartyAData
{
    let f = cols.len();
    let w = chunk.fields;
    let mut x = Vec::with_capacity(rows.len() * f);
    for &r in rows {
        let r = r as usize;
        x.extend_from_slice(&chunk.tokens[r * w + cols.start
                                          ..r * w + cols.end]);
    }
    PartyAData { fields: f, x, n: rows.len() }
}

/// The label party's columns + labels of the selected rows.
pub fn slice_rows_b(chunk: &RowChunk, rows: &[u32], cols: &Range<usize>)
    -> PartyBData
{
    let a = slice_rows_a(chunk, rows, cols);
    let y = rows.iter().map(|&r| chunk.labels[r as usize]).collect();
    PartyBData { fields: a.fields, x: a.x, y, n: a.n }
}

/// Per-window schedule state shared by both feed flavours.
struct WindowCursor {
    windows: ChunkWindows,
    cols: Range<usize>,
    schedule: BatchSchedule,
    rounds_in_window: usize,
    used: usize,
    seed: u64,
}

enum Mode {
    InMemory { cursor: BatchCursor, n: usize },
    Stream(WindowCursor),
}

/// A feature party's batch feed (see module docs).
pub struct FeatureFeed {
    mode: Mode,
    share: Arc<FeedShare<PartyAData>>,
    batch: usize,
    seed: u64,
    taken: u64,
    ssl_pool: Option<Arc<PartyAData>>,
    ssl_rng: Pcg,
}

impl FeatureFeed {
    /// Wrap a fully-materialized table; reproduces the historical
    /// `BatchCursor` sequence exactly (the table `Arc` is shared, not
    /// copied — full-overlap runs stay zero-copy).
    pub fn in_memory(train: Arc<PartyAData>, seed: u64, batch: usize)
        -> Self
    {
        let n = train.n;
        FeatureFeed {
            mode: Mode::InMemory {
                cursor: BatchCursor::new(seed, n, batch),
                n,
            },
            share: FeedShare::new(train),
            batch,
            seed,
            taken: 0,
            ssl_pool: None,
            ssl_rng: Pcg::new(seed, SSL_STREAM),
        }
    }

    /// Attach an unaligned-row reservoir for self-supervised updates.
    pub fn with_ssl_pool(mut self, pool: PartyAData) -> Self {
        self.ssl_pool = Some(Arc::new(pool));
        self
    }

    /// Stream this party's `cols` from a chunked source (see module
    /// docs for the window protocol). `skip_rows` is the evaluation
    /// prefix every party reserves before training rows begin.
    pub fn streaming(
        source: Box<dyn DatasetSource + Send>,
        cols: Range<usize>,
        align: AlignmentMap,
        seed: u64,
        batch: usize,
        chunk_rows: usize,
        skip_rows: usize,
    ) -> Result<Self> {
        let mut windows =
            ChunkWindows::new(source, align, batch, chunk_rows, skip_rows)?;
        let (chunk, aligned, unaligned, ord) = windows.next_window()?;
        let window = Arc::new(slice_rows_a(&chunk, &aligned, &cols));
        let pool = slice_rows_a(&chunk, &unaligned, &cols);
        let schedule = BatchSchedule::new(seed, ord, aligned.len(), batch);
        let rounds_in_window = aligned.len() / batch;
        Ok(FeatureFeed {
            mode: Mode::Stream(WindowCursor {
                windows,
                cols,
                schedule,
                rounds_in_window,
                used: 0,
                seed,
            }),
            share: FeedShare::new(window),
            batch,
            seed,
            taken: 0,
            ssl_pool: Some(Arc::new(pool)),
            ssl_rng: Pcg::new(seed, SSL_STREAM),
        })
    }

    /// Handle for local-update workers.
    pub fn share(&self) -> Arc<FeedShare<PartyAData>> {
        self.share.clone()
    }

    /// First round served from the live window (0 while in-memory).
    pub fn floor(&self) -> u64 {
        self.share.floor()
    }

    /// Rows in the live training table.
    pub fn len(&self) -> usize {
        self.share.snapshot().0.n
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Batch indices + gathered features for `round`, fast-forwarding
    /// past any rounds this feed has not yet served (resume path).
    pub fn batch(&mut self, round: u64, scratch: &mut GatherScratch)
        -> Result<(Vec<u32>, Tensor)>
    {
        let idx = self.indices_for(round)?;
        let (data, _) = self.share.snapshot();
        let xa = gather_a_with(&data, &idx, scratch);
        Ok((idx, xa))
    }

    /// A `[batch, F]` sample of unaligned rows (with replacement), or
    /// `None` when no reservoir is attached or it is empty.
    pub fn ssl_batch(&mut self, scratch: &mut GatherScratch)
        -> Option<Tensor>
    {
        let pool = self.ssl_pool.as_ref()?.clone();
        if pool.n == 0 {
            return None;
        }
        let idx: Vec<u32> = (0..self.batch)
            .map(|_| self.ssl_rng.gen_range(pool.n as u32))
            .collect();
        Some(gather_a_with(&pool, &idx, scratch))
    }

    /// Does this feed carry unaligned rows for SSL work at all?
    pub fn has_ssl_pool(&self) -> bool {
        self.ssl_pool.as_ref().map_or(false, |p| p.n > 0)
    }

    /// Rebuild the cursor from round 0 (rejoin replay). Streaming
    /// feeds refuse: their windows have already been dropped.
    pub fn reset(&mut self) -> Result<()> {
        match &mut self.mode {
            Mode::InMemory { cursor, n } => {
                *cursor = BatchCursor::new(self.seed, *n, self.batch);
                self.taken = 0;
                Ok(())
            }
            Mode::Stream(_) => bail!(
                "streaming feeds cannot replay from round 0 — rejoin \
                 recovery requires the in-memory data plane"
            ),
        }
    }

    fn indices_for(&mut self, round: u64) -> Result<Vec<u32>> {
        while self.taken < round {
            self.advance()?;
        }
        self.advance()
    }

    fn advance(&mut self) -> Result<Vec<u32>> {
        let idx = match &mut self.mode {
            Mode::InMemory { cursor, .. } => cursor.next_indices(),
            Mode::Stream(wc) => {
                if wc.used == wc.rounds_in_window {
                    let (chunk, aligned, unaligned, ord) =
                        wc.windows.next_window()?;
                    let window =
                        Arc::new(slice_rows_a(&chunk, &aligned, &wc.cols));
                    let pool = slice_rows_a(&chunk, &unaligned, &wc.cols);
                    wc.schedule = BatchSchedule::new(
                        wc.seed, ord, aligned.len(), self.batch);
                    wc.rounds_in_window = aligned.len() / self.batch;
                    wc.used = 0;
                    self.share.publish(window, self.taken);
                    self.ssl_pool = Some(Arc::new(pool));
                }
                let idx = wc.schedule.indices(wc.used).to_vec();
                wc.used += 1;
                idx
            }
        };
        self.taken += 1;
        Ok(idx)
    }
}

/// The label party's batch feed: same window protocol, plus labels.
pub struct LabelFeed {
    mode: Mode,
    share: Arc<FeedShare<PartyBData>>,
    batch: usize,
    seed: u64,
    taken: u64,
}

impl LabelFeed {
    pub fn in_memory(train: Arc<PartyBData>, seed: u64, batch: usize)
        -> Self
    {
        let n = train.n;
        LabelFeed {
            mode: Mode::InMemory {
                cursor: BatchCursor::new(seed, n, batch),
                n,
            },
            share: FeedShare::new(train),
            batch,
            seed,
            taken: 0,
        }
    }

    pub fn streaming(
        source: Box<dyn DatasetSource + Send>,
        cols: Range<usize>,
        align: AlignmentMap,
        seed: u64,
        batch: usize,
        chunk_rows: usize,
        skip_rows: usize,
    ) -> Result<Self> {
        let mut windows =
            ChunkWindows::new(source, align, batch, chunk_rows, skip_rows)?;
        let (chunk, aligned, _, ord) = windows.next_window()?;
        let window = Arc::new(slice_rows_b(&chunk, &aligned, &cols));
        let schedule = BatchSchedule::new(seed, ord, aligned.len(), batch);
        let rounds_in_window = aligned.len() / batch;
        Ok(LabelFeed {
            mode: Mode::Stream(WindowCursor {
                windows,
                cols,
                schedule,
                rounds_in_window,
                used: 0,
                seed,
            }),
            share: FeedShare::new(window),
            batch,
            seed,
            taken: 0,
        })
    }

    pub fn share(&self) -> Arc<FeedShare<PartyBData>> {
        self.share.clone()
    }

    pub fn floor(&self) -> u64 {
        self.share.floor()
    }

    pub fn len(&self) -> usize {
        self.share.snapshot().0.n
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Rebuild the cursor from round 0 (see [`FeatureFeed::reset`]).
    pub fn reset(&mut self) -> Result<()> {
        match &mut self.mode {
            Mode::InMemory { cursor, n } => {
                *cursor = BatchCursor::new(self.seed, *n, self.batch);
                self.taken = 0;
                Ok(())
            }
            Mode::Stream(_) => bail!(
                "streaming feeds cannot replay from round 0 — rejoin \
                 recovery requires the in-memory data plane"
            ),
        }
    }

    /// Batch indices + gathered `(features, labels)` for `round`.
    pub fn batch(&mut self, round: u64, scratch: &mut GatherScratch)
        -> Result<(Vec<u32>, Tensor, Tensor)>
    {
        while self.taken < round {
            self.advance()?;
        }
        let idx = self.advance()?;
        let (data, _) = self.share.snapshot();
        let (xb, y) = gather_b_with(&data, &idx, scratch);
        Ok((idx, xb, y))
    }

    fn advance(&mut self) -> Result<Vec<u32>> {
        let idx = match &mut self.mode {
            Mode::InMemory { cursor, .. } => cursor.next_indices(),
            Mode::Stream(wc) => {
                if wc.used == wc.rounds_in_window {
                    let (chunk, aligned, _, ord) =
                        wc.windows.next_window()?;
                    let window =
                        Arc::new(slice_rows_b(&chunk, &aligned, &wc.cols));
                    wc.schedule = BatchSchedule::new(
                        wc.seed, ord, aligned.len(), self.batch);
                    wc.rounds_in_window = aligned.len() / self.batch;
                    wc.used = 0;
                    self.share.publish(window, self.taken);
                }
                let idx = wc.schedule.indices(wc.used).to_vec();
                wc.used += 1;
                idx
            }
        };
        self.taken += 1;
        Ok(idx)
    }
}

/// Denoising corruption for SSL updates: re-draw each token from the
/// vocabulary with probability `rate` (categorical masking noise).
pub fn corrupt_tokens(
    xa: &Tensor,
    vocab: usize,
    rate: f32,
    rng: &mut Pcg,
) -> Result<Tensor> {
    assert!(vocab > 0);
    let src = xa.as_i32()?;
    let mut out = src.to_vec();
    for v in out.iter_mut() {
        if rng.next_f32() < rate {
            *v = rng.gen_range(vocab as u32) as i32;
        }
    }
    Ok(Tensor::i32(xa.shape.clone(), out))
}

#[cfg(test)]
mod tests {
    use std::io::Cursor as IoCursor;

    use crate::data::batcher::gather_a;
    use crate::data::SynthDataset;
    use crate::dataset::csv::CsvSource;
    use crate::dataset::synthetic::SyntheticSource;

    use super::*;

    const SEED: u64 = 42;
    const BATCH: usize = 8;

    /// Satellite regression: at overlap 1.0 the in-memory feed must be
    /// indistinguishable — index for index, byte for byte — from the
    /// raw `(BatchCursor, gather)` pair the party loops used before
    /// the data plane existed. Wire parity is downstream of this.
    #[test]
    fn in_memory_feed_matches_raw_cursor_exactly() {
        let ds = SynthDataset::generate("avazu", 50, 200, 10, 0.0, 3)
            .unwrap();
        let train = Arc::new(ds.train_a.clone());
        let mut feed = FeatureFeed::in_memory(train.clone(), SEED, BATCH);
        let mut cursor = BatchCursor::new(SEED, train.n, BATCH);
        let mut scratch = GatherScratch::default();
        for round in 0..60u64 {
            let (idx, xa) = feed.batch(round, &mut scratch).unwrap();
            let want_idx = cursor.next_indices();
            assert_eq!(idx, want_idx, "index drift at round {round}");
            assert_eq!(xa, gather_a(&train, &want_idx));
        }
        // Zero-copy: the feed shares the caller's table, not a copy.
        assert!(Arc::ptr_eq(&feed.share().snapshot().0, &train));
        assert_eq!(feed.floor(), 0);
    }

    #[test]
    fn label_feed_matches_raw_cursor_and_fast_forwards() {
        let ds = SynthDataset::generate("avazu", 50, 200, 10, 0.0, 3)
            .unwrap();
        let train = Arc::new(ds.train_b.clone());
        let mut feed = LabelFeed::in_memory(train.clone(), SEED, BATCH);
        let mut cursor = BatchCursor::new(SEED, train.n, BATCH);
        let mut scratch = GatherScratch::default();
        // Start at round 5 (resume path): the feed must burn rounds
        // 0..5 exactly like the historical fast-forward loop.
        for _ in 0..5 {
            cursor.next_indices();
        }
        for round in 5..20u64 {
            let (idx, _, y) = feed.batch(round, &mut scratch).unwrap();
            assert_eq!(idx, cursor.next_indices());
            let want: Vec<f32> =
                idx.iter().map(|&i| train.y[i as usize]).collect();
            assert_eq!(y.as_f32().unwrap(), &want[..]);
        }
    }

    #[test]
    fn reset_replays_the_sequence() {
        let ds = SynthDataset::generate("avazu", 50, 100, 10, 0.0, 3)
            .unwrap();
        let mut feed = FeatureFeed::in_memory(
            Arc::new(ds.train_a.clone()), SEED, BATCH);
        let mut scratch = GatherScratch::default();
        let first: Vec<Vec<u32>> = (0..6)
            .map(|r| feed.batch(r, &mut scratch).unwrap().0)
            .collect();
        feed.reset().unwrap();
        let again: Vec<Vec<u32>> = (0..6)
            .map(|r| feed.batch(r, &mut scratch).unwrap().0)
            .collect();
        assert_eq!(first, again);
    }

    /// Build a CSV with 2 feature columns (one per "party").
    fn csv_text(rows: usize) -> String {
        let mut text = String::new();
        for i in 0..rows {
            text += &format!("user{i},{},a{i},b{i}\n", i % 2);
        }
        text
    }

    fn csv_feed(
        text: &str,
        cols: Range<usize>,
        overlap: f64,
        batch: usize,
        chunk: usize,
        skip: usize,
    ) -> Result<FeatureFeed> {
        let src = CsvSource::from_reader(
            IoCursor::new(text.as_bytes().to_vec()), 2, 97);
        FeatureFeed::streaming(
            Box::new(src), cols, AlignmentMap::new(SEED, overlap),
            SEED, batch, chunk, skip)
    }

    #[test]
    fn stream_feeds_agree_across_parties_and_roles() {
        let text = csv_text(300);
        let mut fa = csv_feed(&text, 0..1, 0.5, 4, 32, 0).unwrap();
        let mut fb = csv_feed(&text, 1..2, 0.5, 4, 32, 0).unwrap();
        let src = CsvSource::from_reader(
            IoCursor::new(text.as_bytes().to_vec()), 2, 97);
        let mut lbl = LabelFeed::streaming(
            Box::new(src), 1..2, AlignmentMap::new(SEED, 0.5),
            SEED, 4, 32, 0).unwrap();
        let mut s = GatherScratch::default();
        let (mut s2, mut s3) =
            (GatherScratch::default(), GatherScratch::default());
        for round in 0..40u64 {
            let (ia, _) = fa.batch(round, &mut s).unwrap();
            let (ib, _) = fb.batch(round, &mut s2).unwrap();
            let (il, _, _) = lbl.batch(round, &mut s3).unwrap();
            assert_eq!(ia, ib, "feature parties diverged at {round}");
            assert_eq!(ia, il, "label diverged at {round}");
            assert_eq!(fa.floor(), lbl.floor(), "floors diverged");
        }
        // The epoch wrapped (300 rows, ~150 aligned, 40×4 = 160 drawn
        // plus skipped windows) — rewind determinism held throughout.
        assert!(fa.floor() > 0, "window never advanced");
    }

    #[test]
    fn stream_window_respects_chunk_bound_and_floor() {
        let text = csv_text(300);
        let chunk = 32;
        let mut feed = csv_feed(&text, 0..1, 0.5, 4, chunk, 0).unwrap();
        let mut scratch = GatherScratch::default();
        let mut last_floor = 0;
        for round in 0..40u64 {
            feed.batch(round, &mut scratch).unwrap();
            let (window, floor) = feed.share().snapshot();
            let pooled =
                feed.ssl_pool.as_ref().map_or(0, |p| p.n);
            assert!(
                window.n + pooled <= chunk,
                "window {} + pool {pooled} exceeds chunk {chunk}",
                window.n
            );
            assert!(floor >= last_floor, "floor went backwards");
            assert!(floor <= round, "floor from the future");
            last_floor = floor;
            // Batch indices address the live window only.
            assert!(window.n >= 4);
        }
        assert!(feed.has_ssl_pool(), "overlap 0.5 must pool rows");
        assert!(feed.reset().is_err(), "stream reset must refuse");
    }

    #[test]
    fn stream_skips_eval_prefix_rows() {
        let text = csv_text(300);
        // Feeds differing only in skip must serve different windows.
        let mut with_skip = csv_feed(&text, 0..1, 1.0, 4, 32, 64).unwrap();
        let mut no_skip = csv_feed(&text, 0..1, 1.0, 4, 32, 0).unwrap();
        let mut s = GatherScratch::default();
        let (_, a) = with_skip.batch(0, &mut s).unwrap();
        let a = a.as_i32().unwrap().to_vec();
        let (_, b) = no_skip.batch(0, &mut s).unwrap();
        assert_ne!(a, b.as_i32().unwrap().to_vec());
        // At overlap 1.0 window rows are the raw rows: the skipped
        // feed's first window starts at file row 64.
        let want = super::super::feature_token(0, "a64", 97);
        assert_eq!(with_skip.share().snapshot().0.x[0], want);
    }

    #[test]
    fn unusable_stream_names_the_cure() {
        // 20-row file, chunk 16, batch 16, overlap .2: no window can
        // ever hold a full aligned batch.
        let err = csv_feed(&csv_text(20), 0..1, 0.2, 16, 16, 0)
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("--chunk-rows"), "{err}");
        let err = csv_feed(&csv_text(20), 0..1, 0.5, 8, 4, 0)
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("chunk_rows (4)"), "{err}");
    }

    #[test]
    fn ssl_batch_draws_only_pooled_rows() {
        let ds = SynthDataset::generate("avazu", 50, 100, 10, 0.0, 3)
            .unwrap();
        let f = ds.train_a.fields;
        // A pool of rows holding a marker value outside the aligned
        // table's vocabulary range.
        let pool = PartyAData {
            fields: f,
            x: vec![777; 5 * f],
            n: 5,
        };
        let mut feed = FeatureFeed::in_memory(
            Arc::new(ds.train_a.clone()), SEED, BATCH)
            .with_ssl_pool(pool);
        let mut scratch = GatherScratch::default();
        let xs = feed.ssl_batch(&mut scratch).unwrap();
        assert_eq!(xs.shape, vec![BATCH, f]);
        assert!(xs.as_i32().unwrap().iter().all(|&v| v == 777));
        // Without a pool there is no SSL work.
        let mut bare = FeatureFeed::in_memory(
            Arc::new(ds.train_a.clone()), SEED, BATCH);
        assert!(bare.ssl_batch(&mut scratch).is_none());
        assert!(!bare.has_ssl_pool());
    }

    #[test]
    fn corruption_respects_rate_and_vocab() {
        let clean = Tensor::i32(vec![16, 8], vec![5i32; 128]);
        let mut rng = Pcg::new(1, 2);
        let noisy = corrupt_tokens(&clean, 50, 0.3, &mut rng).unwrap();
        let flipped = noisy
            .as_i32().unwrap()
            .iter()
            .zip(clean.as_i32().unwrap())
            .filter(|(a, b)| a != b)
            .count();
        assert!(flipped > 10 && flipped < 70, "flipped {flipped}/128");
        assert!(noisy.as_i32().unwrap().iter().all(|&v| (0..50).contains(&v)));
        // Rate 0 is the identity.
        let same = corrupt_tokens(&clean, 50, 0.0, &mut rng).unwrap();
        assert_eq!(same.as_i32().unwrap(), clean.as_i32().unwrap());
    }

    #[test]
    fn synthetic_source_streams_like_a_file() {
        // The adapter path: windows over generated tables with a real
        // overlap split, feature cols vs. label cols staying aligned.
        let ds = SynthDataset::generate("avazu", 50, 256, 0, 0.0, 9)
            .unwrap();
        let (fa, fb) = (ds.train_a.fields, ds.train_b.fields);
        let mk = || {
            Box::new(SyntheticSource::new(
                ds.train_a.clone(), ds.train_b.clone(), 50))
        };
        let map = AlignmentMap::new(SEED, 0.4);
        let mut fa_feed = FeatureFeed::streaming(
            mk(), 0..fa, map, SEED, BATCH, 64, 0).unwrap();
        let mut lb_feed = LabelFeed::streaming(
            mk(), fa..fa + fb, map, SEED, BATCH, 64, 0).unwrap();
        let mut s = GatherScratch::default();
        let mut s2 = GatherScratch::default();
        for round in 0..12u64 {
            let (ia, _) = fa_feed.batch(round, &mut s).unwrap();
            let (il, _, _) = lb_feed.batch(round, &mut s2).unwrap();
            assert_eq!(ia, il);
        }
    }
}
