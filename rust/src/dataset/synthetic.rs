//! [`DatasetSource`] adapter over the in-memory synthetic generator.
//!
//! Lets the generated tables flow through the same chunked trait as
//! the file readers, so every consumer (feeds, ablations, examples)
//! is written against one interface. The adapter is *not* a streaming
//! loader — the tables it wraps are already materialized — it exists
//! so `--data-format synthetic` and the file formats share one code
//! path, and so chunk-lifecycle tests can run without touching disk.

use anyhow::Result;

use crate::data::{PartyAData, PartyBData};

use super::{DatasetSource, RowChunk};

/// Chunked view over a generated `(A, B)` table pair: full-width rows
/// (`fields_a + fields_b`), B's labels, row ordinals as keys.
pub struct SyntheticSource {
    a: PartyAData,
    b: PartyBData,
    vocab: usize,
    row: u64,
}

impl SyntheticSource {
    pub fn new(a: PartyAData, b: PartyBData, vocab: usize) -> Self {
        assert_eq!(a.n, b.n, "party tables must be row-aligned");
        assert!(vocab > 0);
        SyntheticSource { a, b, vocab, row: 0 }
    }
}

impl DatasetSource for SyntheticSource {
    fn fields(&self) -> usize {
        self.a.fields + self.b.fields
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn next_chunk(&mut self, max_rows: usize) -> Result<Option<RowChunk>> {
        assert!(max_rows > 0, "chunk size must be positive");
        let start = self.row as usize;
        if start >= self.a.n {
            return Ok(None);
        }
        let end = (start + max_rows).min(self.a.n);
        let (fa, fb) = (self.a.fields, self.b.fields);
        let mut chunk = RowChunk {
            keys: Vec::with_capacity(end - start),
            labels: Vec::with_capacity(end - start),
            tokens: Vec::with_capacity((end - start) * (fa + fb)),
            fields: fa + fb,
            base: self.row,
        };
        for r in start..end {
            chunk.keys.push(r.to_string());
            chunk.labels.push(self.b.y[r]);
            chunk.tokens.extend_from_slice(&self.a.x[r * fa..(r + 1) * fa]);
            chunk.tokens.extend_from_slice(&self.b.x[r * fb..(r + 1) * fb]);
        }
        self.row = end as u64;
        Ok(Some(chunk))
    }

    fn rewind(&mut self) -> Result<()> {
        self.row = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use crate::data::SynthDataset;

    use super::*;

    #[test]
    fn chunks_tile_the_generated_tables() {
        let ds = SynthDataset::generate("avazu", 50, 130, 10, 0.0, 7)
            .unwrap();
        let (fa, fb) = (ds.train_a.fields, ds.train_b.fields);
        let mut src = SyntheticSource::new(
            ds.train_a.clone(), ds.train_b.clone(), 50);
        assert_eq!(src.fields(), fa + fb);
        let mut rows = 0usize;
        while let Some(c) = src.next_chunk(64).unwrap() {
            assert!(c.rows() <= 64, "chunk bound violated");
            assert_eq!(c.base as usize, rows);
            for r in 0..c.rows() {
                let g = rows + r;
                assert_eq!(c.keys[r], g.to_string());
                assert_eq!(c.labels[r], ds.train_b.y[g]);
                let row = &c.tokens[r * (fa + fb)..(r + 1) * (fa + fb)];
                assert_eq!(&row[..fa], &ds.train_a.x[g * fa..(g + 1) * fa]);
                assert_eq!(&row[fa..], &ds.train_b.x[g * fb..(g + 1) * fb]);
            }
            rows += c.rows();
        }
        assert_eq!(rows, 130);
        src.rewind().unwrap();
        assert_eq!(src.next_chunk(8).unwrap().unwrap().rows(), 8);
    }
}
