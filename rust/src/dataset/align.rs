//! Alignment plane: which rows are shared across parties, and where.
//!
//! Real VFL deployments run PSI first; only the intersection of the
//! parties' populations is trainable with exchanged statistics. The
//! [`AlignmentMap`] reproduces that split *deterministically from the
//! row key alone*: every party hashes each key with the shared session
//! seed and keeps it iff the hash fraction falls below the configured
//! overlap. Because membership is a pure function of `(seed, key)`,
//! K parties scanning vertical slices of the same table agree on the
//! aligned subset — and on the order of aligned rows, which is their
//! appearance order in the stream (the PSI-sorted-key convention) —
//! without exchanging a byte.
//!
//! `overlap = 1.0` is exact: every key is aligned and the aligned
//! ordering is the identity, which is what lets the fully-aligned
//! configuration stay byte-identical to the historical data path.

use crate::data::{PartyAData, PartyBData};

/// Stream salt for alignment hashing — disjoint from the batch
/// (0xba7c_4ed0), data (0xDA7A…), and kill (0xFA17) streams.
const ALIGN_STREAM: u64 = 0xa119_6e6d_a90f_5eed;

/// Deterministic membership test for the aligned (PSI-intersection)
/// sample set, parameterized by the shared seed and target overlap
/// fraction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AlignmentMap {
    seed: u64,
    overlap: f64,
}

impl AlignmentMap {
    /// `overlap` is the expected aligned fraction in `(0, 1]`.
    pub fn new(seed: u64, overlap: f64) -> Self {
        assert!(
            overlap > 0.0 && overlap <= 1.0,
            "overlap must be in (0, 1], got {overlap}"
        );
        AlignmentMap { seed, overlap }
    }

    pub fn overlap(&self) -> f64 {
        self.overlap
    }

    /// Is the row with this key in the aligned set?
    pub fn is_aligned(&self, key: &str) -> bool {
        if self.overlap >= 1.0 {
            return true; // exact, not a float comparison
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in (self.seed ^ ALIGN_STREAM).to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        for &b in key.as_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        // Map the hash to [0, 1) with 53 usable bits.
        let frac = (h >> 11) as f64 / (1u64 << 53) as f64;
        frac < self.overlap
    }

    /// Partition row offsets `0..keys.len()` into (aligned, unaligned),
    /// each in appearance order.
    pub fn split(&self, keys: &[String]) -> (Vec<u32>, Vec<u32>) {
        let mut aligned = Vec::new();
        let mut unaligned = Vec::new();
        for (i, key) in keys.iter().enumerate() {
            if self.is_aligned(key) {
                aligned.push(i as u32);
            } else {
                unaligned.push(i as u32);
            }
        }
        (aligned, unaligned)
    }
}

/// Split synthetic row ordinals `0..n` by the same hash the file path
/// uses (keys are the ordinals' decimal strings, matching
/// [`SyntheticSource`](super::SyntheticSource)).
pub fn split_synthetic(
    seed: u64,
    overlap: f64,
    n: usize,
) -> (Vec<u32>, Vec<u32>) {
    let map = AlignmentMap::new(seed, overlap);
    let keys: Vec<String> = (0..n).map(|i| i.to_string()).collect();
    map.split(&keys)
}

/// Materialize the selected rows of an A-side table, in order.
pub fn subset_a(data: &PartyAData, rows: &[u32]) -> PartyAData {
    let f = data.fields;
    let mut x = Vec::with_capacity(rows.len() * f);
    for &r in rows {
        let r = r as usize;
        x.extend_from_slice(&data.x[r * f..(r + 1) * f]);
    }
    PartyAData { fields: f, x, n: rows.len() }
}

/// Materialize the selected rows of the label-side table, in order.
pub fn subset_b(data: &PartyBData, rows: &[u32]) -> PartyBData {
    let f = data.fields;
    let mut x = Vec::with_capacity(rows.len() * f);
    let mut y = Vec::with_capacity(rows.len());
    for &r in rows {
        let r = r as usize;
        x.extend_from_slice(&data.x[r * f..(r + 1) * f]);
        y.push(data.y[r]);
    }
    PartyBData { fields: f, x, y, n: rows.len() }
}

#[cfg(test)]
mod tests {
    use crate::data::SynthDataset;
    use crate::testing::prop::check;
    use crate::{prop_assert, prop_assert_eq};

    use super::*;

    #[test]
    fn overlap_fraction_is_honored() {
        check("alignment-fraction", |rng| {
            let seed = rng.next_u64();
            // Overlaps in [0.1, 1.0] over a few thousand keys.
            let overlap = 0.1 + 0.9 * rng.next_f64();
            let n = 2000 + rng.gen_range(2000) as usize;
            let (aligned, unaligned) = split_synthetic(seed, overlap, n);
            prop_assert_eq!(aligned.len() + unaligned.len(), n);
            let got = aligned.len() as f64 / n as f64;
            // Binomial(n, p) concentrates: 5 sigma + slack.
            let tol = 5.0 * (overlap * (1.0 - overlap) / n as f64).sqrt()
                + 0.01;
            prop_assert!(
                (got - overlap).abs() <= tol,
                "overlap {overlap:.3} yielded fraction {got:.3} over {n}"
            );
            Ok(())
        });
    }

    #[test]
    fn parties_agree_under_the_shared_seed() {
        check("alignment-agreement", |rng| {
            let seed = rng.next_u64();
            let overlap = 0.05 + 0.95 * rng.next_f64();
            let map_a = AlignmentMap::new(seed, overlap);
            let map_b = AlignmentMap::new(seed, overlap);
            let keys: Vec<String> =
                (0..512).map(|_| format!("u{}", rng.next_u64())).collect();
            // Same keys, same seed → identical aligned offsets AND
            // identical aligned ordering (the shared index space).
            prop_assert_eq!(map_a.split(&keys), map_b.split(&keys));
            // A different seed must not systematically agree.
            let other = AlignmentMap::new(seed ^ 0x1, overlap);
            if overlap <= 0.9 {
                prop_assert!(
                    other.split(&keys).0 != map_a.split(&keys).0
                        || overlap < 0.051,
                    "independent seeds produced identical aligned sets"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn full_overlap_is_the_identity() {
        let (aligned, unaligned) = split_synthetic(42, 1.0, 1000);
        assert_eq!(aligned, (0..1000u32).collect::<Vec<_>>());
        assert!(unaligned.is_empty());
        // And exact for arbitrary keys, not just ordinals.
        let map = AlignmentMap::new(7, 1.0);
        assert!(map.is_aligned("anything-at-all"));
    }

    #[test]
    fn membership_is_independent_of_position() {
        let map = AlignmentMap::new(9, 0.4);
        let keys: Vec<String> = (0..64).map(|i| format!("k{i}")).collect();
        let (aligned, _) = map.split(&keys);
        let mut rev = keys.clone();
        rev.reverse();
        let (rev_aligned, _) = map.split(&rev);
        let mapped: Vec<u32> =
            rev_aligned.iter().rev().map(|&i| 63 - i).collect();
        assert_eq!(aligned, mapped);
    }

    #[test]
    fn subsets_gather_rows_in_order() {
        let ds = SynthDataset::generate("avazu", 50, 100, 10, 0.0, 3)
            .unwrap();
        let rows = vec![5u32, 17, 3];
        let a = subset_a(&ds.train_a, &rows);
        let b = subset_b(&ds.train_b, &rows);
        assert_eq!(a.n, 3);
        assert_eq!(b.n, 3);
        let f = ds.train_a.fields;
        assert_eq!(&a.x[f..2 * f], &ds.train_a.x[17 * f..18 * f]);
        assert_eq!(b.y, vec![ds.train_b.y[5], ds.train_b.y[17],
                             ds.train_b.y[3]]);
    }

    #[test]
    #[should_panic(expected = "overlap must be in (0, 1]")]
    fn zero_overlap_rejected() {
        AlignmentMap::new(1, 0.0);
    }
}
