//! Cross-party wire protocol: message types + binary codec.
//!
//! Exactly mirrors the paper's protocol surface: the only tensors that
//! ever cross the party boundary are forward activations `Z_A` and
//! backward derivatives `∇Z_A` (plus an eval lane reusing the activation
//! path and a control lane). No raw features, labels, or model weights
//! are representable on the wire — the privacy boundary is a type-system
//! property here, not a convention (see §4.2 of the paper).
//!
//! Frame layout (little-endian):
//!   [u32 frame_len][u8 tag][u64 round][u8 dtype][u8 ndim][u32 dim…][payload]
//! `frame_len` counts everything after itself. Tensor-less messages stop
//! after `round`.

use crate::tensor::{Data, DType, Tensor};

/// Protocol messages. `round` is the communication-round timestamp `i`
/// that keys the workset-table clocks on both sides.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// A → B: forward activations Z_A^(i) for train batch `round`.
    Activation { round: u64, tensor: Tensor },
    /// B → A: backward derivatives ∇Z_A^(i) for train batch `round`.
    Derivative { round: u64, tensor: Tensor },
    /// A → B: activations for held-out eval batch `round` (eval lane).
    EvalActivation { round: u64, tensor: Tensor },
    /// B → A: acknowledges eval batch `round` (keeps lanes in lock-step).
    EvalAck { round: u64 },
    /// Either direction: orderly end of training.
    Shutdown,
}

const TAG_ACT: u8 = 1;
const TAG_DER: u8 = 2;
const TAG_EVAL_ACT: u8 = 3;
const TAG_EVAL_ACK: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;

impl Message {
    pub fn tag(&self) -> u8 {
        match self {
            Message::Activation { .. } => TAG_ACT,
            Message::Derivative { .. } => TAG_DER,
            Message::EvalActivation { .. } => TAG_EVAL_ACT,
            Message::EvalAck { .. } => TAG_EVAL_ACK,
            Message::Shutdown => TAG_SHUTDOWN,
        }
    }

    pub fn tensor(&self) -> Option<&Tensor> {
        match self {
            Message::Activation { tensor, .. }
            | Message::Derivative { tensor, .. }
            | Message::EvalActivation { tensor, .. } => Some(tensor),
            _ => None,
        }
    }

    pub fn round(&self) -> u64 {
        match self {
            Message::Activation { round, .. }
            | Message::Derivative { round, .. }
            | Message::EvalActivation { round, .. }
            | Message::EvalAck { round } => *round,
            Message::Shutdown => 0,
        }
    }

    /// Payload bytes the WAN simulator charges bandwidth for (tensor data
    /// + header + length framing), computed arithmetically — encoding a
    /// multi-MiB tensor just to measure it would double the send cost
    /// (§Perf in EXPERIMENTS.md).
    pub fn wire_bytes(&self) -> usize {
        let body = 1 + 8
            + self
                .tensor()
                .map(|t| 2 + 4 * t.shape.len() + t.size_bytes())
                .unwrap_or(0);
        body + 4
    }

    // -- codec -------------------------------------------------------------

    /// Encode the frame body (without the leading length word).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.push(self.tag());
        out.extend_from_slice(&self.round().to_le_bytes());
        if let Some(t) = self.tensor() {
            out.push(t.dtype().code());
            out.push(t.shape.len() as u8);
            for &d in &t.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            match &t.data {
                Data::F32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Data::I32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        out
    }

    /// Decode one frame body.
    pub fn decode(buf: &[u8]) -> anyhow::Result<Message> {
        let mut r = Reader { buf, pos: 0 };
        let tag = r.u8()?;
        let round = r.u64()?;
        let msg = match tag {
            TAG_SHUTDOWN => Message::Shutdown,
            TAG_EVAL_ACK => Message::EvalAck { round },
            TAG_ACT | TAG_DER | TAG_EVAL_ACT => {
                let dtype = DType::from_code(r.u8()?)?;
                let ndim = r.u8()? as usize;
                let mut shape = Vec::with_capacity(ndim);
                for _ in 0..ndim {
                    shape.push(r.u32()? as usize);
                }
                // Validate the element count against the frame length
                // BEFORE allocating — a corrupt/hostile header must not
                // drive a huge allocation (checked by the fuzz property).
                let n: usize = shape
                    .iter()
                    .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                    .ok_or_else(|| anyhow::anyhow!("shape overflow"))?;
                let remaining = buf.len() - r.pos;
                if n.checked_mul(4) != Some(remaining) {
                    anyhow::bail!(
                        "frame payload mismatch: shape wants {n} elements, \
                         {remaining} bytes left"
                    );
                }
                let tensor = match dtype {
                    DType::F32 => {
                        let mut v = Vec::with_capacity(n);
                        for _ in 0..n {
                            v.push(f32::from_le_bytes(r.bytes4()?));
                        }
                        Tensor::f32(shape, v)
                    }
                    DType::I32 => {
                        let mut v = Vec::with_capacity(n);
                        for _ in 0..n {
                            v.push(i32::from_le_bytes(r.bytes4()?));
                        }
                        Tensor::i32(shape, v)
                    }
                };
                match tag {
                    TAG_ACT => Message::Activation { round, tensor },
                    TAG_DER => Message::Derivative { round, tensor },
                    _ => Message::EvalActivation { round, tensor },
                }
            }
            _ => anyhow::bail!("unknown message tag {tag}"),
        };
        if r.pos != buf.len() {
            anyhow::bail!("trailing bytes in frame ({} of {})", r.pos,
                          buf.len());
        }
        Ok(msg)
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            anyhow::bail!("truncated frame");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn bytes4(&mut self) -> anyhow::Result<[u8; 4]> {
        Ok(self.take(4)?.try_into().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_tensor() -> Tensor {
        Tensor::f32(vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, f32::MIN,
                                     f32::MAX])
    }

    #[test]
    fn roundtrip_all_variants() {
        let msgs = vec![
            Message::Activation { round: 7, tensor: sample_tensor() },
            Message::Derivative { round: u64::MAX, tensor: sample_tensor() },
            Message::EvalActivation {
                round: 0,
                tensor: Tensor::i32(vec![4], vec![1, -1, 0, i32::MAX]),
            },
            Message::EvalAck { round: 3 },
            Message::Shutdown,
        ];
        for m in msgs {
            let enc = m.encode();
            let dec = Message::decode(&enc).unwrap();
            assert_eq!(dec, m);
        }
    }

    #[test]
    fn rejects_corruption() {
        let enc = Message::Activation { round: 1, tensor: sample_tensor() }
            .encode();
        assert!(Message::decode(&enc[..enc.len() - 1]).is_err());
        let mut bad_tag = enc.clone();
        bad_tag[0] = 99;
        assert!(Message::decode(&bad_tag).is_err());
        let mut trailing = enc;
        trailing.push(0);
        assert!(Message::decode(&trailing).is_err());
    }

    #[test]
    fn wire_bytes_matches_encoded_length_exactly() {
        for m in [
            Message::Activation { round: 3, tensor: sample_tensor() },
            Message::EvalAck { round: 1 },
            Message::Shutdown,
            Message::Derivative {
                round: 2,
                tensor: Tensor::i32(vec![3, 2, 1], vec![1, 2, 3, 4, 5, 6]),
            },
        ] {
            assert_eq!(m.wire_bytes(), m.encode().len() + 4, "{:?}", m.tag());
        }
    }

    #[test]
    fn wire_bytes_tracks_payload() {
        let small = Message::EvalAck { round: 1 }.wire_bytes();
        let big = Message::Activation {
            round: 1,
            tensor: Tensor::zeros_f32(vec![256, 64]),
        }
        .wire_bytes();
        assert!(small < 32);
        assert!(big > 256 * 64 * 4);
        assert!(big < 256 * 64 * 4 + 64);
    }

    #[test]
    fn privacy_surface_is_closed() {
        // Compile-time property documented as a test: the message enum
        // has exactly the five variants above — adding a raw-feature or
        // weight-transfer lane would have to extend this match, which is
        // the review point for the §4.2 security argument.
        let m = Message::Shutdown;
        match m {
            Message::Activation { .. } | Message::Derivative { .. }
            | Message::EvalActivation { .. } | Message::EvalAck { .. }
            | Message::Shutdown => {}
        }
    }
}

#[cfg(test)]
mod fuzz_tests {
    use super::*;
    use crate::testing::prop;
    use crate::prop_assert;

    #[test]
    fn prop_decode_never_panics_on_garbage() {
        // Any byte string must produce Ok or Err — never a panic/abort.
        prop::check("decode total on garbage", |rng| {
            let len = rng.gen_range(64) as usize;
            let bytes: Vec<u8> =
                (0..len).map(|_| rng.next_u32() as u8).collect();
            let _ = Message::decode(&bytes);
            Ok(())
        });
    }

    #[test]
    fn prop_truncated_frames_error_not_panic() {
        prop::check("truncations error", |rng| {
            let rows = 1 + rng.gen_range(8) as usize;
            let cols = 1 + rng.gen_range(8) as usize;
            let t = Tensor::f32(vec![rows, cols], vec![1.0; rows * cols]);
            let enc = Message::Activation { round: 3, tensor: t }.encode();
            let cut = rng.gen_range(enc.len() as u32) as usize;
            if cut < enc.len() {
                prop_assert!(Message::decode(&enc[..cut]).is_err(),
                             "truncation at {cut} decoded");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_roundtrip_random_tensors() {
        prop::check("roundtrip random tensors", |rng| {
            let rows = 1 + rng.gen_range(16) as usize;
            let cols = 1 + rng.gen_range(16) as usize;
            let n = rows * cols;
            let msg = if rng.next_f32() < 0.5 {
                let v: Vec<f32> =
                    (0..n).map(|_| rng.next_normal()).collect();
                Message::Activation {
                    round: rng.next_u64(),
                    tensor: Tensor::f32(vec![rows, cols], v),
                }
            } else {
                let v: Vec<i32> =
                    (0..n).map(|_| rng.next_u32() as i32).collect();
                Message::EvalActivation {
                    round: rng.next_u64(),
                    tensor: Tensor::i32(vec![rows, cols], v),
                }
            };
            let dec = Message::decode(&msg.encode())
                .map_err(|e| format!("decode failed: {e}"))?;
            prop_assert!(dec == msg, "roundtrip mismatch");
            Ok(())
        });
    }
}
