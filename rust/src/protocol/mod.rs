//! Cross-party wire protocol: message types + binary codec.
//!
//! Exactly mirrors the paper's protocol surface: the only tensors that
//! ever cross the party boundary are forward activations `Z_A` and
//! backward derivatives `∇Z_A` (plus an eval lane reusing the activation
//! path and a control lane). No raw features, labels, or model weights
//! are representable on the wire — the privacy boundary is a type-system
//! property here, not a convention (see §4.2 of the paper).
//!
//! Frame layout (little-endian):
//!   `[u32 frame_len][u8 tag][u64 round][u8 dtype][u8 ndim][u32 dim…][payload]`
//! `frame_len` counts everything after itself. Tensor-less messages stop
//! after `round`.
//!
//! Two frame kinds extend the original five (DESIGN.md §5), leaving the
//! original byte streams untouched:
//!   `[… tag=6][u64 0][u32 codec_mask]` — `Hello`
//!   `[… tag=7][u64 round][u8 lane][codec block]` — `Compressed`
//! where the codec block is
//!   `[u8 codec][u32 param][u8 ndim][u32 dim…][u32 extra_len][extra][payload]`
//! (`compress::CompressedStats`). `Hello` advertises the codecs a peer
//! can decode; `outbound_stats` / `into_plain` apply the negotiated
//! codec at this boundary so the rest of the stack only sees plain
//! statistics tensors — peers that never send `Hello` are spoken to in
//! the original uncompressed format.
//!
//! Session bootstrap (DESIGN.md §7) adds two fixed-size control frames:
//!   `[… tag=9][u64 0][u8 ver][u16 party][u16 parties][u32 codecs]` — `Join`
//!   `[… tag=10][u64 0][u8 ver][u16 party][u16 parties][u32 codecs]` — `JoinAck`
//! `Join` is the first frame a dialing feature party puts on a fresh
//! socket: it claims a `PartyId`, states the session size it was
//! configured for, and advertises its decodable codec families (the
//! `Hello` bitmask). The listener answers `JoinAck` (echoing the
//! accepted id) or drops the connection. Both frames carry their own
//! version byte and are validated — version, then id ranges — before
//! the `Message` is constructed; the bodies are fixed-size, so a
//! hostile header can never drive an allocation. Training traffic never
//! carries these tags: they exist only on pre-session sockets.
//!
//! The supervised session lifecycle (DESIGN.md §8) adds two more
//! fixed-size control frames for mid-session re-admission:
//!   `[… tag=11][u64 0][u8 ver][u16 party][u16 parties][u32 epoch]`
//!   `[u64 last_round][u32 codecs]` — `Rejoin`
//!   `[… tag=12][u64 0][u8 ver][u16 party][u16 parties][u32 epoch]`
//!   `[u64 resume_round][u32 replays]` — `RejoinAck`
//! A feature party that lost its link re-dials the label party's
//! listener and opens with `Rejoin`: the party id it held, the session
//! epoch (so a stray dialer from another logical session is refused),
//! and the number of communication rounds it completed before the
//! drop. The label party answers `RejoinAck` with the round the lane
//! resumes at and how many buffered derivative frames it will replay
//! on the fresh transport (0 or 1 under the lock-step protocol —
//! exactly the in-flight round, when it is still in the bounded resend
//! buffer). Like `Join`, both frames carry their own version byte and
//! are validated — version, then id ranges — before the `Message` is
//! constructed, and they only ever travel on pre-transport sockets.
//!
//! Symmetric fault tolerance (DESIGN.md §9) adds one fixed-size refusal
//! frame the listener can put on a bootstrap socket *before* dropping it:
//!   `[… tag=13][u64 0][u8 ver][u16 party][u8 reason][u64 round]` —
//!   `RejoinReject`
//! Without it, a dialer racing the listener's resume-mode epoch check
//! sees a bare EOF and can only retry blindly; with it, the dialer logs
//! the actual refusal ("epoch mismatch (snapshot is round R)" or "this
//! session resumed from a checkpoint — Rejoin required"). The reject is
//! sent only for *resume-mode* refusals: hostile or malformed bootstrap
//! frames still see a silent drop, so a probing stranger learns nothing.
//!
//! K-party sessions (DESIGN.md §6) frame every link with a **versioned
//! header** carrying the endpoints' party ids:
//!   `[u32 frame_len][u8 tag=8][u8 ver=2][u16 src][u16 dst][v1 body…]`
//! The envelope tag 8 cannot collide with a v1 message tag (1..=7), so
//! [`decode_frame`] dispatches on the first byte: headerless frames
//! decode exactly as before (the compat path that keeps the two-party
//! golden fixtures byte-identical), and v2 frames have their ids
//! range-checked against [`crate::session::MAX_PARTIES`] *before* the
//! tensor body — and therefore before any payload-sized allocation —
//! is touched. Two-party sessions never emit the header; it appears on the
//! wire only when a session spans more than two parties.
//!
//! The codec is zero-copy-oriented (DESIGN.md §4): encoding reserves the
//! exact frame size once and bulk-copies the payload as a single memcpy on
//! little-endian targets (with a per-element fallback elsewhere — the wire
//! format is little-endian regardless of host order); decoding bulk-reads
//! into a fresh shared buffer. `encode_into` lets transports reuse one
//! scratch buffer across sends so the steady-state send path performs no
//! allocation at all. The golden-bytes fixtures below pin the on-wire
//! format to the original element-wise codec byte-for-byte.

use crate::compress::{self, CodecKind, CompressedStats};
use crate::session::{PartyId, MAX_PARTIES};
use crate::tensor::{Data, DType, Tensor};

/// Protocol messages. `round` is the communication-round timestamp `i`
/// that keys the workset-table clocks on both sides.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// A → B: forward activations Z_A^(i) for train batch `round`.
    Activation { round: u64, tensor: Tensor },
    /// B → A: backward derivatives ∇Z_A^(i) for train batch `round`.
    Derivative { round: u64, tensor: Tensor },
    /// A → B: activations for held-out eval batch `round` (eval lane).
    EvalActivation { round: u64, tensor: Tensor },
    /// B → A: acknowledges eval batch `round` (keeps lanes in lock-step).
    EvalAck { round: u64 },
    /// Either direction: orderly end of training.
    Shutdown,
    /// Capabilities handshake: the codec families this peer can decode
    /// (bit per `CodecKind::code`). Sent before round 0 when a party
    /// wants compression; never sent otherwise, so pre-compression
    /// peers observe the original byte stream.
    Hello { codecs: u32 },
    /// One statistics tensor in compressed form on `lane`. Decompressed
    /// at the protocol boundary via [`Message::into_plain`].
    Compressed { round: u64, lane: Lane, stats: CompressedStats },
    /// Bootstrap, feature → label: claim `party` in a `parties`-party
    /// session and advertise the codec families this peer can decode
    /// (the `Hello` bitmask). Sent exactly once, as the first frame on
    /// a freshly-dialed socket — never during training.
    Join { party: PartyId, parties: u16, codecs: u32 },
    /// Bootstrap, label → feature: accept the claim. Echoes the
    /// accepted id and the session size so a misconfigured dialer
    /// fails at bootstrap, not mid-round.
    JoinAck { party: PartyId, parties: u16, codecs: u32 },
    /// Re-admission, feature → label: a party that lost its link
    /// re-dials and asks back into a *running* session. `epoch`
    /// identifies the logical session (a dialer from another run is
    /// refused before any lane state is touched); `last_round` is how
    /// many communication rounds this party completed before the drop.
    /// Sent exactly once, as the first frame on a freshly-dialed
    /// socket — never during training.
    Rejoin { party: PartyId, parties: u16, epoch: u32,
             last_round: u64, codecs: u32 },
    /// Re-admission, label → feature: accept the returning party.
    /// `resume_round` is the round the lane re-enters lock-step at
    /// (the feature party fast-forwards its batch cursor there);
    /// `replays` is the number of buffered derivative frames the label
    /// will replay on the fresh transport before normal traffic.
    RejoinAck { party: PartyId, parties: u16, epoch: u32,
                resume_round: u64, replays: u32 },
    /// Bootstrap refusal, label → feature: the listener is dropping
    /// this dialer's socket and says why first. `reason` is the refusal
    /// class; `round` is the round the listener's checkpoint resumes at
    /// (so an epoch-mismatch log can name the snapshot it raced). Sent
    /// only for resume-mode refusals — never for hostile frames, which
    /// are still dropped silently.
    RejoinReject { party: PartyId, reason: RejectReason, round: u64 },
    /// Observability, label → watcher: the push exporter's periodic
    /// snapshot of every link's *cumulative* counters as of `round`
    /// (DESIGN.md §10). Totals, not deltas: a watcher that misses a
    /// tick loses nothing, and the stream's final frame is exactly the
    /// `RunRecord` link rows. Carries only aggregate accounting — no
    /// statistics tensors — so it cannot widen the privacy surface.
    Metrics { round: u64, links: Vec<LinkMetricsRow> },
}

/// One directed link's cumulative counters inside a [`Message::Metrics`]
/// frame: 36 bytes on the wire —
/// `[u16 src][u16 dst][u64 msgs][u64 wire][u64 raw][u64 busy_ns]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkMetricsRow {
    pub src: PartyId,
    pub dst: PartyId,
    pub messages: u64,
    pub wire_bytes: u64,
    pub raw_bytes: u64,
    pub busy_nanos: u64,
}

/// Why a resume-mode listener refused a bootstrap frame. Closed set,
/// carried as one byte on the wire — no free-form text crosses the
/// party boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The dialer's `Rejoin` echoed a session epoch that is not the
    /// epoch of the checkpoint this listener resumed from.
    EpochMismatch,
    /// The dialer sent a fresh `Join`, but this session is resuming
    /// from a checkpoint: only `Rejoin` is admissible.
    NeedRejoin,
}

impl RejectReason {
    fn code(self) -> u8 {
        match self {
            RejectReason::EpochMismatch => 1,
            RejectReason::NeedRejoin => 2,
        }
    }

    fn from_code(c: u8) -> anyhow::Result<RejectReason> {
        match c {
            1 => Ok(RejectReason::EpochMismatch),
            2 => Ok(RejectReason::NeedRejoin),
            _ => anyhow::bail!("invalid reject reason code {c}"),
        }
    }
}

/// Which statistics lane a compressed frame travels on. Exactly the
/// three tensor-bearing messages — compression cannot widen the privacy
/// surface (§4.2), it can only re-encode what was already representable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    Activation,
    Derivative,
    EvalActivation,
}

impl Lane {
    fn tag(self) -> u8 {
        match self {
            Lane::Activation => TAG_ACT,
            Lane::Derivative => TAG_DER,
            Lane::EvalActivation => TAG_EVAL_ACT,
        }
    }

    fn from_tag(t: u8) -> anyhow::Result<Lane> {
        match t {
            TAG_ACT => Ok(Lane::Activation),
            TAG_DER => Ok(Lane::Derivative),
            TAG_EVAL_ACT => Ok(Lane::EvalActivation),
            _ => anyhow::bail!("invalid compressed lane tag {t}"),
        }
    }
}

const TAG_ACT: u8 = 1;
const TAG_DER: u8 = 2;
const TAG_EVAL_ACT: u8 = 3;
const TAG_EVAL_ACK: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_HELLO: u8 = 6;
const TAG_COMP: u8 = 7;
/// Envelope tag for v2 (party-addressed) frames. Disjoint from every
/// v1 message tag so the decoder can dispatch on the first byte.
const TAG_V2: u8 = 8;
const TAG_JOIN: u8 = 9;
const TAG_JOIN_ACK: u8 = 10;
const TAG_REJOIN: u8 = 11;
const TAG_REJOIN_ACK: u8 = 12;
const TAG_REJOIN_REJECT: u8 = 13;
const TAG_METRICS: u8 = 14;
/// Current addressed-frame version.
const FRAME_VERSION: u8 = 2;
/// Current bootstrap (`Join`/`JoinAck`) frame version. Carried in the
/// body so the handshake can evolve independently of both the v1
/// message set and the v2 envelope.
pub const JOIN_VERSION: u8 = 1;
/// Current re-admission (`Rejoin`/`RejoinAck`) frame version. Versioned
/// separately from `Join` so the re-admission handshake can evolve
/// without disturbing the frozen bootstrap fixtures.
pub const REJOIN_VERSION: u8 = 1;
/// Current bootstrap-refusal (`RejoinReject`) frame version. Versioned
/// separately so the refusal vocabulary can grow without disturbing
/// either frozen handshake layout.
pub const REJECT_VERSION: u8 = 1;
/// Current metrics-stream (`Metrics`) frame version. Versioned
/// separately so the observability row layout can grow (histograms,
/// codec error) without disturbing any handshake or statistics frame.
pub const METRICS_VERSION: u8 = 1;
/// Cap on rows per `Metrics` frame, validated before any row is read:
/// a star mesh has at most `MAX_PARTIES - 1` links per direction, so
/// twice the party cap bounds every legitimate frame with slack.
pub const MAX_METRICS_ROWS: usize = 2 * MAX_PARTIES as usize;
/// Encoded size of one [`LinkMetricsRow`].
const METRICS_ROW_BYTES: usize = 2 + 2 + 8 + 8 + 8 + 8;

/// Bytes the v2 envelope adds in front of a v1 body:
/// `[u8 tag][u8 ver][u16 src][u16 dst]`.
pub const FRAME_V2_OVERHEAD: usize = 6;

/// Source/destination addressing of a v2 frame. Each mesh link is
/// point-to-point, so the header is identity *verification* rather than
/// routing: wire transports (`TcpTransport::with_identity`) reject
/// frames whose ids don't match the link's endpoints, so a miswired or
/// confused peer fails loudly at the first frame instead of corrupting
/// the round clock. (In-proc links are coupled at construction and
/// only charge the envelope to the byte accounting.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    pub src: PartyId,
    pub dst: PartyId,
}

impl FrameHeader {
    /// The header the peer is expected to stamp on its own frames.
    pub fn reply(self) -> FrameHeader {
        FrameHeader { src: self.dst, dst: self.src }
    }

    fn encode_into(self, out: &mut Vec<u8>) {
        out.push(TAG_V2);
        out.push(FRAME_VERSION);
        out.extend_from_slice(&self.src.0.to_le_bytes());
        out.extend_from_slice(&self.dst.0.to_le_bytes());
    }
}

/// Encode one frame body — v1 when `header` is `None` (byte-identical
/// to [`Message::encode`]), v2 envelope + v1 body otherwise.
pub fn encode_frame(header: Option<FrameHeader>, msg: &Message) -> Vec<u8> {
    let extra = if header.is_some() { FRAME_V2_OVERHEAD } else { 0 };
    let mut out = Vec::with_capacity(msg.wire_bytes() - 4 + extra);
    if let Some(h) = header {
        h.encode_into(&mut out);
    }
    msg.encode_body(&mut out);
    out
}

/// Encode the complete frame — length word, optional v2 envelope, body
/// — into a reusable scratch buffer (the transport send path; see
/// [`Message::encode_into`]).
pub fn encode_frame_into(header: Option<FrameHeader>, msg: &Message,
                         out: &mut Vec<u8>) {
    let extra = if header.is_some() { FRAME_V2_OVERHEAD } else { 0 };
    out.clear();
    out.reserve(msg.wire_bytes() + extra);
    let body_len = (msg.wire_bytes() - 4 + extra) as u32;
    out.extend_from_slice(&body_len.to_le_bytes());
    if let Some(h) = header {
        h.encode_into(out);
    }
    msg.encode_body(out);
}

/// Decode one frame body of either version. v1 frames (any first byte
/// other than the envelope tag) take the original decode path and
/// return no header — the compat path that keeps pre-session peers and
/// the PR-2 golden fixtures working. v2 frames have their version and
/// party ids validated *before* the body is parsed, so an out-of-range
/// id is rejected without any payload-sized allocation (the same
/// hostile-header discipline as the shape/length checks).
pub fn decode_frame(buf: &[u8])
                    -> anyhow::Result<(Option<FrameHeader>, Message)> {
    if buf.first() != Some(&TAG_V2) {
        return Ok((None, Message::decode(buf)?));
    }
    if buf.len() < FRAME_V2_OVERHEAD {
        anyhow::bail!("truncated v2 frame header ({} bytes)", buf.len());
    }
    let version = buf[1];
    if version != FRAME_VERSION {
        anyhow::bail!("unsupported frame version {version} \
                       (this build speaks {FRAME_VERSION})");
    }
    let src = u16::from_le_bytes([buf[2], buf[3]]);
    let dst = u16::from_le_bytes([buf[4], buf[5]]);
    if src >= MAX_PARTIES || dst >= MAX_PARTIES {
        anyhow::bail!(
            "party id out of range in frame header: src {src}, dst {dst} \
             (max {MAX_PARTIES})"
        );
    }
    if src == dst {
        anyhow::bail!("frame addressed to its own source (party {src})");
    }
    let msg = Message::decode(&buf[FRAME_V2_OVERHEAD..])?;
    Ok((Some(FrameHeader { src: PartyId(src), dst: PartyId(dst) }), msg))
}

impl Message {
    pub fn tag(&self) -> u8 {
        match self {
            Message::Activation { .. } => TAG_ACT,
            Message::Derivative { .. } => TAG_DER,
            Message::EvalActivation { .. } => TAG_EVAL_ACT,
            Message::EvalAck { .. } => TAG_EVAL_ACK,
            Message::Shutdown => TAG_SHUTDOWN,
            Message::Hello { .. } => TAG_HELLO,
            Message::Compressed { .. } => TAG_COMP,
            Message::Join { .. } => TAG_JOIN,
            Message::JoinAck { .. } => TAG_JOIN_ACK,
            Message::Rejoin { .. } => TAG_REJOIN,
            Message::RejoinAck { .. } => TAG_REJOIN_ACK,
            Message::RejoinReject { .. } => TAG_REJOIN_REJECT,
            Message::Metrics { .. } => TAG_METRICS,
        }
    }

    pub fn tensor(&self) -> Option<&Tensor> {
        match self {
            Message::Activation { tensor, .. }
            | Message::Derivative { tensor, .. }
            | Message::EvalActivation { tensor, .. } => Some(tensor),
            _ => None,
        }
    }

    pub fn round(&self) -> u64 {
        match self {
            Message::Activation { round, .. }
            | Message::Derivative { round, .. }
            | Message::EvalActivation { round, .. }
            | Message::EvalAck { round }
            | Message::Compressed { round, .. }
            | Message::Metrics { round, .. } => *round,
            Message::Shutdown
            | Message::Hello { .. }
            | Message::Join { .. }
            | Message::JoinAck { .. }
            | Message::Rejoin { .. }
            | Message::RejoinAck { .. }
            | Message::RejoinReject { .. } => 0,
        }
    }

    /// Payload bytes the WAN simulator charges bandwidth for (tensor data
    /// + header + length framing), computed arithmetically — encoding a
    /// multi-MiB tensor just to measure it would double the send cost
    /// (§Perf in EXPERIMENTS.md).
    pub fn wire_bytes(&self) -> usize {
        let body = 1 + 8
            + match self {
                Message::Hello { .. } => 4,
                // ver + party + parties + codecs.
                Message::Join { .. } | Message::JoinAck { .. } => {
                    1 + 2 + 2 + 4
                }
                // ver + party + parties + epoch + round word + trailer.
                Message::Rejoin { .. } | Message::RejoinAck { .. } => {
                    1 + 2 + 2 + 4 + 8 + 4
                }
                // ver + party + reason + round.
                Message::RejoinReject { .. } => 1 + 2 + 1 + 8,
                // ver + row count + fixed-size rows.
                Message::Metrics { links, .. } => {
                    1 + 1 + METRICS_ROW_BYTES * links.len()
                }
                Message::Compressed { stats, .. } => {
                    1 + stats.wire_block_bytes()
                }
                _ => self
                    .tensor()
                    .map(|t| 2 + 4 * t.shape.len() + t.size_bytes())
                    .unwrap_or(0),
            };
        body + 4
    }

    /// Bytes the message would occupy uncompressed — the plain-frame
    /// size of the statistics a `Compressed` frame carries, and exactly
    /// `wire_bytes` for everything else. `LinkStats` accumulates both
    /// so transports can report their compression ratio.
    pub fn raw_bytes(&self) -> usize {
        match self {
            Message::Compressed { stats, .. } => {
                4 + 1 + 8 + 2 + 4 * stats.shape.len() + 4 * stats.numel()
            }
            _ => self.wire_bytes(),
        }
    }

    /// Resolve a `Compressed` frame into its plain equivalent by
    /// dequantizing the payload; every other message passes through.
    /// Receivers call this on each frame, so past this boundary the
    /// stack only ever sees plain statistics tensors.
    pub fn into_plain(self) -> anyhow::Result<Message> {
        match self {
            Message::Compressed { round, lane, stats } => {
                let tensor = compress::decompress_stats(&stats)?;
                Ok(match lane {
                    Lane::Activation => {
                        Message::Activation { round, tensor }
                    }
                    Lane::Derivative => {
                        Message::Derivative { round, tensor }
                    }
                    Lane::EvalActivation => {
                        Message::EvalActivation { round, tensor }
                    }
                })
            }
            m => Ok(m),
        }
    }

    // -- codec -------------------------------------------------------------

    /// Append the frame body (without the leading length word) to `out`.
    fn encode_body(&self, out: &mut Vec<u8>) {
        out.push(self.tag());
        out.extend_from_slice(&self.round().to_le_bytes());
        if let Some(t) = self.tensor() {
            out.push(t.dtype().code());
            out.push(t.shape.len() as u8);
            for &d in &t.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            match &t.data {
                Data::F32(v) => write_f32s_le(out, v),
                Data::I32(v) => write_i32s_le(out, v),
            }
        }
        match self {
            Message::Hello { codecs } => {
                out.extend_from_slice(&codecs.to_le_bytes());
            }
            Message::Join { party, parties, codecs }
            | Message::JoinAck { party, parties, codecs } => {
                out.push(JOIN_VERSION);
                out.extend_from_slice(&party.0.to_le_bytes());
                out.extend_from_slice(&parties.to_le_bytes());
                out.extend_from_slice(&codecs.to_le_bytes());
            }
            Message::Rejoin { party, parties, epoch, last_round, codecs } => {
                out.push(REJOIN_VERSION);
                out.extend_from_slice(&party.0.to_le_bytes());
                out.extend_from_slice(&parties.to_le_bytes());
                out.extend_from_slice(&epoch.to_le_bytes());
                out.extend_from_slice(&last_round.to_le_bytes());
                out.extend_from_slice(&codecs.to_le_bytes());
            }
            Message::RejoinAck { party, parties, epoch, resume_round,
                                 replays } => {
                out.push(REJOIN_VERSION);
                out.extend_from_slice(&party.0.to_le_bytes());
                out.extend_from_slice(&parties.to_le_bytes());
                out.extend_from_slice(&epoch.to_le_bytes());
                out.extend_from_slice(&resume_round.to_le_bytes());
                out.extend_from_slice(&replays.to_le_bytes());
            }
            Message::RejoinReject { party, reason, round } => {
                out.push(REJECT_VERSION);
                out.extend_from_slice(&party.0.to_le_bytes());
                out.push(reason.code());
                out.extend_from_slice(&round.to_le_bytes());
            }
            Message::Metrics { links, .. } => {
                out.push(METRICS_VERSION);
                out.push(links.len() as u8);
                for row in links {
                    out.extend_from_slice(&row.src.0.to_le_bytes());
                    out.extend_from_slice(&row.dst.0.to_le_bytes());
                    out.extend_from_slice(&row.messages.to_le_bytes());
                    out.extend_from_slice(&row.wire_bytes.to_le_bytes());
                    out.extend_from_slice(&row.raw_bytes.to_le_bytes());
                    out.extend_from_slice(&row.busy_nanos.to_le_bytes());
                }
            }
            Message::Compressed { lane, stats, .. } => {
                out.push(lane.tag());
                out.push(stats.kind.code());
                out.extend_from_slice(&stats.kind.param().to_le_bytes());
                out.push(stats.shape.len() as u8);
                for &d in &stats.shape {
                    out.extend_from_slice(&(d as u32).to_le_bytes());
                }
                out.extend_from_slice(
                    &(stats.extra.len() as u32).to_le_bytes());
                out.extend_from_slice(&stats.extra);
                out.extend_from_slice(&stats.payload);
            }
            _ => {}
        }
    }

    /// Encode the frame body (without the leading length word). The
    /// buffer is sized exactly once up front; the payload goes in as one
    /// bulk copy.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes() - 4);
        self.encode_body(&mut out);
        out
    }

    /// Encode the complete frame — length word followed by the body —
    /// into `out`, clearing it first. Transports keep one scratch buffer
    /// and call this per send: after the first few messages the buffer
    /// reaches steady-state capacity and sends stop allocating.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.reserve(self.wire_bytes());
        let body_len = (self.wire_bytes() - 4) as u32;
        out.extend_from_slice(&body_len.to_le_bytes());
        self.encode_body(out);
    }

    /// Decode one frame body.
    pub fn decode(buf: &[u8]) -> anyhow::Result<Message> {
        let mut r = Reader { buf, pos: 0 };
        let tag = r.u8()?;
        let round = r.u64()?;
        let msg = match tag {
            TAG_SHUTDOWN => Message::Shutdown,
            TAG_EVAL_ACK => Message::EvalAck { round },
            TAG_HELLO => Message::Hello { codecs: r.u32()? },
            TAG_JOIN | TAG_JOIN_ACK => {
                // Version first, ids second, both validated before the
                // Message is constructed. The body is fixed-size, so no
                // allocation rides on these fields — but the range
                // discipline matches the tensor/compressed paths: a
                // hostile bootstrap frame dies on arithmetic alone.
                let ver = r.u8()?;
                if ver != JOIN_VERSION {
                    anyhow::bail!(
                        "unsupported join version {ver} (this build \
                         speaks {JOIN_VERSION})"
                    );
                }
                let party = r.u16()?;
                let parties = r.u16()?;
                let codecs = r.u32()?;
                if !(2..=MAX_PARTIES).contains(&parties) {
                    anyhow::bail!(
                        "join frame declares a {parties}-party session \
                         (valid: 2..={MAX_PARTIES})"
                    );
                }
                if party == 0 || party >= parties {
                    anyhow::bail!(
                        "join frame claims party id {party} in a \
                         {parties}-party session (valid feature ids: \
                         1..={})", parties - 1
                    );
                }
                let party = PartyId(party);
                if tag == TAG_JOIN {
                    Message::Join { party, parties, codecs }
                } else {
                    Message::JoinAck { party, parties, codecs }
                }
            }
            TAG_REJOIN | TAG_REJOIN_ACK => {
                // Same discipline as Join: version first, ids second,
                // both validated before the Message is constructed.
                // The body is fixed-size, so no allocation rides on any
                // of these fields.
                let ver = r.u8()?;
                if ver != REJOIN_VERSION {
                    anyhow::bail!(
                        "unsupported rejoin version {ver} (this build \
                         speaks {REJOIN_VERSION})"
                    );
                }
                let party = r.u16()?;
                let parties = r.u16()?;
                let epoch = r.u32()?;
                let round_word = r.u64()?;
                let trailer = r.u32()?;
                if !(2..=MAX_PARTIES).contains(&parties) {
                    anyhow::bail!(
                        "rejoin frame declares a {parties}-party session \
                         (valid: 2..={MAX_PARTIES})"
                    );
                }
                if party == 0 || party >= parties {
                    anyhow::bail!(
                        "rejoin frame claims party id {party} in a \
                         {parties}-party session (valid feature ids: \
                         1..={})", parties - 1
                    );
                }
                let party = PartyId(party);
                if tag == TAG_REJOIN {
                    Message::Rejoin {
                        party,
                        parties,
                        epoch,
                        last_round: round_word,
                        codecs: trailer,
                    }
                } else {
                    Message::RejoinAck {
                        party,
                        parties,
                        epoch,
                        resume_round: round_word,
                        replays: trailer,
                    }
                }
            }
            TAG_REJOIN_REJECT => {
                // Same discipline again: version first, then the party
                // id and reason code, all validated before the Message
                // is constructed. No `parties` field travels on a
                // reject, so the id is bounded by the session-size cap.
                let ver = r.u8()?;
                if ver != REJECT_VERSION {
                    anyhow::bail!(
                        "unsupported reject version {ver} (this build \
                         speaks {REJECT_VERSION})"
                    );
                }
                let party = r.u16()?;
                let reason = RejectReason::from_code(r.u8()?)?;
                let round = r.u64()?;
                if party == 0 || party >= MAX_PARTIES {
                    anyhow::bail!(
                        "reject frame names party id {party} (valid \
                         feature ids: 1..={})", MAX_PARTIES - 1
                    );
                }
                Message::RejoinReject {
                    party: PartyId(party),
                    reason,
                    round,
                }
            }
            TAG_METRICS => {
                // Same discipline as the handshake frames: version
                // first, then the row count and every row's party ids,
                // all validated before the Message is constructed. Rows
                // are fixed-size, so the only allocation is the Vec
                // whose length the cap below bounds.
                let ver = r.u8()?;
                if ver != METRICS_VERSION {
                    anyhow::bail!(
                        "unsupported metrics version {ver} (this build \
                         speaks {METRICS_VERSION})"
                    );
                }
                let n = r.u8()? as usize;
                if n > MAX_METRICS_ROWS {
                    anyhow::bail!(
                        "metrics frame declares {n} link rows \
                         (max {MAX_METRICS_ROWS})"
                    );
                }
                let remaining = buf.len() - r.pos;
                if remaining != n * METRICS_ROW_BYTES {
                    anyhow::bail!(
                        "metrics frame payload mismatch: {n} rows want \
                         {} bytes, {remaining} left",
                        n * METRICS_ROW_BYTES
                    );
                }
                let mut links = Vec::with_capacity(n);
                for _ in 0..n {
                    let src = r.u16()?;
                    let dst = r.u16()?;
                    if src >= MAX_PARTIES || dst >= MAX_PARTIES {
                        anyhow::bail!(
                            "metrics row names party id out of range: \
                             src {src}, dst {dst} (max {MAX_PARTIES})"
                        );
                    }
                    if src == dst {
                        anyhow::bail!(
                            "metrics row links party {src} to itself"
                        );
                    }
                    links.push(LinkMetricsRow {
                        src: PartyId(src),
                        dst: PartyId(dst),
                        messages: r.u64()?,
                        wire_bytes: r.u64()?,
                        raw_bytes: r.u64()?,
                        busy_nanos: r.u64()?,
                    });
                }
                Message::Metrics { round, links }
            }
            TAG_COMP => {
                let lane = Lane::from_tag(r.u8()?)?;
                let code = r.u8()?;
                let param = r.u32()?;
                let kind = CodecKind::from_wire(code, param)?;
                let ndim = r.u8()? as usize;
                let mut shape = Vec::with_capacity(ndim);
                for _ in 0..ndim {
                    shape.push(r.u32()? as usize);
                }
                // Expected lengths are derived (overflow-checked) from
                // the header BEFORE any payload-sized allocation — the
                // same hostile-header discipline as the plain path.
                let (extra_len, payload_len) =
                    compress::expected_lens(kind, &shape)?;
                let declared = r.u32()? as usize;
                if declared != extra_len {
                    anyhow::bail!(
                        "compressed frame declares {declared} extra \
                         bytes, codec wants {extra_len}"
                    );
                }
                let want = extra_len
                    .checked_add(payload_len)
                    .ok_or_else(|| anyhow::anyhow!("frame size overflow"))?;
                let remaining = buf.len() - r.pos;
                if remaining != want {
                    anyhow::bail!(
                        "compressed frame payload mismatch: {remaining} \
                         bytes left, codec wants {want}"
                    );
                }
                let extra = r.take(extra_len)?.to_vec();
                let payload = r.take(payload_len)?.to_vec();
                Message::Compressed {
                    round,
                    lane,
                    stats: CompressedStats { kind, shape, extra, payload },
                }
            }
            TAG_ACT | TAG_DER | TAG_EVAL_ACT => {
                let dtype = DType::from_code(r.u8()?)?;
                let ndim = r.u8()? as usize;
                let mut shape = Vec::with_capacity(ndim);
                for _ in 0..ndim {
                    shape.push(r.u32()? as usize);
                }
                // Validate the element count against the frame length
                // BEFORE allocating — a corrupt/hostile header must not
                // drive a huge allocation (checked by the fuzz property).
                let n: usize = shape
                    .iter()
                    .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                    .ok_or_else(|| anyhow::anyhow!("shape overflow"))?;
                let remaining = buf.len() - r.pos;
                if n.checked_mul(4) != Some(remaining) {
                    anyhow::bail!(
                        "frame payload mismatch: shape wants {n} elements, \
                         {remaining} bytes left"
                    );
                }
                let payload = r.take(remaining)?;
                let tensor = match dtype {
                    DType::F32 => Tensor::f32(shape, read_f32s_le(payload)),
                    DType::I32 => Tensor::i32(shape, read_i32s_le(payload)),
                };
                match tag {
                    TAG_ACT => Message::Activation { round, tensor },
                    TAG_DER => Message::Derivative { round, tensor },
                    _ => Message::EvalActivation { round, tensor },
                }
            }
            _ => anyhow::bail!("unknown message tag {tag}"),
        };
        if r.pos != buf.len() {
            anyhow::bail!("trailing bytes in frame ({} of {})", r.pos,
                          buf.len());
        }
        Ok(msg)
    }
}

/// Sender-side protocol boundary for the statistics lanes: build the
/// outgoing message for `tensor` under the *negotiated* `codec`, and
/// return the tensor the sender must keep using locally (workset cache,
/// exact math).
///
/// - `Identity` (or a non-f32/empty tensor) produces the original plain
///   frame and hands back the same `Arc` handle — the PR-1 zero-copy
///   path, byte-identical on the wire.
/// - Lossy codecs produce a `Compressed` frame and hand back the
///   *dequantized* round-trip, so the sender's cache matches what the
///   receiver decodes bit-for-bit and staleness weighting sees the same
///   statistics on both parties.
pub fn outbound_stats(codec: CodecKind, lane: Lane, round: u64,
                      tensor: Tensor)
                      -> anyhow::Result<(Message, Tensor)> {
    if !codec.is_lossy() || tensor.as_f32().is_err() || tensor.is_empty() {
        let msg = match lane {
            Lane::Activation => {
                Message::Activation { round, tensor: tensor.clone() }
            }
            Lane::Derivative => {
                Message::Derivative { round, tensor: tensor.clone() }
            }
            Lane::EvalActivation => {
                Message::EvalActivation { round, tensor: tensor.clone() }
            }
        };
        return Ok((msg, tensor));
    }
    let stats = compress::compress_tensor(codec, &tensor)?;
    let dequantized = compress::decompress_stats(&stats)?;
    Ok((Message::Compressed { round, lane, stats }, dequantized))
}

// -- bulk payload transcoding ----------------------------------------------
//
// The wire format is little-endian. On little-endian hosts the in-memory
// representation of f32/i32 slices is already the wire representation, so
// the payload moves as one memcpy; big-endian hosts fall back to the
// per-element path. f32 and i32 have no padding and every bit pattern is
// valid for them, so the raw-byte views below are sound.

#[cfg(target_endian = "little")]
fn write_f32s_le(out: &mut Vec<u8>, v: &[f32]) {
    // SAFETY: f32 is 4 bytes, no padding; the slice is valid for
    // v.len() * 4 bytes of reads.
    let bytes = unsafe {
        std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 4)
    };
    out.extend_from_slice(bytes);
}

#[cfg(not(target_endian = "little"))]
fn write_f32s_le(out: &mut Vec<u8>, v: &[f32]) {
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

#[cfg(target_endian = "little")]
fn write_i32s_le(out: &mut Vec<u8>, v: &[i32]) {
    // SAFETY: as write_f32s_le.
    let bytes = unsafe {
        std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 4)
    };
    out.extend_from_slice(bytes);
}

#[cfg(not(target_endian = "little"))]
fn write_i32s_le(out: &mut Vec<u8>, v: &[i32]) {
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

#[cfg(target_endian = "little")]
fn read_f32s_le(bytes: &[u8]) -> std::sync::Arc<[f32]> {
    debug_assert_eq!(bytes.len() % 4, 0);
    let n = bytes.len() / 4;
    // Build the shared buffer directly so the payload is copied exactly
    // once — no staging Vec, no second Vec→Arc move.
    let mut arc = std::sync::Arc::<[f32]>::new_uninit_slice(n);
    // SAFETY: the freshly-created Arc is unique (get_mut succeeds); the
    // single memcpy fully initializes all n * 4 bytes, and any bit
    // pattern is a valid f32; u8 pointees have no alignment requirement.
    unsafe {
        let dst = std::sync::Arc::get_mut(&mut arc).unwrap();
        std::ptr::copy_nonoverlapping(bytes.as_ptr(),
                                      dst.as_mut_ptr().cast::<u8>(),
                                      n * 4);
        arc.assume_init()
    }
}

#[cfg(not(target_endian = "little"))]
fn read_f32s_le(bytes: &[u8]) -> std::sync::Arc<[f32]> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect::<Vec<_>>()
        .into()
}

#[cfg(target_endian = "little")]
fn read_i32s_le(bytes: &[u8]) -> std::sync::Arc<[i32]> {
    debug_assert_eq!(bytes.len() % 4, 0);
    let n = bytes.len() / 4;
    let mut arc = std::sync::Arc::<[i32]>::new_uninit_slice(n);
    // SAFETY: as read_f32s_le.
    unsafe {
        let dst = std::sync::Arc::get_mut(&mut arc).unwrap();
        std::ptr::copy_nonoverlapping(bytes.as_ptr(),
                                      dst.as_mut_ptr().cast::<u8>(),
                                      n * 4);
        arc.assume_init()
    }
}

#[cfg(not(target_endian = "little"))]
fn read_i32s_le(bytes: &[u8]) -> std::sync::Arc<[i32]> {
    bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect::<Vec<_>>()
        .into()
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        // checked_add: a hostile header must not wrap `pos + n` around
        // usize::MAX and alias an in-bounds slice.
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| anyhow::anyhow!("frame offset overflow"))?;
        if end > self.buf.len() {
            anyhow::bail!("truncated frame");
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> anyhow::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_tensor() -> Tensor {
        Tensor::f32(vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, f32::MIN,
                                     f32::MAX])
    }

    #[test]
    fn roundtrip_all_variants() {
        let msgs = vec![
            Message::Activation { round: 7, tensor: sample_tensor() },
            Message::Derivative { round: u64::MAX, tensor: sample_tensor() },
            Message::EvalActivation {
                round: 0,
                tensor: Tensor::i32(vec![4], vec![1, -1, 0, i32::MAX]),
            },
            Message::EvalAck { round: 3 },
            Message::Shutdown,
        ];
        for m in msgs {
            let enc = m.encode();
            let dec = Message::decode(&enc).unwrap();
            assert_eq!(dec, m);
        }
    }

    #[test]
    fn encode_into_prefixes_length_and_reuses_buffer() {
        let m = Message::Activation { round: 5, tensor: sample_tensor() };
        let body = m.encode();
        let mut scratch = Vec::new();
        m.encode_into(&mut scratch);
        assert_eq!(scratch.len(), m.wire_bytes());
        assert_eq!(&scratch[..4],
                   &(body.len() as u32).to_le_bytes());
        assert_eq!(&scratch[4..], &body[..]);
        // Re-encoding a smaller message into the same buffer resets it.
        let cap = scratch.capacity();
        Message::Shutdown.encode_into(&mut scratch);
        assert_eq!(scratch.len(), Message::Shutdown.wire_bytes());
        assert!(scratch.capacity() >= cap, "scratch must be reusable");
        assert_eq!(&scratch[4..], &Message::Shutdown.encode()[..]);
    }

    #[test]
    fn rejects_corruption() {
        let enc = Message::Activation { round: 1, tensor: sample_tensor() }
            .encode();
        assert!(Message::decode(&enc[..enc.len() - 1]).is_err());
        let mut bad_tag = enc.clone();
        bad_tag[0] = 99;
        assert!(Message::decode(&bad_tag).is_err());
        let mut trailing = enc;
        trailing.push(0);
        assert!(Message::decode(&trailing).is_err());
    }

    #[test]
    fn wire_bytes_matches_encoded_length_exactly() {
        for m in [
            Message::Activation { round: 3, tensor: sample_tensor() },
            Message::EvalAck { round: 1 },
            Message::Shutdown,
            Message::Derivative {
                round: 2,
                tensor: Tensor::i32(vec![3, 2, 1], vec![1, 2, 3, 4, 5, 6]),
            },
        ] {
            assert_eq!(m.wire_bytes(), m.encode().len() + 4, "{:?}", m.tag());
        }
    }

    #[test]
    fn wire_bytes_tracks_payload() {
        let small = Message::EvalAck { round: 1 }.wire_bytes();
        let big = Message::Activation {
            round: 1,
            tensor: Tensor::zeros_f32(vec![256, 64]),
        }
        .wire_bytes();
        assert!(small < 32);
        assert!(big > 256 * 64 * 4);
        assert!(big < 256 * 64 * 4 + 64);
    }

    #[test]
    fn encode_does_not_copy_out_of_band() {
        // The encoded buffer is sized exactly — no growth reallocations.
        let m = Message::Activation {
            round: 1,
            tensor: Tensor::zeros_f32(vec![64, 32]),
        };
        let enc = m.encode();
        assert_eq!(enc.len(), m.wire_bytes() - 4);
        // No growth doubling: capacity was reserved once, up front.
        assert!(enc.capacity() < (m.wire_bytes() - 4) * 2,
                "encode reallocated: cap {}", enc.capacity());
    }

    #[test]
    fn privacy_surface_is_closed() {
        // Compile-time property documented as a test: the message enum
        // has exactly these variants — adding a raw-feature or
        // weight-transfer lane would have to extend this match, which is
        // the review point for the §4.2 security argument. `Compressed`
        // does not widen the surface: `Lane` is closed over the three
        // statistics lanes, and `Hello` carries only a codec bitmask.
        // `Join`/`JoinAck` carry only session topology (ids, size) and
        // the `Hello` codec bitmask — no statistics at all.
        // `Rejoin`/`RejoinAck` add only lifecycle scalars (epoch, round
        // counters, replay count) on top of the same topology fields.
        // `RejoinReject` carries a party id, a closed one-byte reason
        // code, and a round counter — no statistics, no free-form text.
        // `Metrics` carries only per-link aggregate counters (message/
        // byte/nanosecond totals) — observability without statistics.
        let m = Message::Shutdown;
        match m {
            Message::Activation { .. } | Message::Derivative { .. }
            | Message::EvalActivation { .. } | Message::EvalAck { .. }
            | Message::Shutdown | Message::Hello { .. }
            | Message::Join { .. } | Message::JoinAck { .. }
            | Message::Rejoin { .. } | Message::RejoinAck { .. }
            | Message::Metrics { .. } => {}
            Message::RejoinReject { reason, .. } => match reason {
                RejectReason::EpochMismatch | RejectReason::NeedRejoin => {}
            },
            Message::Compressed { lane, .. } => match lane {
                Lane::Activation | Lane::Derivative
                | Lane::EvalActivation => {}
            },
        }
    }

    #[test]
    fn roundtrip_hello_and_compressed_variants() {
        let tensor = sample_tensor();
        let mut msgs = vec![Message::Hello { codecs: 0b1011 }];
        for kind in [CodecKind::Fp16, CodecKind::QuantInt8,
                     CodecKind::TopK(3)] {
            let stats =
                crate::compress::compress_tensor(kind, &tensor).unwrap();
            msgs.push(Message::Compressed {
                round: 42,
                lane: Lane::Derivative,
                stats,
            });
        }
        for m in msgs {
            let dec = Message::decode(&m.encode()).unwrap();
            assert_eq!(dec, m);
            assert_eq!(m.wire_bytes(), m.encode().len() + 4);
        }
    }

    #[test]
    fn into_plain_dequantizes_compressed_frames() {
        let tensor = Tensor::f32(vec![1, 4], vec![0.0, 1.0, 2.0, 3.0]);
        let stats = crate::compress::compress_tensor(
            CodecKind::QuantInt8, &tensor).unwrap();
        let expect = crate::compress::decompress_stats(&stats).unwrap();
        let m = Message::Compressed {
            round: 5,
            lane: Lane::Activation,
            stats,
        };
        match m.into_plain().unwrap() {
            Message::Activation { round, tensor: t } => {
                assert_eq!(round, 5);
                assert_eq!(t, expect);
            }
            other => panic!("wrong lane: {:?}", other.tag()),
        }
        // Non-compressed messages pass through untouched.
        let plain = Message::EvalAck { round: 9 };
        assert_eq!(plain.clone().into_plain().unwrap(), plain);
    }

    #[test]
    fn outbound_stats_identity_shares_the_allocation() {
        let t = sample_tensor();
        let (msg, local) = outbound_stats(
            CodecKind::Identity, Lane::Activation, 3, t.clone()).unwrap();
        // Zero-copy: message and local handle alias the input buffer.
        assert!(local.shares_data(&t));
        match msg {
            Message::Activation { round, tensor } => {
                assert_eq!(round, 3);
                assert!(tensor.shares_data(&t));
            }
            other => panic!("wrong frame: {:?}", other.tag()),
        }
    }

    #[test]
    fn outbound_stats_lossy_returns_the_receiver_view() {
        let t = Tensor::f32(vec![2, 3],
                            vec![0.1, -2.0, 3.5, 0.0, 9.0, -0.25]);
        let (msg, local) = outbound_stats(
            CodecKind::Fp16, Lane::Derivative, 7, t.clone()).unwrap();
        let receiver = msg.into_plain().unwrap();
        match receiver {
            Message::Derivative { round, tensor } => {
                assert_eq!(round, 7);
                // Cache-consistency invariant: sender's local tensor ==
                // receiver's decoded tensor, bit for bit.
                assert_eq!(tensor, local);
            }
            other => panic!("wrong frame: {:?}", other.tag()),
        }
        // i32 tensors fall back to plain frames.
        let ids = Tensor::i32(vec![2], vec![4, 5]);
        let (msg, local) = outbound_stats(
            CodecKind::Fp16, Lane::Activation, 1, ids.clone()).unwrap();
        assert!(local.shares_data(&ids));
        assert_eq!(msg.tag(), 1);
    }

    #[test]
    fn compressed_frames_are_smaller_than_plain() {
        let t = Tensor::f32(vec![256, 64],
                            (0..256 * 64).map(|i| (i as f32).cos())
                                          .collect::<Vec<_>>());
        let plain = Message::Activation { round: 0, tensor: t.clone() };
        for kind in [CodecKind::Fp16, CodecKind::QuantInt8,
                     CodecKind::TopK(512)] {
            let (msg, _) =
                outbound_stats(kind, Lane::Activation, 0, t.clone())
                    .unwrap();
            assert!(msg.wire_bytes() < plain.wire_bytes(),
                    "{} frame not smaller", kind.label());
            // raw_bytes reports the uncompressed size for the ratio.
            assert_eq!(msg.raw_bytes(), plain.wire_bytes());
        }
    }
}

#[cfg(test)]
mod golden_tests {
    //! Golden-bytes fixtures: hex frames captured from the seed
    //! element-wise codec. The bulk codec must keep the on-wire format
    //! byte-identical — both directions are asserted for every variant.

    use super::*;

    fn hex_to_bytes(hex: &str) -> Vec<u8> {
        let compact: String =
            hex.chars().filter(|c| !c.is_whitespace()).collect();
        assert_eq!(compact.len() % 2, 0, "odd hex length");
        (0..compact.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&compact[i..i + 2], 16).unwrap())
            .collect()
    }

    fn fixtures() -> Vec<(&'static str, Message, &'static str)> {
        vec![
            (
                "shutdown",
                Message::Shutdown,
                "05 0000000000000000",
            ),
            (
                "eval_ack",
                Message::EvalAck { round: 0x0102030405060708 },
                "04 0807060504030201",
            ),
            (
                "activation_f32_2x2",
                Message::Activation {
                    round: 1,
                    tensor: Tensor::f32(vec![2, 2],
                                        vec![0.0, 1.0, -2.0, 0.5]),
                },
                "01 0100000000000000 00 02 02000000 02000000 \
                 00000000 0000803f 000000c0 0000003f",
            ),
            (
                "derivative_f32_3",
                Message::Derivative {
                    round: 2,
                    tensor: Tensor::f32(vec![3], vec![1.5, -0.25, 3.0]),
                },
                "02 0200000000000000 00 01 03000000 \
                 0000c03f 000080be 00004040",
            ),
            (
                "eval_activation_i32_2x1",
                Message::EvalActivation {
                    round: 9,
                    tensor: Tensor::i32(vec![2, 1], vec![7, -1]),
                },
                "03 0900000000000000 01 02 02000000 01000000 \
                 07000000 ffffffff",
            ),
        ]
    }

    /// Compressed-path fixtures: frames captured from this codec
    /// implementation at introduction time (PR 2). Byte-for-byte drift
    /// in the codec block layout or in any codec's packed output fails
    /// here.
    fn compressed_fixtures() -> Vec<(&'static str, Message, &'static str)> {
        use crate::compress::{compress_tensor, CodecKind};
        let fp16 = compress_tensor(
            CodecKind::Fp16,
            &Tensor::f32(vec![2, 2], vec![0.0, 1.0, -2.0, 0.5]),
        )
        .unwrap();
        let int8 = compress_tensor(
            CodecKind::QuantInt8,
            &Tensor::f32(vec![1, 4], vec![0.0, 1.0, 2.0, 3.0]),
        )
        .unwrap();
        let topk = compress_tensor(
            CodecKind::TopK(2),
            &Tensor::f32(vec![4], vec![0.5, -3.0, 0.25, 2.0]),
        )
        .unwrap();
        vec![
            (
                "hello_all_codecs",
                Message::Hello { codecs: 0x0f },
                "06 0000000000000000 0f000000",
            ),
            (
                "compressed_fp16_2x2",
                Message::Compressed {
                    round: 1,
                    lane: Lane::Activation,
                    stats: fp16,
                },
                "07 0100000000000000 01 01 00000000 02 02000000 \
                 02000000 00000000 0000 003c 00c0 0038",
            ),
            (
                "compressed_int8_1x4",
                Message::Compressed {
                    round: 2,
                    lane: Lane::Derivative,
                    stats: int8,
                },
                "07 0200000000000000 02 02 00000000 02 01000000 \
                 04000000 08000000 c1c0403c 00000000 00 55 aa ff",
            ),
            (
                "compressed_topk2_4",
                Message::Compressed {
                    round: 9,
                    lane: Lane::EvalActivation,
                    stats: topk,
                },
                "07 0900000000000000 03 03 02000000 01 04000000 \
                 00000000 01000000 000040c0 03000000 00000040",
            ),
        ]
    }

    #[test]
    fn golden_encode_is_byte_identical() {
        for (name, msg, hex) in fixtures() {
            assert_eq!(msg.encode(), hex_to_bytes(hex),
                       "encode drifted for fixture '{name}'");
        }
    }

    #[test]
    fn golden_compressed_encode_is_byte_identical() {
        for (name, msg, hex) in compressed_fixtures() {
            assert_eq!(msg.encode(), hex_to_bytes(hex),
                       "encode drifted for fixture '{name}'");
        }
    }

    #[test]
    fn golden_compressed_decode_recovers_messages() {
        for (name, msg, hex) in compressed_fixtures() {
            let dec = Message::decode(&hex_to_bytes(hex))
                .unwrap_or_else(|e| panic!("fixture '{name}': {e}"));
            assert_eq!(dec, msg, "decode drifted for fixture '{name}'");
        }
    }

    #[test]
    fn golden_decode_recovers_messages() {
        for (name, msg, hex) in fixtures() {
            let dec = Message::decode(&hex_to_bytes(hex))
                .unwrap_or_else(|e| panic!("fixture '{name}': {e}"));
            assert_eq!(dec, msg, "decode drifted for fixture '{name}'");
        }
    }

    #[test]
    fn golden_framed_encoding_prefixes_length() {
        for (name, msg, hex) in fixtures() {
            let body = hex_to_bytes(hex);
            let mut framed = Vec::new();
            msg.encode_into(&mut framed);
            assert_eq!(&framed[..4],
                       &(body.len() as u32).to_le_bytes(),
                       "length word wrong for fixture '{name}'");
            assert_eq!(&framed[4..], &body[..],
                       "framed body drifted for fixture '{name}'");
        }
    }
}

#[cfg(test)]
mod v2_tests {
    //! Addressed-frame coverage: golden bytes for the v2 envelope, the
    //! v1 backward-compat path against the exact PR-2 fixture bytes,
    //! and hostile-header rejection for out-of-range party ids.

    use super::*;

    fn hex_to_bytes(hex: &str) -> Vec<u8> {
        let compact: String =
            hex.chars().filter(|c| !c.is_whitespace()).collect();
        assert_eq!(compact.len() % 2, 0, "odd hex length");
        (0..compact.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&compact[i..i + 2], 16).unwrap())
            .collect()
    }

    fn hdr(src: u16, dst: u16) -> FrameHeader {
        FrameHeader { src: PartyId(src), dst: PartyId(dst) }
    }

    /// v2 fixtures: the envelope prefix is pinned byte-for-byte, the
    /// body is the corresponding v1 fixture unchanged.
    fn v2_fixtures() -> Vec<(&'static str, FrameHeader, Message,
                             &'static str)> {
        vec![
            (
                "v2_activation_p1_to_p0",
                hdr(1, 0),
                Message::Activation {
                    round: 1,
                    tensor: Tensor::f32(vec![2, 2],
                                        vec![0.0, 1.0, -2.0, 0.5]),
                },
                "08 02 0100 0000 \
                 01 0100000000000000 00 02 02000000 02000000 \
                 00000000 0000803f 000000c0 0000003f",
            ),
            (
                "v2_derivative_p0_to_p2",
                hdr(0, 2),
                Message::Derivative {
                    round: 2,
                    tensor: Tensor::f32(vec![3], vec![1.5, -0.25, 3.0]),
                },
                "08 02 0000 0200 \
                 02 0200000000000000 00 01 03000000 \
                 0000c03f 000080be 00004040",
            ),
            (
                "v2_hello_p2_to_p0",
                hdr(2, 0),
                Message::Hello { codecs: 0x0f },
                "08 02 0200 0000 06 0000000000000000 0f000000",
            ),
            (
                "v2_eval_ack_p0_to_p3",
                hdr(0, 3),
                Message::EvalAck { round: 0x0102030405060708 },
                "08 02 0000 0300 04 0807060504030201",
            ),
        ]
    }

    #[test]
    fn golden_v2_encode_is_byte_identical() {
        for (name, h, msg, hex) in v2_fixtures() {
            assert_eq!(encode_frame(Some(h), &msg), hex_to_bytes(hex),
                       "v2 encode drifted for fixture '{name}'");
        }
    }

    #[test]
    fn golden_v2_decode_recovers_header_and_message() {
        for (name, h, msg, hex) in v2_fixtures() {
            let (got_h, got_m) = decode_frame(&hex_to_bytes(hex))
                .unwrap_or_else(|e| panic!("fixture '{name}': {e}"));
            assert_eq!(got_h, Some(h), "header drifted for '{name}'");
            assert_eq!(got_m, msg, "message drifted for '{name}'");
        }
    }

    #[test]
    fn v1_fixture_bytes_still_decode_headerless() {
        // Backward compat: the exact PR-2 fixture byte strings must
        // come back through decode_frame with no header attached.
        for (name, hex) in [
            ("shutdown", "05 0000000000000000"),
            ("eval_ack", "04 0807060504030201"),
            (
                "activation_f32_2x2",
                "01 0100000000000000 00 02 02000000 02000000 \
                 00000000 0000803f 000000c0 0000003f",
            ),
            (
                "compressed_fp16_2x2",
                "07 0100000000000000 01 01 00000000 02 02000000 \
                 02000000 00000000 0000 003c 00c0 0038",
            ),
            ("hello_all_codecs", "06 0000000000000000 0f000000"),
        ] {
            let bytes = hex_to_bytes(hex);
            let (h, m) = decode_frame(&bytes)
                .unwrap_or_else(|e| panic!("fixture '{name}': {e}"));
            assert_eq!(h, None, "v1 fixture '{name}' grew a header");
            assert_eq!(m.encode(), bytes,
                       "v1 fixture '{name}' did not round-trip");
        }
    }

    #[test]
    fn headerless_encode_frame_matches_v1_encode() {
        let msg = Message::Derivative {
            round: 9,
            tensor: Tensor::f32(vec![2], vec![1.0, -1.0]),
        };
        assert_eq!(encode_frame(None, &msg), msg.encode());
        let mut framed = Vec::new();
        encode_frame_into(None, &msg, &mut framed);
        let mut v1 = Vec::new();
        msg.encode_into(&mut v1);
        assert_eq!(framed, v1);
    }

    #[test]
    fn encode_frame_into_prefixes_envelope_length() {
        let msg = Message::EvalAck { round: 7 };
        let h = hdr(1, 0);
        let body = encode_frame(Some(h), &msg);
        assert_eq!(body.len(), msg.wire_bytes() - 4 + FRAME_V2_OVERHEAD);
        let mut framed = Vec::new();
        encode_frame_into(Some(h), &msg, &mut framed);
        assert_eq!(&framed[..4], &(body.len() as u32).to_le_bytes());
        assert_eq!(&framed[4..], &body[..]);
        // The scratch is reusable across header modes.
        encode_frame_into(None, &msg, &mut framed);
        assert_eq!(&framed[4..], &msg.encode()[..]);
    }

    #[test]
    fn reply_swaps_endpoints() {
        assert_eq!(hdr(3, 0).reply(), hdr(0, 3));
    }

    #[test]
    fn rejects_bad_versions_and_truncations() {
        let good = encode_frame(Some(hdr(1, 0)),
                                &Message::EvalAck { round: 1 });
        let mut bad_ver = good.clone();
        bad_ver[1] = 3;
        assert!(decode_frame(&bad_ver).is_err(), "version 3 accepted");
        // Every prefix of the envelope fails cleanly (cut 0 falls into
        // the v1 path, where an empty body is equally an error).
        for cut in 0..FRAME_V2_OVERHEAD {
            assert!(decode_frame(&good[..cut]).is_err(),
                    "truncated header at {cut} decoded");
        }
        // Self-addressed frames are rejected.
        let mut selfie = encode_frame(Some(hdr(1, 0)),
                                      &Message::EvalAck { round: 1 });
        selfie[4] = 1; // dst := 1 == src
        assert!(decode_frame(&selfie).is_err(), "self-addressed decoded");
    }
}

#[cfg(test)]
mod bootstrap_tests {
    //! `Join`/`JoinAck` coverage: golden bytes pinning the handshake
    //! frame layout, roundtrips, and hostile-header rejection (wrong
    //! version / out-of-range ids — validated before the message is
    //! built; duplicate-id rejection is a *listener* semantic and is
    //! covered in `session::bootstrap`).

    use super::*;

    fn hex_to_bytes(hex: &str) -> Vec<u8> {
        let compact: String =
            hex.chars().filter(|c| !c.is_whitespace()).collect();
        assert_eq!(compact.len() % 2, 0, "odd hex length");
        (0..compact.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&compact[i..i + 2], 16).unwrap())
            .collect()
    }

    /// Golden fixtures captured at introduction time: byte-for-byte
    /// drift in the bootstrap handshake fails here.
    fn join_fixtures() -> Vec<(&'static str, Message, &'static str)> {
        vec![
            (
                "join_p2_of_3",
                Message::Join {
                    party: PartyId(2),
                    parties: 3,
                    codecs: 0x0f,
                },
                "09 0000000000000000 01 0200 0300 0f000000",
            ),
            (
                "join_ack_p2_of_3",
                Message::JoinAck {
                    party: PartyId(2),
                    parties: 3,
                    codecs: 0x0f,
                },
                "0a 0000000000000000 01 0200 0300 0f000000",
            ),
            (
                "join_p1_of_2_no_codecs",
                Message::Join {
                    party: PartyId(1),
                    parties: 2,
                    codecs: 0,
                },
                "09 0000000000000000 01 0100 0200 00000000",
            ),
            (
                "join_ack_p63_of_64_all_codecs",
                Message::JoinAck {
                    party: PartyId(63),
                    parties: 64,
                    codecs: 0xffff_ffff,
                },
                "0a 0000000000000000 01 3f00 4000 ffffffff",
            ),
        ]
    }

    #[test]
    fn golden_join_encode_is_byte_identical() {
        for (name, msg, hex) in join_fixtures() {
            assert_eq!(msg.encode(), hex_to_bytes(hex),
                       "encode drifted for fixture '{name}'");
            assert_eq!(msg.wire_bytes(), msg.encode().len() + 4,
                       "wire_bytes drifted for fixture '{name}'");
        }
    }

    #[test]
    fn golden_join_decode_recovers_messages() {
        for (name, msg, hex) in join_fixtures() {
            let dec = Message::decode(&hex_to_bytes(hex))
                .unwrap_or_else(|e| panic!("fixture '{name}': {e}"));
            assert_eq!(dec, msg, "decode drifted for fixture '{name}'");
            // Bootstrap frames travel headerless: decode_frame must
            // take the v1 path and attach no envelope.
            let (h, m) = decode_frame(&hex_to_bytes(hex)).unwrap();
            assert_eq!(h, None, "join fixture '{name}' grew a header");
            assert_eq!(m, msg);
        }
    }

    #[test]
    fn rejects_wrong_join_version() {
        let good = Message::Join {
            party: PartyId(1),
            parties: 3,
            codecs: 0x0f,
        }
        .encode();
        for bad_ver in [0u8, 2, 7, 255] {
            let mut bent = good.clone();
            bent[9] = bad_ver; // version byte follows tag + round
            let e = Message::decode(&bent).unwrap_err().to_string();
            assert!(e.contains("join version"), "version {bad_ver}: {e}");
        }
    }

    #[test]
    fn rejects_out_of_range_join_ids() {
        // (party, parties) pairs the decoder must refuse: the label id
        // can never join, ids must sit inside the declared session, and
        // the session size itself is bounded by MAX_PARTIES.
        for (party, parties) in [
            (0u16, 3u16),                 // label party never joins
            (3, 3),                       // id == parties
            (9, 3),                       // id > parties
            (1, 1),                       // no feature slots
            (1, 0),                       // degenerate session
            (1, MAX_PARTIES + 1),         // session too large
            (u16::MAX, MAX_PARTIES),      // both huge
        ] {
            let frame = Message::Join {
                party: PartyId(party),
                parties,
                codecs: 0,
            }
            .encode();
            assert!(Message::decode(&frame).is_err(),
                    "join ({party}, {parties}) decoded");
        }
        // Boundary: the largest legal claim still decodes.
        let ok = Message::Join {
            party: PartyId(MAX_PARTIES - 1),
            parties: MAX_PARTIES,
            codecs: 0,
        };
        assert_eq!(Message::decode(&ok.encode()).unwrap(), ok);
    }

    /// Golden fixtures for the re-admission handshake, captured at
    /// introduction time: byte-for-byte drift in the `Rejoin` /
    /// `RejoinAck` layout fails here. Tags 11/12 are fresh — disjoint
    /// from every pre-existing tag (1..=10) — so no historic byte
    /// stream can collide with them.
    fn rejoin_fixtures() -> Vec<(&'static str, Message, &'static str)> {
        vec![
            (
                "rejoin_p2_of_3_round_7",
                Message::Rejoin {
                    party: PartyId(2),
                    parties: 3,
                    epoch: 0x0102_0304,
                    last_round: 7,
                    codecs: 0x0f,
                },
                "0b 0000000000000000 01 0200 0300 04030201 \
                 0700000000000000 0f000000",
            ),
            (
                "rejoin_ack_p2_of_3_resume_9_one_replay",
                Message::RejoinAck {
                    party: PartyId(2),
                    parties: 3,
                    epoch: 0x0102_0304,
                    resume_round: 9,
                    replays: 1,
                },
                "0c 0000000000000000 01 0200 0300 04030201 \
                 0900000000000000 01000000",
            ),
            (
                "rejoin_p1_of_2_round_0",
                Message::Rejoin {
                    party: PartyId(1),
                    parties: 2,
                    epoch: 0,
                    last_round: 0,
                    codecs: 0,
                },
                "0b 0000000000000000 01 0100 0200 00000000 \
                 0000000000000000 00000000",
            ),
            (
                "rejoin_ack_p63_of_64_big_round",
                Message::RejoinAck {
                    party: PartyId(63),
                    parties: 64,
                    epoch: 0xffff_ffff,
                    resume_round: 0x0102_0304_0506_0708,
                    replays: 0,
                },
                "0c 0000000000000000 01 3f00 4000 ffffffff \
                 0807060504030201 00000000",
            ),
        ]
    }

    #[test]
    fn golden_rejoin_encode_is_byte_identical() {
        for (name, msg, hex) in rejoin_fixtures() {
            assert_eq!(msg.encode(), hex_to_bytes(hex),
                       "encode drifted for fixture '{name}'");
            assert_eq!(msg.wire_bytes(), msg.encode().len() + 4,
                       "wire_bytes drifted for fixture '{name}'");
        }
    }

    #[test]
    fn golden_rejoin_decode_recovers_messages() {
        for (name, msg, hex) in rejoin_fixtures() {
            let dec = Message::decode(&hex_to_bytes(hex))
                .unwrap_or_else(|e| panic!("fixture '{name}': {e}"));
            assert_eq!(dec, msg, "decode drifted for fixture '{name}'");
            // Re-admission frames travel headerless on the raw socket:
            // decode_frame must take the v1 path and attach no envelope.
            let (h, m) = decode_frame(&hex_to_bytes(hex)).unwrap();
            assert_eq!(h, None, "rejoin fixture '{name}' grew a header");
            assert_eq!(m, msg);
        }
    }

    #[test]
    fn rejects_wrong_rejoin_version() {
        let good = Message::Rejoin {
            party: PartyId(1),
            parties: 3,
            epoch: 9,
            last_round: 4,
            codecs: 0x0f,
        }
        .encode();
        for bad_ver in [0u8, 2, 7, 255] {
            let mut bent = good.clone();
            bent[9] = bad_ver; // version byte follows tag + round
            let e = Message::decode(&bent).unwrap_err().to_string();
            assert!(e.contains("rejoin version"),
                    "version {bad_ver}: {e}");
        }
    }

    #[test]
    fn rejects_out_of_range_rejoin_ids() {
        // Same refusal table as Join: the label id can never rejoin,
        // ids must sit inside the declared session, and the session
        // size itself is bounded by MAX_PARTIES.
        for (party, parties) in [
            (0u16, 3u16),
            (3, 3),
            (9, 3),
            (1, 1),
            (1, 0),
            (1, MAX_PARTIES + 1),
            (u16::MAX, MAX_PARTIES),
        ] {
            let frame = Message::Rejoin {
                party: PartyId(party),
                parties,
                epoch: 0,
                last_round: 0,
                codecs: 0,
            }
            .encode();
            assert!(Message::decode(&frame).is_err(),
                    "rejoin ({party}, {parties}) decoded");
        }
        let ok = Message::Rejoin {
            party: PartyId(MAX_PARTIES - 1),
            parties: MAX_PARTIES,
            epoch: 1,
            last_round: 2,
            codecs: 3,
        };
        assert_eq!(Message::decode(&ok.encode()).unwrap(), ok);
    }

    #[test]
    fn rejoin_truncations_error_cleanly() {
        let enc = Message::RejoinAck {
            party: PartyId(2),
            parties: 3,
            epoch: 5,
            resume_round: 6,
            replays: 1,
        }
        .encode();
        for cut in 0..enc.len() {
            assert!(Message::decode(&enc[..cut]).is_err(),
                    "truncation at {cut} decoded");
        }
        let mut trailing = enc;
        trailing.push(0);
        assert!(Message::decode(&trailing).is_err(), "trailing byte ok'd");
    }

    /// Golden fixtures for the bootstrap-refusal frame, captured at
    /// introduction time (machine-checked against an independent Python
    /// rebuild of the layout). Tag 13 is fresh — disjoint from every
    /// pre-existing tag (1..=12).
    fn reject_fixtures() -> Vec<(&'static str, Message, &'static str)> {
        vec![
            (
                "reject_p2_epoch_mismatch_round_7",
                Message::RejoinReject {
                    party: PartyId(2),
                    reason: RejectReason::EpochMismatch,
                    round: 7,
                },
                "0d 0000000000000000 01 0200 01 0700000000000000",
            ),
            (
                "reject_p63_need_rejoin_big_round",
                Message::RejoinReject {
                    party: PartyId(63),
                    reason: RejectReason::NeedRejoin,
                    round: 0x0102_0304_0506_0708,
                },
                "0d 0000000000000000 01 3f00 02 0807060504030201",
            ),
        ]
    }

    #[test]
    fn golden_reject_encode_is_byte_identical() {
        for (name, msg, hex) in reject_fixtures() {
            assert_eq!(msg.encode(), hex_to_bytes(hex),
                       "encode drifted for fixture '{name}'");
            assert_eq!(msg.wire_bytes(), msg.encode().len() + 4,
                       "wire_bytes drifted for fixture '{name}'");
        }
    }

    #[test]
    fn golden_reject_decode_recovers_messages() {
        for (name, msg, hex) in reject_fixtures() {
            let dec = Message::decode(&hex_to_bytes(hex))
                .unwrap_or_else(|e| panic!("fixture '{name}': {e}"));
            assert_eq!(dec, msg, "decode drifted for fixture '{name}'");
            // Refusal frames travel headerless on the raw socket.
            let (h, m) = decode_frame(&hex_to_bytes(hex)).unwrap();
            assert_eq!(h, None, "reject fixture '{name}' grew a header");
            assert_eq!(m, msg);
        }
    }

    #[test]
    fn rejects_wrong_reject_version_reason_and_ids() {
        let good = Message::RejoinReject {
            party: PartyId(2),
            reason: RejectReason::EpochMismatch,
            round: 4,
        }
        .encode();
        for bad_ver in [0u8, 2, 7, 255] {
            let mut bent = good.clone();
            bent[9] = bad_ver; // version byte follows tag + round
            let e = Message::decode(&bent).unwrap_err().to_string();
            assert!(e.contains("reject version"),
                    "version {bad_ver}: {e}");
        }
        // Unknown reason codes are refused (the set is closed).
        for bad_reason in [0u8, 3, 9, 255] {
            let mut bent = good.clone();
            bent[12] = bad_reason; // reason byte follows ver + party
            let e = Message::decode(&bent).unwrap_err().to_string();
            assert!(e.contains("reject reason"),
                    "reason {bad_reason}: {e}");
        }
        // The label id can never be the rejected party, and ids are
        // bounded by the session-size cap.
        for bad_party in [0u16, MAX_PARTIES, u16::MAX] {
            let mut bent = good.clone();
            bent[10..12].copy_from_slice(&bad_party.to_le_bytes());
            assert!(Message::decode(&bent).is_err(),
                    "reject party {bad_party} decoded");
        }
        // Boundary: the largest legal id still decodes.
        let ok = Message::RejoinReject {
            party: PartyId(MAX_PARTIES - 1),
            reason: RejectReason::NeedRejoin,
            round: 0,
        };
        assert_eq!(Message::decode(&ok.encode()).unwrap(), ok);
    }

    #[test]
    fn reject_truncations_error_cleanly() {
        let enc = Message::RejoinReject {
            party: PartyId(2),
            reason: RejectReason::NeedRejoin,
            round: 6,
        }
        .encode();
        for cut in 0..enc.len() {
            assert!(Message::decode(&enc[..cut]).is_err(),
                    "truncation at {cut} decoded");
        }
        let mut trailing = enc;
        trailing.push(0);
        assert!(Message::decode(&trailing).is_err(), "trailing byte ok'd");
    }

    #[test]
    fn join_truncations_error_cleanly() {
        let enc = Message::JoinAck {
            party: PartyId(2),
            parties: 3,
            codecs: 0x0f,
        }
        .encode();
        for cut in 0..enc.len() {
            assert!(Message::decode(&enc[..cut]).is_err(),
                    "truncation at {cut} decoded");
        }
        let mut trailing = enc;
        trailing.push(0);
        assert!(Message::decode(&trailing).is_err(), "trailing byte ok'd");
    }
}

#[cfg(test)]
mod metrics_tests {
    //! `Metrics` (tag 14) coverage: golden bytes pinning the push-stream
    //! frame layout (machine-checked against an independent Python
    //! rebuild at introduction time), roundtrips, truncation totality,
    //! and hostile-header rejection — the same discipline as tags 9–13.

    use super::*;

    fn hex_to_bytes(hex: &str) -> Vec<u8> {
        let compact: String =
            hex.chars().filter(|c| !c.is_whitespace()).collect();
        assert_eq!(compact.len() % 2, 0, "odd hex length");
        (0..compact.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&compact[i..i + 2], 16).unwrap())
            .collect()
    }

    fn row(src: u16, dst: u16, messages: u64, wire_bytes: u64,
           raw_bytes: u64, busy_nanos: u64) -> LinkMetricsRow {
        LinkMetricsRow {
            src: PartyId(src),
            dst: PartyId(dst),
            messages,
            wire_bytes,
            raw_bytes,
            busy_nanos,
        }
    }

    /// Golden fixtures captured at introduction time: byte-for-byte
    /// drift in the metrics-stream layout fails here. Tag 14 is fresh —
    /// disjoint from every pre-existing tag (1..=13).
    fn metrics_fixtures() -> Vec<(&'static str, Message, &'static str)> {
        vec![
            (
                "metrics_empty_round_3",
                Message::Metrics { round: 3, links: vec![] },
                "0e 0300000000000000 01 00",
            ),
            (
                "metrics_two_links_round_7",
                Message::Metrics {
                    round: 7,
                    links: vec![
                        row(1, 0, 3, 1000, 2000, 500),
                        row(0, 2, 1, 0x0102_0304_0506_0708,
                            u64::MAX, 0),
                    ],
                },
                "0e 0700000000000000 01 02 \
                 0100 0000 0300000000000000 e803000000000000 \
                 d007000000000000 f401000000000000 \
                 0000 0200 0100000000000000 0807060504030201 \
                 ffffffffffffffff 0000000000000000",
            ),
            (
                "metrics_p63_max_round",
                Message::Metrics {
                    round: u64::MAX,
                    links: vec![row(63, 0, 0, 0, 0, 0)],
                },
                "0e ffffffffffffffff 01 01 \
                 3f00 0000 0000000000000000 0000000000000000 \
                 0000000000000000 0000000000000000",
            ),
        ]
    }

    #[test]
    fn golden_metrics_encode_is_byte_identical() {
        for (name, msg, hex) in metrics_fixtures() {
            assert_eq!(msg.encode(), hex_to_bytes(hex),
                       "encode drifted for fixture '{name}'");
            assert_eq!(msg.wire_bytes(), msg.encode().len() + 4,
                       "wire_bytes drifted for fixture '{name}'");
            assert_eq!(msg.raw_bytes(), msg.wire_bytes(),
                       "metrics frames are never compressed");
        }
    }

    #[test]
    fn golden_metrics_decode_recovers_messages() {
        for (name, msg, hex) in metrics_fixtures() {
            let dec = Message::decode(&hex_to_bytes(hex))
                .unwrap_or_else(|e| panic!("fixture '{name}': {e}"));
            assert_eq!(dec, msg, "decode drifted for fixture '{name}'");
            // Metrics frames travel headerless on the watch socket:
            // decode_frame must take the v1 path and attach no envelope.
            let (h, m) = decode_frame(&hex_to_bytes(hex)).unwrap();
            assert_eq!(h, None, "metrics fixture '{name}' grew a header");
            assert_eq!(m, msg);
        }
    }

    #[test]
    fn rejects_wrong_metrics_version() {
        let good = Message::Metrics {
            round: 2,
            links: vec![row(1, 0, 1, 2, 3, 4)],
        }
        .encode();
        for bad_ver in [0u8, 2, 7, 255] {
            let mut bent = good.clone();
            bent[9] = bad_ver; // version byte follows tag + round
            let e = Message::decode(&bent).unwrap_err().to_string();
            assert!(e.contains("metrics version"),
                    "version {bad_ver}: {e}");
        }
    }

    #[test]
    fn rejects_bad_row_ids_and_counts() {
        // Out-of-range endpoints and self-links are refused per row.
        for (src, dst) in [(MAX_PARTIES, 0u16), (0, MAX_PARTIES),
                           (u16::MAX, u16::MAX), (1, 1), (0, 0)] {
            let frame = Message::Metrics {
                round: 0,
                links: vec![row(src, dst, 0, 0, 0, 0)],
            }
            .encode();
            assert!(Message::decode(&frame).is_err(),
                    "metrics row ({src}, {dst}) decoded");
        }
        // A declared row count past the cap is refused before any row
        // is read (the payload behind it is absent entirely).
        let mut frame = Vec::new();
        frame.push(14u8);
        frame.extend_from_slice(&0u64.to_le_bytes());
        frame.push(METRICS_VERSION);
        frame.push(200u8); // > MAX_METRICS_ROWS = 128
        let e = Message::decode(&frame).unwrap_err().to_string();
        assert!(e.contains("link rows"), "cap not enforced: {e}");
        // Boundary: the largest legal endpoints still decode.
        let ok = Message::Metrics {
            round: 1,
            links: vec![row(MAX_PARTIES - 1, 0, 1, 2, 3, 4),
                        row(0, MAX_PARTIES - 1, 5, 6, 7, 8)],
        };
        assert_eq!(Message::decode(&ok.encode()).unwrap(), ok);
    }

    #[test]
    fn metrics_truncations_error_cleanly() {
        let enc = Message::Metrics {
            round: 9,
            links: vec![row(1, 0, 10, 20, 30, 40),
                        row(2, 0, 1, 2, 3, 4)],
        }
        .encode();
        for cut in 0..enc.len() {
            assert!(Message::decode(&enc[..cut]).is_err(),
                    "truncation at {cut} decoded");
        }
        let mut trailing = enc;
        trailing.push(0);
        assert!(Message::decode(&trailing).is_err(), "trailing byte ok'd");
    }
}

#[cfg(test)]
mod fuzz_tests {
    use super::*;
    use crate::testing::prop;
    use crate::prop_assert;

    #[test]
    fn prop_decode_never_panics_on_garbage() {
        // Any byte string must produce Ok or Err — never a panic/abort.
        prop::check("decode total on garbage", |rng| {
            let len = rng.gen_range(64) as usize;
            let bytes: Vec<u8> =
                (0..len).map(|_| rng.next_u32() as u8).collect();
            let _ = Message::decode(&bytes);
            Ok(())
        });
    }

    #[test]
    fn prop_truncated_frames_error_not_panic() {
        prop::check("truncations error", |rng| {
            let rows = 1 + rng.gen_range(8) as usize;
            let cols = 1 + rng.gen_range(8) as usize;
            let t = Tensor::f32(vec![rows, cols], vec![1.0; rows * cols]);
            let enc = Message::Activation { round: 3, tensor: t }.encode();
            let cut = rng.gen_range(enc.len() as u32) as usize;
            if cut < enc.len() {
                prop_assert!(Message::decode(&enc[..cut]).is_err(),
                             "truncation at {cut} decoded");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_roundtrip_random_tensors() {
        prop::check("roundtrip random tensors", |rng| {
            let rows = 1 + rng.gen_range(16) as usize;
            let cols = 1 + rng.gen_range(16) as usize;
            let n = rows * cols;
            let msg = if rng.next_f32() < 0.5 {
                let v: Vec<f32> =
                    (0..n).map(|_| rng.next_normal()).collect();
                Message::Activation {
                    round: rng.next_u64(),
                    tensor: Tensor::f32(vec![rows, cols], v),
                }
            } else {
                let v: Vec<i32> =
                    (0..n).map(|_| rng.next_u32() as i32).collect();
                Message::EvalActivation {
                    round: rng.next_u64(),
                    tensor: Tensor::i32(vec![rows, cols], v),
                }
            };
            let dec = Message::decode(&msg.encode())
                .map_err(|e| format!("decode failed: {e}"))?;
            prop_assert!(dec == msg, "roundtrip mismatch");
            Ok(())
        });
    }

    #[test]
    fn prop_hostile_headers_near_usize_max_error_cleanly() {
        // Hand-built frames whose dim words multiply toward (or past)
        // usize::MAX: decode must reject them without panicking and
        // without attempting the implied multi-exabyte allocation.
        prop::check("hostile huge-dim headers", |rng| {
            let mut frame = Vec::new();
            frame.push(1 + rng.gen_range(3) as u8); // a tensor tag
            frame.extend_from_slice(&rng.next_u64().to_le_bytes());
            frame.push(rng.gen_range(2) as u8); // valid dtype code
            let ndim = 2 + rng.gen_range(6) as u8;
            frame.push(ndim);
            for _ in 0..ndim {
                // Bias dims huge: u32::MAX-ish values whose product
                // overflows usize on 64-bit (and wildly on 32-bit).
                let d = u32::MAX - rng.gen_range(7);
                frame.extend_from_slice(&d.to_le_bytes());
            }
            // Little or no payload behind the hostile header.
            for _ in 0..rng.gen_range(8) {
                frame.push(rng.next_u32() as u8);
            }
            prop_assert!(Message::decode(&frame).is_err(),
                         "hostile header decoded");
            Ok(())
        });
    }

    #[test]
    fn prop_compressed_roundtrip_random_tensors() {
        use crate::compress::{compress_tensor, CodecKind};
        prop::check("compressed roundtrip", |rng| {
            let rows = 1 + rng.gen_range(12) as usize;
            let cols = 1 + rng.gen_range(12) as usize;
            let v: Vec<f32> = (0..rows * cols)
                .map(|_| rng.next_normal())
                .collect();
            let t = Tensor::f32(vec![rows, cols], v);
            let kind = match rng.gen_range(3) {
                0 => CodecKind::Fp16,
                1 => CodecKind::QuantInt8,
                _ => CodecKind::TopK(1 + rng.gen_range(16)),
            };
            let stats = compress_tensor(kind, &t)
                .map_err(|e| format!("compress: {e}"))?;
            let msg = Message::Compressed {
                round: rng.next_u64(),
                lane: Lane::Activation,
                stats,
            };
            let dec = Message::decode(&msg.encode())
                .map_err(|e| format!("decode: {e}"))?;
            prop_assert!(dec == msg, "compressed roundtrip mismatch");
            prop_assert!(msg.wire_bytes() == msg.encode().len() + 4,
                         "wire_bytes drifted");
            Ok(())
        });
    }

    #[test]
    fn prop_compressed_truncations_and_garbage_error_cleanly() {
        use crate::compress::{compress_tensor, CodecKind};
        prop::check("compressed frames total", |rng| {
            let n = 1 + rng.gen_range(64) as usize;
            let v: Vec<f32> =
                (0..n).map(|_| rng.next_normal()).collect();
            let t = Tensor::f32(vec![n], v);
            let stats = compress_tensor(CodecKind::QuantInt8, &t)
                .map_err(|e| format!("compress: {e}"))?;
            let enc = Message::Compressed {
                round: 1,
                lane: Lane::Derivative,
                stats,
            }
            .encode();
            // Truncation at every prefix errors, never panics.
            let cut = rng.gen_range(enc.len() as u32) as usize;
            prop_assert!(Message::decode(&enc[..cut]).is_err(),
                         "truncation at {cut} decoded");
            // Single-byte corruption is Ok-or-Err, never a panic (it can
            // legitimately decode when it hits payload bytes).
            let mut bent = enc.clone();
            let at = rng.gen_range(bent.len() as u32) as usize;
            bent[at] ^= 1 + (rng.next_u32() as u8 & 0x7f);
            let _ = Message::decode(&bent);
            Ok(())
        });
    }

    #[test]
    fn prop_hostile_compressed_headers_error_cleanly() {
        // Compressed frames with huge dim words / absurd extra_len must
        // be rejected by arithmetic (Reader::take + expected_lens), not
        // by attempting the implied allocation.
        prop::check("hostile compressed headers", |rng| {
            let mut frame = Vec::new();
            frame.push(7u8); // TAG_COMP
            frame.extend_from_slice(&rng.next_u64().to_le_bytes());
            frame.push(1 + rng.gen_range(3) as u8); // valid lane
            frame.push(rng.gen_range(4) as u8); // valid codec family
            frame.extend_from_slice(&rng.next_u32().to_le_bytes()); // param
            let ndim = 2 + rng.gen_range(6) as u8;
            frame.push(ndim);
            for _ in 0..ndim {
                let d = u32::MAX - rng.gen_range(7);
                frame.extend_from_slice(&d.to_le_bytes());
            }
            frame.extend_from_slice(&rng.next_u32().to_le_bytes());
            for _ in 0..rng.gen_range(16) {
                frame.push(rng.next_u32() as u8);
            }
            prop_assert!(Message::decode(&frame).is_err(),
                         "hostile compressed header decoded");
            Ok(())
        });
    }

    #[test]
    fn prop_v2_roundtrip_random_frames() {
        prop::check("v2 frame roundtrip", |rng| {
            let rows = 1 + rng.gen_range(8) as usize;
            let cols = 1 + rng.gen_range(8) as usize;
            let v: Vec<f32> =
                (0..rows * cols).map(|_| rng.next_normal()).collect();
            let src = rng.gen_range(MAX_PARTIES as u32) as u16;
            let mut dst = rng.gen_range(MAX_PARTIES as u32) as u16;
            if dst == src {
                dst = (dst + 1) % MAX_PARTIES;
            }
            let h = FrameHeader { src: PartyId(src), dst: PartyId(dst) };
            let msg = Message::Activation {
                round: rng.next_u64(),
                tensor: Tensor::f32(vec![rows, cols], v),
            };
            let enc = encode_frame(Some(h), &msg);
            let (got_h, got_m) = decode_frame(&enc)
                .map_err(|e| format!("decode: {e}"))?;
            prop_assert!(got_h == Some(h), "header mismatch");
            prop_assert!(got_m == msg, "message mismatch");
            prop_assert!(enc.len()
                             == msg.wire_bytes() - 4 + FRAME_V2_OVERHEAD,
                         "v2 length drifted");
            Ok(())
        });
    }

    #[test]
    fn prop_hostile_party_ids_error_before_allocation() {
        // v2 envelopes whose src/dst ids are out of range must be
        // rejected from the 6 header bytes alone — even when the body
        // behind them declares a huge tensor, decode must never reach
        // (let alone allocate for) it.
        prop::check("hostile party ids", |rng| {
            let mut frame = Vec::new();
            frame.push(8u8); // TAG_V2
            frame.push(2u8); // valid version
            // At least one endpoint out of range; bias both huge.
            let src = MAX_PARTIES + rng.gen_range(u16::MAX as u32
                                                  - MAX_PARTIES as u32)
                as u16;
            let dst = if rng.next_f32() < 0.5 {
                rng.gen_range(MAX_PARTIES as u32) as u16
            } else {
                MAX_PARTIES + rng.gen_range(1000) as u16
            };
            frame.extend_from_slice(&src.to_le_bytes());
            frame.extend_from_slice(&dst.to_le_bytes());
            // A hostile body: huge dims behind the bad header.
            frame.push(1u8); // Activation
            frame.extend_from_slice(&rng.next_u64().to_le_bytes());
            frame.push(0u8); // f32
            frame.push(4u8); // ndim
            for _ in 0..4 {
                frame.extend_from_slice(&u32::MAX.to_le_bytes());
            }
            prop_assert!(decode_frame(&frame).is_err(),
                         "out-of-range party id decoded");
            Ok(())
        });
    }

    #[test]
    fn prop_hostile_join_frames_error_cleanly() {
        // Hand-built Join/JoinAck frames with random versions and id
        // pairs: decode must be total (Ok or Err, never a panic), must
        // reject every wrong version, and must reject every (party,
        // parties) pair outside the valid feature-id range — from the
        // fixed-size header alone.
        prop::check("hostile join frames", |rng| {
            let tag = if rng.next_f32() < 0.5 { 9u8 } else { 10u8 };
            let ver = (rng.gen_range(4) as u8).wrapping_sub(1); // 255,0,1,2
            let party = rng.next_u32() as u16;
            let parties = rng.next_u32() as u16;
            let mut frame = Vec::new();
            frame.push(tag);
            frame.extend_from_slice(&rng.next_u64().to_le_bytes());
            frame.push(ver);
            frame.extend_from_slice(&party.to_le_bytes());
            frame.extend_from_slice(&parties.to_le_bytes());
            frame.extend_from_slice(&rng.next_u32().to_le_bytes());
            let ids_ok = (2..=MAX_PARTIES).contains(&parties)
                && party >= 1
                && party < parties;
            // Round must be 0 for a join to round-trip; random rounds
            // still decode (the field is ignored) — the property under
            // test is version/range rejection, so only assert the
            // rejecting cases.
            let dec = Message::decode(&frame);
            if ver != JOIN_VERSION || !ids_ok {
                prop_assert!(dec.is_err(),
                             "hostile join (ver {ver}, party {party}, \
                              parties {parties}) decoded");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_hostile_rejoin_frames_error_cleanly() {
        // Hand-built Rejoin/RejoinAck frames with random versions and
        // id pairs: decode must be total (Ok or Err, never a panic),
        // must reject every wrong version, and must reject every
        // (party, parties) pair outside the valid feature-id range —
        // from the fixed-size header alone, before any allocation.
        prop::check("hostile rejoin frames", |rng| {
            let tag = if rng.next_f32() < 0.5 { 11u8 } else { 12u8 };
            let ver = (rng.gen_range(4) as u8).wrapping_sub(1); // 255,0,1,2
            let party = rng.next_u32() as u16;
            let parties = rng.next_u32() as u16;
            let mut frame = Vec::new();
            frame.push(tag);
            frame.extend_from_slice(&rng.next_u64().to_le_bytes());
            frame.push(ver);
            frame.extend_from_slice(&party.to_le_bytes());
            frame.extend_from_slice(&parties.to_le_bytes());
            frame.extend_from_slice(&rng.next_u32().to_le_bytes()); // epoch
            frame.extend_from_slice(&rng.next_u64().to_le_bytes()); // round
            frame.extend_from_slice(&rng.next_u32().to_le_bytes()); // trailer
            let ids_ok = (2..=MAX_PARTIES).contains(&parties)
                && party >= 1
                && party < parties;
            let dec = Message::decode(&frame);
            if ver != REJOIN_VERSION || !ids_ok {
                prop_assert!(dec.is_err(),
                             "hostile rejoin (ver {ver}, party {party}, \
                              parties {parties}) decoded");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_hostile_reject_frames_error_cleanly() {
        // Hand-built RejoinReject frames with random versions, reason
        // codes, and party ids: decode must be total (Ok or Err, never
        // a panic), must reject every wrong version, every unknown
        // reason code, and every out-of-range party id — from the
        // fixed-size header alone, before any allocation.
        prop::check("hostile reject frames", |rng| {
            let ver = (rng.gen_range(4) as u8).wrapping_sub(1); // 255,0,1,2
            let party = rng.next_u32() as u16;
            let reason = rng.gen_range(5) as u8; // 0..=4
            let mut frame = Vec::new();
            frame.push(13u8);
            frame.extend_from_slice(&rng.next_u64().to_le_bytes());
            frame.push(ver);
            frame.extend_from_slice(&party.to_le_bytes());
            frame.push(reason);
            frame.extend_from_slice(&rng.next_u64().to_le_bytes());
            let fields_ok = (1..=2).contains(&reason)
                && party >= 1
                && party < MAX_PARTIES;
            let dec = Message::decode(&frame);
            if ver != REJECT_VERSION || !fields_ok {
                prop_assert!(dec.is_err(),
                             "hostile reject (ver {ver}, party {party}, \
                              reason {reason}) decoded");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_hostile_metrics_frames_error_cleanly() {
        // Hand-built Metrics frames with random versions, row counts,
        // and row endpoints: decode must be total (Ok or Err, never a
        // panic), must reject every wrong version, every over-cap row
        // count, and every out-of-range or self-linked row — and a
        // well-formed random frame must round-trip exactly.
        prop::check("hostile metrics frames", |rng| {
            let ver = (rng.gen_range(4) as u8).wrapping_sub(1); // 255,0,1,2
            let n = rng.gen_range(256) as u8;
            let mut frame = Vec::new();
            frame.push(14u8);
            frame.extend_from_slice(&rng.next_u64().to_le_bytes());
            frame.push(ver);
            frame.push(n);
            let mut rows_ok = true;
            for _ in 0..n {
                // Bias ids toward the boundary so both sides are hit.
                let src = rng.gen_range(2 * MAX_PARTIES as u32) as u16;
                let dst = rng.gen_range(2 * MAX_PARTIES as u32) as u16;
                rows_ok &= src < MAX_PARTIES && dst < MAX_PARTIES
                    && src != dst;
                frame.extend_from_slice(&src.to_le_bytes());
                frame.extend_from_slice(&dst.to_le_bytes());
                for _ in 0..4 {
                    frame.extend_from_slice(
                        &rng.next_u64().to_le_bytes());
                }
            }
            let dec = Message::decode(&frame);
            if ver != METRICS_VERSION
                || n as usize > MAX_METRICS_ROWS
                || !rows_ok
            {
                prop_assert!(dec.is_err(),
                             "hostile metrics (ver {ver}, rows {n}) \
                              decoded");
            } else {
                let msg = dec.map_err(|e| format!("well-formed \
                    metrics frame rejected: {e}"))?;
                prop_assert!(msg.encode() == frame,
                             "metrics roundtrip drifted");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_encode_into_agrees_with_encode() {
        prop::check("encode_into == 4-byte len + encode", |rng| {
            let rows = 1 + rng.gen_range(8) as usize;
            let cols = 1 + rng.gen_range(8) as usize;
            let v: Vec<f32> =
                (0..rows * cols).map(|_| rng.next_normal()).collect();
            let msg = Message::Derivative {
                round: rng.next_u64(),
                tensor: Tensor::f32(vec![rows, cols], v),
            };
            let mut framed = Vec::new();
            msg.encode_into(&mut framed);
            let body = msg.encode();
            prop_assert!(framed.len() == body.len() + 4,
                         "framed length mismatch");
            prop_assert!(&framed[..4] == (body.len() as u32)
                             .to_le_bytes().as_slice(),
                         "length word mismatch");
            prop_assert!(&framed[4..] == &body[..], "body mismatch");
            Ok(())
        });
    }
}
