//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! subcommands, and generated `--help` text. Used by the main binary, the
//! examples and the bench harnesses.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

pub struct Cli {
    pub bin: &'static str,
    pub about: &'static str,
    specs: Vec<ArgSpec>,
}

impl Cli {
    pub fn new(bin: &'static str, about: &'static str) -> Self {
        Cli { bin, about, specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &str,
               help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOPTIONS:\n", self.bin, self.about);
        for spec in &self.specs {
            let tail = if spec.is_flag {
                String::new()
            } else if let Some(d) = &spec.default {
                format!(" (default: {d})")
            } else {
                " (required)".to_string()
            };
            s.push_str(&format!("  --{:<18} {}{}\n", spec.name, spec.help,
                                tail));
        }
        s
    }

    /// Parse an argv slice (without the program name). Prints help and
    /// exits on `--help`. Errors on unknown options or missing required
    /// values.
    pub fn parse(&self, argv: &[String]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                print!("{}", self.help_text());
                std::process::exit(0);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key}"))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        anyhow::bail!("flag --{key} takes no value");
                    }
                    args.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .ok_or_else(|| {
                                    anyhow::anyhow!("--{key} needs a value")
                                })?
                                .clone()
                        }
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        // Fill defaults, check required.
        for spec in &self.specs {
            if spec.is_flag || args.values.contains_key(spec.name) {
                continue;
            }
            match &spec.default {
                Some(d) => {
                    args.values.insert(spec.name.to_string(), d.clone());
                }
                None => anyhow::bail!("missing required option --{}",
                                      spec.name),
            }
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, key: &str) -> &str {
        self.values
            .get(key)
            .unwrap_or_else(|| panic!("option {key} not declared"))
    }

    pub fn get_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .parse()
            .map_err(|e| anyhow::anyhow!("--{key}: {e}"))
    }

    pub fn get_u64(&self, key: &str) -> anyhow::Result<u64> {
        self.get(key)
            .parse()
            .map_err(|e| anyhow::anyhow!("--{key}: {e}"))
    }

    pub fn get_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .parse()
            .map_err(|e| anyhow::anyhow!("--{key}: {e}"))
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("rounds", "100", "number of rounds")
            .req("config", "config path")
            .flag("verbose", "chatty")
    }

    #[test]
    fn parses_values_flags_positionals() {
        let a = cli()
            .parse(&argv(&["--config", "c.toml", "--rounds=7", "--verbose",
                           "extra"]))
            .unwrap();
        assert_eq!(a.get("config"), "c.toml");
        assert_eq!(a.get_usize("rounds").unwrap(), 7);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults_and_required() {
        let a = cli().parse(&argv(&["--config", "x"])).unwrap();
        assert_eq!(a.get_usize("rounds").unwrap(), 100);
        assert!(!a.has_flag("verbose"));
        assert!(cli().parse(&argv(&[])).is_err());
    }

    #[test]
    fn rejects_unknown() {
        assert!(cli().parse(&argv(&["--config", "x", "--nope", "1"])).is_err());
    }
}
