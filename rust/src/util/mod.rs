//! From-scratch substrates: PRNG, JSON, CLI, logging, statistics.
//!
//! The offline build environment ships only the `xla` crate's dependency
//! closure, so the usual ecosystem crates (rand, serde, clap, criterion,
//! proptest) are reimplemented here at the scale this project needs.

pub mod cli;
pub mod json;
pub mod logger;
pub mod rng;
pub mod stats;
