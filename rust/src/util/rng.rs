//! Deterministic PRNG (PCG-XSH-RR 64/32) built from scratch.
//!
//! The crates.io `rand` family is unavailable offline, and determinism
//! across the two parties is load-bearing: the paper's data-management
//! protocol (§2.1) has both parties sample mini-batches *with the same
//! seed* so that post-PSI-aligned instances stay aligned. A tiny,
//! fully-specified generator we control end-to-end is therefore safer
//! than a vendored dependency.

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit xorshift-rotate output.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Seeded constructor; `stream` selects one of 2^63 independent
    /// sequences (used to derive per-field / per-party substreams).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Single-stream constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa bits → exact dyadic rationals in [0,1).
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) via Lemire's multiply-shift with
    /// rejection (unbiased).
    pub fn gen_range(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "gen_range bound must be positive");
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box–Muller (we never need more than ~1e8 draws;
    /// numerical tail quality is irrelevant here).
    pub fn next_normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::seeded(42);
        let mut b = Pcg::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg::new(42, 1);
        let mut b = Pcg::new(42, 2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn f32_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg::seeded(7);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_range_unbiased_small_bound() {
        let mut rng = Pcg::seeded(9);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.gen_range(3) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::seeded(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.next_normal() as f64;
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::seeded(13);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
