//! Minimal `log` facade backend: timestamped stderr lines, level filtered
//! by `CELU_LOG` (error|warn|info|debug|trace; default info).

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::time::Instant;

struct StderrLogger {
    start: once_cell::sync::Lazy<Instant>,
}

static LOGGER: StderrLogger =
    StderrLogger { start: once_cell::sync::Lazy::new(Instant::now) };

impl Log for StderrLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = (*self.start).elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent; safe to call from tests and examples).
pub fn init() {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        let filter = match std::env::var("CELU_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            _ => LevelFilter::Info,
        };
        let _ = log::set_logger(&LOGGER);
        log::set_max_level(filter);
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
