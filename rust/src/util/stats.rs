//! Small statistics helpers used across metrics and the experiment
//! harnesses (mean ± stddev over trials, quantiles, online summaries).

/// Mean and (population) standard deviation of a sample; (0, 0) if empty.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Linear-interpolated quantile (q in [0,1]) of an unsorted sample.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty sample");
    assert!((0.0..=1.0).contains(&q), "q out of range");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Online mean/min/max accumulator (constant memory).
#[derive(Debug, Clone, Default)]
pub struct Running {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        self.sum += x;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

/// Exponential moving average with bias correction (loss smoothing).
#[derive(Debug, Clone)]
pub struct Ema {
    beta: f64,
    value: f64,
    steps: u64,
}

impl Ema {
    pub fn new(beta: f64) -> Self {
        assert!((0.0..1.0).contains(&beta));
        Ema { beta, value: 0.0, steps: 0 }
    }

    pub fn push(&mut self, x: f64) {
        self.value = self.beta * self.value + (1.0 - self.beta) * x;
        self.steps += 1;
    }

    pub fn get(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.value / (1.0 - self.beta.powi(self.steps as i32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn running_tracks_extremes() {
        let mut r = Running::default();
        for x in [3.0, -1.0, 7.0] {
            r.push(x);
        }
        assert_eq!(r.min, -1.0);
        assert_eq!(r.max, 7.0);
        assert!((r.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn ema_converges_to_constant() {
        let mut e = Ema::new(0.9);
        for _ in 0..200 {
            e.push(5.0);
        }
        assert!((e.get() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn ema_bias_corrected_early() {
        let mut e = Ema::new(0.99);
        e.push(3.0);
        assert!((e.get() - 3.0).abs() < 1e-9);
    }
}

#[cfg(test)]
mod prop_tests {
    use super::*;
    use crate::testing::prop;
    use crate::prop_assert;

    #[test]
    fn prop_quantile_bounds_and_monotonicity() {
        prop::check("quantile within [min,max], monotone in q", |rng| {
            let n = 1 + rng.gen_range(50) as usize;
            let xs: Vec<f64> =
                (0..n).map(|_| rng.next_normal() as f64).collect();
            let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let mut prev = lo;
            for i in 0..=10 {
                let q = quantile(&xs, i as f64 / 10.0);
                prop_assert!(q >= lo - 1e-12 && q <= hi + 1e-12,
                             "q out of bounds");
                prop_assert!(q >= prev - 1e-12, "quantile not monotone");
                prev = q;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_mean_std_shift_invariance() {
        prop::check("std invariant under shift", |rng| {
            let n = 2 + rng.gen_range(40) as usize;
            let xs: Vec<f64> =
                (0..n).map(|_| rng.next_normal() as f64).collect();
            let shifted: Vec<f64> = xs.iter().map(|x| x + 42.0).collect();
            let (m1, s1) = mean_std(&xs);
            let (m2, s2) = mean_std(&shifted);
            prop_assert!((m2 - m1 - 42.0).abs() < 1e-9, "mean shift wrong");
            prop_assert!((s2 - s1).abs() < 1e-9, "std not shift-invariant");
            Ok(())
        });
    }

    #[test]
    fn prop_running_matches_batch() {
        prop::check("running mean == batch mean", |rng| {
            let n = 1 + rng.gen_range(60) as usize;
            let xs: Vec<f64> =
                (0..n).map(|_| rng.next_normal() as f64).collect();
            let mut r = Running::default();
            for &x in &xs {
                r.push(x);
            }
            let (m, _) = mean_std(&xs);
            prop_assert!((r.mean() - m).abs() < 1e-9, "mean mismatch");
            prop_assert!(r.n == n as u64, "count mismatch");
            Ok(())
        });
    }
}
