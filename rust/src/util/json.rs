//! Minimal JSON parser + writer built from scratch (serde is unavailable
//! offline). Scope: everything the artifact manifests and the metrics
//! emitters need — objects, arrays, strings (with escapes), numbers,
//! booleans, null. Not a general-purpose validating parser, but strict
//! enough to reject malformed manifests loudly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors (loud failures beat silent defaults) ------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn expect(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => anyhow::bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> anyhow::Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            anyhow::bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => anyhow::bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> anyhow::Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => anyhow::bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> anyhow::Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => anyhow::bail!("expected object, got {self:?}"),
        }
    }

    // -- writer -----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builder for metrics emitters.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    escape_into(out, s);
    out.push('"');
}

/// Append `s` to `out` with every JSON-significant character escaped
/// (quotes, backslashes, and control characters — the latter as `\n` /
/// `\r` / `\t` or `\u00XX`). This is the one escaping routine every
/// artifact and exporter in the crate must route hostile strings
/// through: OS error messages, checkpoint paths, and event payloads
/// all reach JSON output via this function, so a quote or newline in
/// an error string can never produce an invalid document.
pub fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
}

/// [`escape_into`] returning a fresh `String` (without surrounding
/// quotes).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    escape_into(&mut out, s);
    out
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogates unsupported (manifests are ASCII).
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // UTF-8 passthrough: find char boundary.
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len()
                        && (self.bytes[self.pos] & 0xC0) == 0x80
                    {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.'
            || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{
          "abi_version": 1, "model": "wdl", "batch": 256,
          "params_a": [{"name": "emb", "shape": [2600, 8], "init": "normal_0.01"}],
          "files": {"a_fwd": "a_fwd.hlo.txt"}
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.expect("abi_version").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.expect("model").unwrap().as_str().unwrap(), "wdl");
        let p0 = &j.expect("params_a").unwrap().as_arr().unwrap()[0];
        assert_eq!(p0.expect("shape").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            j.expect("files").unwrap().expect("a_fwd").unwrap()
                .as_str().unwrap(),
            "a_fwd.hlo.txt"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3],"b":"x\"y\n","c":true,"d":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\"}", "tru", "1.2.3", "{\"a\":1}x"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn escapes_in_writer() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn escape_helper_neutralizes_hostile_strings() {
        // The public helper is what exporters reach for; its output
        // must embed into a JSON document verbatim.
        let hostile = "disk \"full\"\\path\nline2\r\tok\u{1}";
        let escaped = escape(hostile);
        assert!(!escaped.contains('\n') && !escaped.contains('\r'));
        let doc = format!("{{\"e\":\"{escaped}\"}}");
        let parsed = Json::parse(&doc).unwrap();
        assert_eq!(parsed.expect("e").unwrap().as_str().unwrap(), hostile);
        // And it matches the writer's own escaping exactly.
        assert_eq!(format!("\"{escaped}\""),
                   Json::Str(hostile.into()).to_string());
    }

    #[test]
    fn numbers_roundtrip() {
        for s in ["0", "-1", "3.25", "1e3", "-2.5e-2"] {
            let j = Json::parse(s).unwrap();
            let v = j.as_f64().unwrap();
            assert_eq!(Json::parse(&j.to_string()).unwrap().as_f64().unwrap(), v);
        }
    }
}

#[cfg(test)]
mod fuzz_tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn prop_parse_never_panics_on_garbage() {
        prop::check("json parse total", |rng| {
            let len = rng.gen_range(48) as usize;
            let s: String = (0..len)
                .map(|_| {
                    let c = b" {}[]\",:0123456789.truefalsnl\\eE+-";
                    c[rng.gen_range(c.len() as u32) as usize] as char
                })
                .collect();
            let _ = Json::parse(&s);
            Ok(())
        });
    }

    #[test]
    fn prop_writer_output_always_reparses() {
        prop::check("json writer reparses", |rng| {
            fn gen(rng: &mut crate::util::rng::Pcg, depth: u32) -> Json {
                match if depth > 2 { rng.gen_range(4) }
                      else { rng.gen_range(6) } {
                    0 => Json::Null,
                    1 => Json::Bool(rng.next_f32() < 0.5),
                    2 => Json::Num((rng.next_normal() * 100.0) as f64),
                    3 => Json::Str(
                        (0..rng.gen_range(8))
                            .map(|_| {
                                let c = b"ab\"\\\n\tz";
                                c[rng.gen_range(c.len() as u32) as usize]
                                    as char
                            })
                            .collect()),
                    4 => Json::Arr((0..rng.gen_range(4))
                        .map(|_| gen(rng, depth + 1)).collect()),
                    _ => Json::Obj((0..rng.gen_range(4))
                        .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                        .collect()),
                }
            }
            let j = gen(rng, 0);
            let parsed = Json::parse(&j.to_string())
                .map_err(|e| format!("writer output unparseable: {e}"))?;
            if parsed != j {
                return Err(format!("roundtrip mismatch: {j:?}"));
            }
            Ok(())
        });
    }
}
