//! `celu-vfl` — CLI launcher for the CELU-VFL training framework.
//!
//! Subcommands:
//!   train   run a K-party training job in-process (simulated WAN;
//!           --parties 2 is the classic two-party run)
//!   party   run one party of a K-process TCP session (the label party
//!           is the session server; feature parties dial in and claim
//!           an id via the Join handshake — DESIGN.md §7)
//!   serve   host many concurrent sessions behind one listener: the
//!           multi-session service plane routes every session's
//!           bootstrap, rejoins and scrapes by session epoch
//!           (DESIGN.md §11)
//!   watch   attach to a running session's observability plane and
//!           render live per-link gauges from its tag-14 metric stream
//!           (DESIGN.md §10)
//!   campaign  sweep seeded chaos fault-plans over real sessions,
//!           judge each against round-parity / byte-identity /
//!           no-hang oracles, and shrink failing seeds to minimal
//!           `FaultPlan` reproducers (DESIGN.md §13)
//!   info    print artifact/manifest information
//!
//! Examples:
//!   celu-vfl train --config configs/quickstart.toml
//!   celu-vfl train --algorithm celu --r 5 --w 5 --xi 60 --rounds 2000
//!   celu-vfl train --parties 3 --rounds 500
//!   # K=3 over TCP, one shell per party (any launch order):
//!   celu-vfl party --role label   --parties 3 --listen 0.0.0.0:7000
//!   celu-vfl party --role feature --parties 3 --party 1 --connect host:7000
//!   celu-vfl party --role feature --parties 3 --party 2 --connect host:7000
//!   # From a fourth shell, live link totals off the same port:
//!   celu-vfl watch --connect host:7000
//!   # Nightly-style chaos sweep, reproducible from the root seed:
//!   celu-vfl campaign --seeds 8 --root-seed 42 --shrink \
//!            --report campaign.json
//!   celu-vfl info --artifacts artifacts

use celu_vfl::compress::CodecKind;
use celu_vfl::config::{Algorithm, DataFormat, RunConfig};
use celu_vfl::coordinator::run_training;
use celu_vfl::util::cli::Cli;
use celu_vfl::util::logger;

fn main() {
    logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&argv[1..]),
        Some("party") => cmd_party(&argv[1..]),
        Some("serve") => cmd_serve(&argv[1..]),
        Some("watch") => cmd_watch(&argv[1..]),
        Some("campaign") => cmd_campaign(&argv[1..]),
        Some("info") => cmd_info(&argv[1..]),
        _ => {
            eprintln!(
                "usage: celu-vfl <train|party|serve|watch|campaign|\
                 info> [options]\n\
                 run `celu-vfl <cmd> --help` for details"
            );
            Err(anyhow::anyhow!("no subcommand"))
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        1
    });
    std::process::exit(code);
}

/// Apply common CLI overrides on top of a (possibly file-loaded) config.
fn apply_overrides(cfg: &mut RunConfig,
                   args: &celu_vfl::util::cli::Args) -> anyhow::Result<()> {
    let ov = |v: &str| v != "-";
    if ov(args.get("algorithm")) {
        cfg.algorithm = Algorithm::parse(args.get("algorithm"))?;
    }
    if ov(args.get("model")) {
        cfg.model = args.get("model").to_string();
    }
    if ov(args.get("dataset")) {
        cfg.dataset = args.get("dataset").to_string();
    }
    if ov(args.get("size")) {
        cfg.size = args.get("size").to_string();
    }
    if ov(args.get("r")) {
        cfg.r_local = args.get_usize("r")?;
    }
    if ov(args.get("w")) {
        cfg.w_workset = args.get_usize("w")?;
    }
    if ov(args.get("xi")) {
        cfg.xi_degrees = args.get_f64("xi")?;
    }
    if ov(args.get("compress")) {
        cfg.compress = CodecKind::parse(args.get("compress"))?;
    }
    if ov(args.get("parties")) {
        cfg.parties = args.get_usize("parties")?;
    }
    if ov(args.get("rounds")) {
        cfg.max_rounds = args.get_usize("rounds")?;
    }
    if ov(args.get("lr")) {
        cfg.lr = args.get_f64("lr")?;
    }
    if ov(args.get("seed")) {
        cfg.seed = args.get_u64("seed")?;
    }
    if ov(args.get("target-auc")) {
        cfg.target_auc = args.get_f64("target-auc")?;
    }
    if ov(args.get("bandwidth")) {
        cfg.wan.bandwidth_mbps = args.get_f64("bandwidth")?;
    }
    if ov(args.get("straggler-wait-ms")) {
        cfg.straggler_wait_ms = args.get_u64("straggler-wait-ms")?;
    }
    if ov(args.get("checkpoint-dir")) {
        cfg.checkpoint_dir = args.get("checkpoint-dir").to_string();
    }
    if ov(args.get("checkpoint-every")) {
        cfg.checkpoint_every = args.get_usize("checkpoint-every")?;
    }
    if ov(args.get("data")) {
        cfg.data = args.get("data").to_string();
    }
    if ov(args.get("data-format")) {
        cfg.data_format = DataFormat::parse(args.get("data-format"))?;
    }
    if ov(args.get("chunk-rows")) {
        cfg.chunk_rows = args.get_usize("chunk-rows")?;
    }
    if ov(args.get("overlap")) {
        cfg.overlap = args.get_f64("overlap")?;
    }
    if ov(args.get("ssl-ratio")) {
        cfg.ssl_ratio = args.get_usize("ssl-ratio")?;
    }
    cfg.validate()
}

fn train_cli(bin: &'static str, about: &'static str) -> Cli {
    Cli::new(bin, about)
        .opt("config", "-", "TOML config file (defaults applied otherwise)")
        .opt("algorithm", "-", "vanilla | fedbcd | celu")
        .opt("model", "-", "wdl | dssm")
        .opt("dataset", "-", "criteo | avazu | d3")
        .opt("size", "-", "tiny | small | big | paper")
        .opt("r", "-", "local updates per cached batch (R)")
        .opt("w", "-", "workset capacity (W)")
        .opt("xi", "-", "weighting threshold ξ in degrees (180 = off)")
        .opt("compress", "-",
             "statistics wire codec: none | fp16 | int8 | topk:<k>")
        .opt("parties", "-",
             "total parties incl. the label party (2 = classic)")
        .opt("rounds", "-", "max communication rounds")
        .opt("lr", "-", "AdaGrad learning rate")
        .opt("seed", "-", "PRNG seed")
        .opt("target-auc", "-", "stop when validation AUC reaches this")
        .opt("bandwidth", "-", "simulated WAN bandwidth in Mbps (0 = off)")
        .opt("straggler-wait-ms", "-",
             "bounded per-lane wait before stepping on stale stats \
              (0 = block forever)")
        .opt("checkpoint-dir", "-",
             "write restartable label-party snapshots here")
        .opt("checkpoint-every", "-",
             "rounds between checkpoints (with --checkpoint-dir)")
        .opt("data", "-",
             "on-disk dataset to stream (with --data-format csv|libsvm)")
        .opt("data-format", "-", "csv | libsvm | synthetic")
        .opt("chunk-rows", "-",
             "rows per streamed window (the per-party memory bound)")
        .opt("overlap", "-",
             "aligned (PSI-intersection) row fraction in (0, 1]; \
              below 1 feature parties run self-supervised local \
              updates on their unaligned rows")
        .opt("ssl-ratio", "-",
             "self-supervised updates per communication round on \
              unaligned rows (0 = off)")
        .opt("out", "-", "write the run record JSON here")
}

fn load_config(args: &celu_vfl::util::cli::Args) -> anyhow::Result<RunConfig> {
    let mut cfg = match args.get("config") {
        "-" => RunConfig::quick(),
        path => RunConfig::from_toml_file(path)?,
    };
    apply_overrides(&mut cfg, args)?;
    Ok(cfg)
}

fn cmd_train(argv: &[String]) -> anyhow::Result<()> {
    let cli = train_cli("celu-vfl train", "two-party VFL training run");
    let args = cli.parse(argv)?;
    let cfg = load_config(&args)?;
    log::info!(
        "training {}/{} algo={} parties={} R={} W={} ξ={}° compress={} \
         lr={} rounds={}",
        cfg.model, cfg.dataset, cfg.algorithm.name(), cfg.parties,
        cfg.effective_r(), cfg.effective_w(), cfg.xi_degrees,
        cfg.compress.label(), cfg.lr, cfg.max_rounds
    );
    let outcome = run_training(&cfg)?;
    let rec = &outcome.record;
    println!(
        "done: rounds={} best_auc={:.4} wall={:.1}s comm_busy={:.1}s \
         local_updates={} stop={:?}",
        rec.comm_rounds,
        rec.best_auc(),
        rec.wall.as_secs_f64(),
        rec.comm_busy.as_secs_f64(),
        rec.local_updates,
        outcome.stop_reason
    );
    if args.get("out") != "-" {
        std::fs::write(args.get("out"), rec.to_json().to_string())?;
        log::info!("wrote run record to {}", args.get("out"));
    }
    Ok(())
}

fn cmd_party(argv: &[String]) -> anyhow::Result<()> {
    let cli = train_cli("celu-vfl party",
                        "one party of a K-process TCP session")
        .req("role", "label | feature (aliases: b | a)")
        .opt("listen", "127.0.0.1:7001",
             "label: address the session listener binds")
        .opt("connect", "127.0.0.1:7001",
             "feature: the label party's listener address")
        .opt("party", "1", "feature: this party's id (1..parties)")
        .opt("join-timeout", "30",
             "seconds to wait for the full mesh to assemble")
        .opt("resume", "-",
             "restart from this checkpoint snapshot — label: session \
              snapshot, dialers Rejoin into the resumed session; \
              feature: this party's own snapshot, it Rejoins with its \
              model state restored");
    let args = cli.parse(argv)?;
    let cfg = load_config(&args)?;
    let timeout = args.get_f64("join-timeout")?;
    // Finite + bounded before Duration::from_secs_f64, which panics on
    // inf/overflow instead of erroring.
    anyhow::ensure!(
        timeout > 0.0 && timeout <= 86_400.0,
        "--join-timeout must be in (0, 86400] seconds, got {timeout}"
    );
    // Range-check before the u16 cast: a fat-fingered id must fail
    // here, not silently wrap onto another party's slot and get that
    // party rejected as a duplicate.
    let party = args.get_usize("party")?;
    anyhow::ensure!(
        party <= u16::MAX as usize,
        "--party {party} does not fit a party id (max {})", u16::MAX
    );
    celu_vfl::experiments::tcp::run_tcp_party(
        &cfg,
        args.get("role"),
        args.get("listen"),
        args.get("connect"),
        party as u16,
        std::time::Duration::from_secs_f64(timeout),
        args.get("resume"),
    )
}

fn cmd_serve(argv: &[String]) -> anyhow::Result<()> {
    let cli = train_cli("celu-vfl serve",
                        "host many concurrent sessions on one listener")
        .opt("listen", "127.0.0.1:7001",
             "address the multi-session server binds")
        .opt("sessions", "1",
             "what to host: a session count (seeds --seed, --seed+1, …) \
              or a comma-separated seed list ('7,11,13') — dialers must \
              be launched with the matching --seed")
        .opt("join-timeout", "30",
             "seconds each session's mesh gets to assemble")
        .opt("cache-budget", "0",
             "global workset residency cap in cached rounds×lanes \
              shared by every hosted session (0 = per-session W \
              bounds only)");
    let args = cli.parse(argv)?;
    let cfg = load_config(&args)?;
    let timeout = args.get_f64("join-timeout")?;
    anyhow::ensure!(
        timeout > 0.0 && timeout <= 86_400.0,
        "--join-timeout must be in (0, 86400] seconds, got {timeout}"
    );
    celu_vfl::experiments::serve::run_serve(
        &cfg,
        args.get("listen"),
        args.get("sessions"),
        std::time::Duration::from_secs_f64(timeout),
        args.get_usize("cache-budget")?,
    )
}

fn cmd_watch(argv: &[String]) -> anyhow::Result<()> {
    use celu_vfl::metrics::exporters::push::{frame_rows,
                                             read_metrics_frame};
    use std::io::Write as _;

    let cli = Cli::new("celu-vfl watch",
                       "live per-link gauges from a running session")
        .opt("connect", "127.0.0.1:7001",
             "the label party's session listener address");
    let args = cli.parse(argv)?;
    let addr = args.get("connect");
    let mut stream = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))?;
    stream.write_all(b"GET /watch HTTP/1.0\r\n\r\n")?;
    stream.flush()?;
    println!(
        "watching {addr}: one cumulative frame per tick, one line per \
         directed link (Ctrl-C to detach)"
    );
    let mut frames = 0u64;
    loop {
        let msg = match read_metrics_frame(&mut stream) {
            Ok(m) => m,
            // The very first read failing means the peer refused the
            // stream (bootstrap-phase 503, no registry, or not a
            // session port at all) — that is an error, not an ending.
            Err(e) if frames == 0 => {
                return Err(anyhow::anyhow!(
                    "no metric stream from {addr}: {e:#} — is a \
                     supervised session live on that port?"
                ))
            }
            // After that, EOF is the session ending; the last frame
            // already carried the final totals.
            Err(_) => break,
        };
        frames += 1;
        for (src, dst, s) in frame_rows(&msg) {
            println!(
                "round={:<8} {}->{} msgs={:<8} wire={:<12} raw={:<12} \
                 busy={:.3}s ratio={:.2}",
                msg.round(), src.0, dst.0, s.messages, s.bytes,
                s.raw_bytes, s.busy.as_secs_f64(),
                s.compression_ratio()
            );
        }
    }
    println!("session ended after {frames} frames — totals above are \
              final");
    Ok(())
}

fn cmd_campaign(argv: &[String]) -> anyhow::Result<()> {
    use celu_vfl::campaign::{run_campaign, CampaignOpts, Scenario};

    let cli = Cli::new("celu-vfl campaign",
                       "seeded chaos sweep over real sessions")
        .opt("scenarios", "all",
             "comma-separated scenario list (single, multi, reorder, \
              codec, kill, rejoin-abort, serve) or 'all'")
        .opt("seeds", "4", "cases per scenario (indices 0..N)")
        .opt("root-seed", "42",
             "campaign root seed — every case re-derives from \
              (root seed, scenario, index) alone")
        .opt("budget-ms", "20000",
             "per-case wall-clock budget (the no-hang oracle)")
        .opt("report", "-", "write the JSON campaign report here")
        .flag("shrink",
              "delta-debug failing cases to minimal reproducers");
    let args = cli.parse(argv)?;
    let scenarios = match args.get("scenarios") {
        "all" => Scenario::all().to_vec(),
        list => list
            .split(',')
            .map(|s| Scenario::parse(s.trim()))
            .collect::<anyhow::Result<Vec<_>>>()?,
    };
    let budget_ms = args.get_u64("budget-ms")?;
    anyhow::ensure!(budget_ms > 0, "--budget-ms must be positive");
    let opts = CampaignOpts {
        scenarios,
        seeds: args.get_u64("seeds")?,
        root_seed: args.get_u64("root-seed")?,
        budget: std::time::Duration::from_millis(budget_ms),
        shrink: args.has_flag("shrink"),
    };
    let started = std::time::Instant::now();
    let report = run_campaign(&opts);
    // Wall-clock chatter goes to stderr: stdout and the JSON artifact
    // stay byte-reproducible for a fixed (scenarios, seeds, root seed).
    eprintln!("campaign wall time: {:.1}s",
              started.elapsed().as_secs_f64());
    print!("{}", report.summary_table());
    if report.failed() > 0 {
        print!("{}", report.failure_details());
    }
    if args.get("report") != "-" {
        std::fs::write(args.get("report"),
                       report.to_json().to_string())?;
        log::info!("wrote campaign report to {}", args.get("report"));
    }
    anyhow::ensure!(
        report.failed() == 0,
        "{} of {} chaos cases failed (reproducers above; rerun with \
         --root-seed {} and --shrink for minimal plans)",
        report.failed(), report.cases.len(), report.root_seed
    );
    Ok(())
}

fn cmd_info(argv: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("celu-vfl info", "inspect artifact sets")
        .opt("artifacts", "artifacts", "artifact root directory");
    let args = cli.parse(argv)?;
    let root = std::path::Path::new(args.get("artifacts"));
    anyhow::ensure!(root.is_dir(), "no artifact dir at {root:?} — run \
                                    `make artifacts`");
    println!("{:<24} {:>8} {:>6} {:>10} {:>8}", "set", "batch", "z_dim",
             "params", "fields");
    let mut entries: Vec<_> = std::fs::read_dir(root)?
        .filter_map(|e| e.ok())
        .filter(|e| e.path().join("manifest.json").exists())
        .collect();
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let m = celu_vfl::runtime::Manifest::load(&e.path())?;
        println!(
            "{:<24} {:>8} {:>6} {:>10} {:>5}/{:<3}",
            e.file_name().to_string_lossy(),
            m.batch,
            m.z_dim,
            m.total_params(),
            m.fields_a,
            m.fields_b
        );
    }
    Ok(())
}
