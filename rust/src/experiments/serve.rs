//! Multi-session deployment: `celu-vfl serve` — one label-party
//! process hosting many concurrent training sessions (DESIGN.md §11).
//!
//! Where `celu-vfl party --role label` is a single-tenant server (bind,
//! admit one mesh, train, exit), `serve` binds once and multiplexes:
//! every session in `--sessions` gets its own registry, its own
//! re-admission point, and its own label-party training loop on a
//! dedicated thread, while one reactor routes all of their bootstraps,
//! rejoins and observability scrapes. Sessions share the base config
//! and differ by seed — the seed derives the session epoch that
//! `Rejoin` frames route by, so every dialer must be launched with the
//! matching `--seed`. Worksets across sessions share one optional
//! global [`CacheBudget`] (`--cache-budget`), bounding the process's
//! total cached rounds while each session keeps its own W bound.
//!
//!     celu-vfl serve --listen 0.0.0.0:7000 --parties 3 --sessions 7,11
//!     celu-vfl party --role feature --parties 3 --party 1 \
//!         --seed 7 --connect host:7000     # one dialer per session id

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::RunConfig;
use crate::coordinator::label_party::LabelRunOpts;
use crate::coordinator::trainer::{load_data, load_set};
use crate::session::server::{SessionHandle, SessionServer};
use crate::session::{SessionBuilder, LABEL_PARTY};
use crate::workset::CacheBudget;

/// Parse the `--sessions` spec: either a session *count* (`"3"` hosts
/// seeds `base..base+2`) or an explicit comma-separated seed list
/// (`"7,11,13"`).
pub fn parse_sessions(spec: &str, base_seed: u64)
                      -> anyhow::Result<Vec<u64>> {
    let seeds: Vec<u64> = if spec.contains(',') {
        spec.split(',')
            .map(|s| s.trim().parse::<u64>().map_err(|e| {
                anyhow::anyhow!("bad seed '{s}' in --sessions: {e}")
            }))
            .collect::<anyhow::Result<_>>()?
    } else {
        let n: u64 = spec.trim().parse().map_err(|e| {
            anyhow::anyhow!("--sessions must be a count or a \
                             comma-separated seed list, got '{spec}': {e}")
        })?;
        anyhow::ensure!(n >= 1, "--sessions must host at least one");
        (0..n).map(|i| base_seed + i).collect()
    };
    anyhow::ensure!(!seeds.is_empty(), "--sessions names no sessions");
    Ok(seeds)
}

/// Host one training session per seed on a single server socket and
/// run them all to completion.
pub fn run_serve(cfg: &RunConfig, listen: &str, sessions: &str,
                 join_timeout: Duration, cache_budget: usize)
                 -> anyhow::Result<()> {
    cfg.validate()?;
    let seeds = parse_sessions(sessions, cfg.seed)?;
    let mut server = SessionServer::bind(listen)?
        .with_join_timeout(join_timeout)
        .with_auth_token(&cfg.metrics_token);
    if cache_budget > 0 {
        server = server.with_cache_budget(CacheBudget::new(cache_budget));
    }
    for &seed in &seeds {
        let mut scfg = cfg.clone();
        scfg.seed = seed;
        let epoch = server.host(scfg)?;
        log::info!("hosting session seed={seed} epoch={epoch:#010x}");
    }
    println!("serving {} sessions on {}", seeds.len(),
             server.local_addr()?);
    let start = Instant::now();
    let outcomes = server.serve(run_hosted_label)?;
    let wall = start.elapsed().as_secs_f64();
    let ok = outcomes.iter().filter(|o| o.result.is_ok()).count();
    println!(
        "served {}/{} sessions to completion in {wall:.1}s",
        ok, outcomes.len()
    );
    for o in &outcomes {
        if let Err(e) = &o.result {
            log::warn!("session {} failed: {e:#}", o.label);
        }
    }
    anyhow::ensure!(ok == outcomes.len(),
                    "{} of {} sessions failed",
                    outcomes.len() - ok, outcomes.len());
    Ok(())
}

/// The per-session runner: exactly the single-tenant label arm of
/// `celu-vfl party`, fed from a [`SessionHandle`] instead of an owned
/// listener.
fn run_hosted_label(h: SessionHandle) -> anyhow::Result<()> {
    let set = load_set(&h.cfg)?;
    let data = load_data(&h.cfg, &set)?;
    let mut b = SessionBuilder::new(&h.cfg, LABEL_PARTY)
        .with_registry(h.registry.clone());
    for l in h.links {
        b = b.link_full(l);
    }
    let session = b.build()?;
    let report = session.run_label_with(
        set,
        Arc::new(data.train_b),
        Arc::new(data.test_b),
        LabelRunOpts {
            readmission: Some(h.readmission),
            resume: None,
            registry: None, // run_label_with injects the session's own
            cache_budget: h.cache_budget,
        },
    )?;
    let best = report.series.iter().map(|p| p.auc).fold(0.0f64, f64::max);
    println!(
        "SESSION {} done: seed={} rounds={} local_updates={} \
         best_auc={best:.4} stop={:?} rejoins={}",
        h.label, h.cfg.seed, report.comm_rounds, report.local_updates,
        report.stop_reason, report.rejoins
    );
    for row in h.registry.link_rows() {
        let s = row.stats;
        println!(
            "SESSION {} LINK {} {} {} {} {}",
            h.label, row.src.0, row.dst.0, s.bytes, s.raw_bytes,
            s.messages
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sessions_spec_parses_counts_and_seed_lists() {
        assert_eq!(parse_sessions("3", 10).unwrap(), vec![10, 11, 12]);
        assert_eq!(parse_sessions("7,11, 13", 10).unwrap(),
                   vec![7, 11, 13]);
        assert!(parse_sessions("0", 10).is_err());
        assert!(parse_sessions("x", 10).is_err());
        assert!(parse_sessions("7,,9", 10).is_err());
    }
}
