//! Theorem-1 sanity probe: measure ρ (Assumption 1.2) empirically.
//!
//! The convergence bound Δ = L²log(2d/δ)/B·(1+1/W) + σ²(2−ρ) says the
//! approximation error grows as the gradient cosine ρ between the
//! stale-statistics gradient g̃ and the fresh-statistics gradient g drops.
//! This harness trains a model briefly, then measures cos(g̃, g) at Party
//! A as a function of staleness s: it replays the exact protocol, keeps
//! ∇Z_A from s rounds ago, and uses the `a_grad_cos` artifact to compare
//! the gradients both cotangents induce on the *current* params.


use crate::config::RunConfig;
use crate::coordinator::trainer::{load_data, load_set};
use crate::data::batcher::{gather_a, gather_b, BatchCursor};
use crate::runtime::{PartyARuntime, PartyBRuntime};
use crate::util::stats::mean_std;

/// ρ measurements per staleness in `0..=max_staleness`.
pub struct RhoProfile {
    /// (staleness s, mean cos(g̃, g), std).
    pub rows: Vec<(usize, f64, f64)>,
}

pub fn rho_probe(cfg: &RunConfig, warmup_rounds: usize,
                 max_staleness: usize, probes: usize)
                 -> anyhow::Result<RhoProfile> {
    let set = load_set(cfg)?;
    let data = load_data(cfg, &set)?;
    let batch = set.manifest.batch;
    let mut a = PartyARuntime::new(set.clone(), cfg.seed, cfg.lr as f32,
                                   cfg.cos_xi() as f32, false)?;
    let mut b = PartyBRuntime::new(set.clone(), cfg.seed, cfg.lr as f32,
                                   cfg.cos_xi() as f32, false)?;
    let mut cursor = BatchCursor::new(cfg.seed, data.train_a.n, batch);

    // Warm up with vanilla two-phase rounds so gradients are non-trivial.
    let run_round = |a: &mut PartyARuntime, b: &mut PartyBRuntime,
                     cursor: &mut BatchCursor| -> anyhow::Result<()> {
        let idx = cursor.next_indices();
        let xa = gather_a(&data.train_a, &idx);
        let (xb, y) = gather_b(&data.train_b, &idx);
        let za = a.forward(&xa)?;
        let (dza, _loss) = b.exact_step(&xb, &y, &za)?;
        a.exact_update(&xa, &dza)?;
        Ok(())
    };
    for _ in 0..warmup_rounds {
        run_round(&mut a, &mut b, &mut cursor)?;
    }

    // Pin one batch, snapshot its derivatives ∇Z_A^(t0), then keep
    // training on OTHER batches; at each age s measure the cosine between
    // the gradient the stale cotangent induces on the *current* params and
    // the gradient the fresh cotangent (recomputed side-effect-free via
    // `dza_probe`) induces — exactly the g̃-vs-g angle of Assumption 1.2,
    // isolated to the same batch rows.
    let mut rows_acc: Vec<Vec<f64>> = vec![Vec::new(); max_staleness + 1];
    for _ in 0..probes {
        let idx0 = cursor.next_indices();
        let xa0 = gather_a(&data.train_a, &idx0);
        let (xb0, y0) = gather_b(&data.train_b, &idx0);
        let za0 = a.forward(&xa0)?;
        let dza_stale = b.dza_probe(&xb0, &y0, &za0)?;
        for age in 0..=max_staleness {
            // Fresh derivatives for the pinned rows under current params.
            let za_now = a.forward(&xa0)?;
            let dza_fresh = b.dza_probe(&xb0, &y0, &za_now)?;
            let (cos, _n1, _n2) =
                a.grad_cos(&xa0, &dza_fresh, &dza_stale)?;
            rows_acc[age].push(cos as f64);
            if age < max_staleness {
                run_round(&mut a, &mut b, &mut cursor)?;
            }
        }
    }
    let rows = rows_acc
        .into_iter()
        .enumerate()
        .map(|(s, v)| {
            let (m, sd) = mean_std(&v);
            (s, m, sd)
        })
        .collect();
    Ok(RhoProfile { rows })
}

impl RhoProfile {
    pub fn print(&self) {
        println!("{:<12} {:>12} {:>8}", "staleness", "mean cos(g̃,g)",
                 "±std");
        for (s, m, sd) in &self.rows {
            println!("{s:<12} {m:>12.4} {sd:>8.4}");
        }
    }

    /// ρ should (weakly) decrease with staleness — Theorem 1's tradeoff.
    pub fn is_monotone_decreasing(&self, slack: f64) -> bool {
        self.rows
            .windows(2)
            .all(|w| w[1].1 <= w[0].1 + slack)
    }
}
