//! Two-process deployment: run one party over real TCP.
//!
//! The production shape of a VFL job — each enterprise runs its own
//! binary inside its own network perimeter; only `Z`/`∇Z` frames cross
//! the boundary. Both processes must be launched with the same config
//! (model/dataset/size/seed) so the pre-aligned synthetic data and the
//! batch schedule agree, mirroring the paper's post-PSI setup.
//!
//! Roles accept the session vocabulary (`feature` / `label`) as well as
//! the historic two-party aliases (`a` = feature, `b` = label); either
//! way the run goes through the session drivers, so the wire format is
//! the byte-identical two-party stream. Multi-party TCP meshes (a
//! label process accepting K−1 feature connections, identified by
//! their v2 frame headers) are an open ROADMAP item — in-proc K-party
//! runs are already supported by `trainer::run_training`.

use std::sync::Arc;

use crate::config::RunConfig;
use crate::coordinator::{run_party_a, run_party_b};
use crate::coordinator::trainer::{load_data, load_set};
use crate::transport::tcp::TcpTransport;
use crate::transport::Transport;

pub fn run_tcp_party(cfg: &RunConfig, role: &str, listen: &str,
                     connect: &str) -> anyhow::Result<()> {
    cfg.validate()?;
    anyhow::ensure!(
        cfg.parties == 2,
        "TCP deployment currently supports two-party sessions; use the \
         in-proc trainer for --parties {}", cfg.parties
    );
    let set = load_set(cfg)?;
    let data = load_data(cfg, &set)?;
    match role {
        "b" | "label" => {
            let transport: Arc<dyn Transport> =
                Arc::new(TcpTransport::listen(listen, cfg.wan)?);
            let report = run_party_b(
                cfg,
                set,
                Arc::new(data.train_b),
                Arc::new(data.test_b),
                transport.clone(),
            )?;
            let best = report
                .series
                .iter()
                .map(|p| p.auc)
                .fold(0.0f64, f64::max);
            let stats = transport.stats();
            println!(
                "label party done: rounds={} local_updates={} \
                 best_auc={:.4} sent={}B (raw {}B, ratio {:.2}) stop={:?}",
                report.comm_rounds, report.local_updates, best,
                stats.bytes, stats.raw_bytes, stats.compression_ratio(),
                report.stop_reason
            );
        }
        "a" | "feature" => {
            let transport: Arc<dyn Transport> =
                Arc::new(TcpTransport::connect(connect, cfg.wan)?);
            let report = run_party_a(
                cfg,
                set,
                Arc::new(data.train_a),
                Arc::new(data.test_a),
                transport.clone(),
            )?;
            let stats = transport.stats();
            println!(
                "feature party {} done: rounds={} local_updates={} \
                 sent={}B (raw {}B, ratio {:.2})",
                report.party, report.comm_rounds, report.local_updates,
                stats.bytes, stats.raw_bytes, stats.compression_ratio()
            );
        }
        other => anyhow::bail!(
            "role must be 'feature'/'a' or 'label'/'b', got '{other}'"),
    }
    Ok(())
}
