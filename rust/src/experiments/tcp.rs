//! K-process deployment: run one party of a TCP session.
//!
//! The production shape of a VFL job — each enterprise runs its own
//! binary inside its own network perimeter; only `Z`/`∇Z` frames cross
//! the boundary. The label party is the **session server**
//! (`--role label --listen ADDR`): it binds once and accepts K−1
//! `Join`-identified connections (DESIGN.md §7). Each feature party is
//! a dialer (`--role feature --party N --connect ADDR`) that retries
//! with backoff until the label party is up, so the K shells can be
//! launched in any order. Every process must be launched with the same
//! config (model/dataset/size/seed/parties) so the pre-aligned
//! synthetic data and the batch schedule agree, mirroring the paper's
//! post-PSI setup; the bootstrap handshake rejects session-size
//! mismatches outright.
//!
//! Roles accept the session vocabulary (`feature` / `label`) as well as
//! the historic two-party aliases (`a` = feature, `b` = label). With
//! `--parties 2` the training wire is the byte-identical two-party
//! stream (v1 frames); with more parties every link speaks v2
//! (party-addressed) frames and each feature process trains on its own
//! vertical slice of the Party-A feature space — which requires
//! artifacts compiled for the slice width (`aot.py --parties K`).

use std::sync::Arc;
use std::time::Duration;

use crate::config::RunConfig;
use crate::coordinator::trainer::{feature_slices, load_data, load_set};
use crate::session::bootstrap::{SessionDialer, SessionListener};
use crate::session::{PartyId, SessionBuilder};

pub fn run_tcp_party(cfg: &RunConfig, role: &str, listen: &str,
                     connect: &str, party: u16, join_timeout: Duration)
                     -> anyhow::Result<()> {
    cfg.validate()?;
    match role {
        "b" | "label" => {
            // Bind before touching artifacts: dialers can already be
            // retrying, and an artifact error should not look like a
            // dead listener from their side any longer than necessary.
            let listener =
                SessionListener::bind(listen)?.with_timeout(join_timeout);
            log::info!(
                "label party listening on {} for {} feature parties",
                listener.local_addr()?,
                cfg.feature_parties()
            );
            let set = load_set(cfg)?;
            let data = load_data(cfg, &set)?;
            let session = SessionBuilder::from_bootstrap(cfg, listener)?;
            let report = session.run_label(
                set,
                Arc::new(data.train_b),
                Arc::new(data.test_b),
            )?;
            let best = report
                .series
                .iter()
                .map(|p| p.auc)
                .fold(0.0f64, f64::max);
            println!(
                "label party done: parties={} rounds={} local_updates={} \
                 best_auc={:.4} stop={:?}",
                cfg.parties, report.comm_rounds, report.local_updates,
                best, report.stop_reason
            );
            // Per-link accounting keyed by the ids that actually
            // joined — the K-party analogue of the old single-link
            // summary line.
            println!("{:<8} {:>10} {:>10} {:>8} {:>8}", "link",
                     "wire B", "raw B", "msgs", "ratio");
            for (peer, s) in session.mesh().link_stats() {
                println!(
                    "0->{:<5} {:>10} {:>10} {:>8} {:>8.2}",
                    peer.0, s.bytes, s.raw_bytes, s.messages,
                    s.compression_ratio()
                );
            }
        }
        "a" | "feature" => {
            let k = cfg.feature_parties();
            anyhow::ensure!(
                party >= 1 && (party as usize) <= k,
                "--party {party} out of range for --parties {} \
                 (valid feature ids: 1..={k})", cfg.parties
            );
            let set = load_set(cfg)?;
            let data = load_data(cfg, &set)?;
            // Every process computes the same deterministic split and
            // keeps only its own slice — no feature data ever moves.
            let (mut train_slices, mut test_slices) =
                feature_slices(cfg, &set, data.train_a, data.test_a)?;
            let train = Arc::new(train_slices.swap_remove(party as usize - 1));
            let test = Arc::new(test_slices.swap_remove(party as usize - 1));
            let dialer = SessionDialer::new(connect, PartyId(party))
                .with_timeout(join_timeout);
            let session = SessionBuilder::from_bootstrap(cfg, dialer)?;
            let report = session.run_feature(set, train, test)?;
            let stats = session.mesh().links()[0].transport.stats();
            println!(
                "feature party {} done: rounds={} local_updates={} \
                 sent={}B (raw {}B, ratio {:.2})",
                report.party, report.comm_rounds, report.local_updates,
                stats.bytes, stats.raw_bytes, stats.compression_ratio()
            );
        }
        other => anyhow::bail!(
            "role must be 'feature'/'a' or 'label'/'b', got '{other}'"),
    }
    Ok(())
}
