//! K-process deployment: run one party of a TCP session.
//!
//! The production shape of a VFL job — each enterprise runs its own
//! binary inside its own network perimeter; only `Z`/`∇Z` frames cross
//! the boundary. The label party is the **session server**
//! (`--role label --listen ADDR`): it binds once, accepts K−1
//! `Join`-identified connections (DESIGN.md §7), and keeps the
//! listener alive for the rest of the run as the session's
//! *re-admission point* — a feature party that drops mid-session
//! re-dials with `Rejoin` and resumes in place (DESIGN.md §8). Each
//! feature party is a dialer (`--role feature --party N --connect
//! ADDR`) that retries with jittered backoff until the label party is
//! up, so the K shells can be launched in any order. Every process
//! must be launched with the same config
//! (model/dataset/size/seed/parties) so the pre-aligned synthetic data
//! and the batch schedule agree, mirroring the paper's post-PSI setup;
//! the bootstrap handshake rejects session-size mismatches outright.
//!
//! Lifecycle knobs: `--straggler-wait-ms` bounds how long the label
//! party waits per lane before stepping on cached stale statistics;
//! `--checkpoint-dir`/`--checkpoint-every` write restartable snapshots
//! on *every* role (DESIGN.md §8/§9); `--resume <ckpt>` restarts a
//! process from its own snapshot. A resumed label listener expects
//! `Rejoin`s (fresh `celu-vfl party` dialers fall back to `Rejoin`
//! automatically), imports its model state, and continues from the
//! snapshot's round; a resumed feature party `Rejoin`s the live
//! session claiming its snapshot's completed rounds, restores its
//! bottom model + AdaGrad state, pins the snapshot's wire codec, and
//! fast-forwards its deterministic batch cursor to wherever the
//! session is now.
//!
//! Roles accept the session vocabulary (`feature` / `label`) as well as
//! the historic two-party aliases (`a` = feature, `b` = label). With
//! `--parties 2` the training wire is the byte-identical two-party
//! stream (v1 frames); with more parties every link speaks v2
//! (party-addressed) frames and each feature process trains on its own
//! vertical slice of the Party-A feature space — which requires
//! artifacts compiled for the slice width (`aot.py --parties K`).

use std::sync::Arc;
use std::time::Duration;

use crate::config::RunConfig;
use crate::coordinator::feature_party::{FeatureRunOpts, RejoinPolicy};
use crate::coordinator::label_party::LabelRunOpts;
use crate::coordinator::trainer::{feature_memory_plan, feature_slices,
                                  feature_stream_plan, label_memory_plan,
                                  label_stream_plan, load_data, load_set};
use crate::metrics::facade::Registry;
use crate::session::bootstrap::{SessionDialer, SessionListener};
use crate::session::checkpoint::{FeatureSnapshot, SessionSnapshot};
use crate::session::supervisor::session_epoch;
use crate::session::{PartyId, SessionBuilder, LABEL_PARTY};

pub fn run_tcp_party(cfg: &RunConfig, role: &str, listen: &str,
                     connect: &str, party: u16, join_timeout: Duration,
                     resume: &str)
                     -> anyhow::Result<()> {
    cfg.validate()?;
    match role {
        "b" | "label" => {
            // Bind before touching artifacts: dialers can already be
            // retrying, and an artifact error should not look like a
            // dead listener from their side any longer than necessary.
            // The listener doubles as the observability endpoint: a
            // `GET /metrics` on the session port scrapes this registry,
            // `GET /watch` streams tag-14 metric frames (DESIGN.md §10).
            let registry = Registry::new();
            let mut listener = SessionListener::bind(listen)?
                .with_timeout(join_timeout)
                .with_metrics(registry.clone())
                .with_auth_token(&cfg.metrics_token);
            let snapshot = if resume != "-" && !resume.is_empty() {
                let snap = SessionSnapshot::load(resume)?;
                log::info!(
                    "resuming from {resume}: round {}, epoch {:#x}",
                    snap.round, snap.epoch
                );
                listener = listener.with_resume(snap.epoch, snap.round);
                Some(snap)
            } else {
                None
            };
            log::info!(
                "label party listening on {} for {} feature parties",
                listener.local_addr()?,
                cfg.feature_parties()
            );
            let set = load_set(cfg)?;
            // Data plane (DESIGN.md §12): every process builds only its
            // own feed — streaming formats read this party's columns
            // from disk; synthetic materializes and applies the overlap
            // split locally (membership is a pure function of the
            // shared seed, so all K processes agree without a byte).
            let (feed, test_b) = if cfg.data_format.is_streaming() {
                label_stream_plan(cfg, &set)?
            } else {
                let data = load_data(cfg, &set)?;
                label_memory_plan(cfg, &set, data.train_b, data.test_b)?
            };
            let (links, readmission, _epoch, _start_round) =
                listener.establish_supervised(cfg)?;
            let mut b = SessionBuilder::new(cfg, LABEL_PARTY)
                .with_registry(registry.clone());
            for l in links {
                b = b.link_full(l);
            }
            let session = b.build()?;
            let report = session.run_label_data(
                set,
                feed,
                test_b,
                LabelRunOpts {
                    readmission: Some(readmission),
                    resume: snapshot,
                    // run_label_data injects the session registry —
                    // the same one the listener serves scrapes from.
                    registry: None,
                    cache_budget: None,
                },
            )?;
            let best = report
                .series
                .iter()
                .map(|p| p.auc)
                .fold(0.0f64, f64::max);
            let events = registry.events();
            println!(
                "label party done: parties={} rounds={} local_updates={} \
                 best_auc={:.4} stop={:?} rejoins={} events={}",
                cfg.parties, report.comm_rounds, report.local_updates,
                best, report.stop_reason, report.rejoins, events.len()
            );
            for e in &events {
                println!(
                    "event {:<20} round={:<8} party={}",
                    e.kind(),
                    e.round(),
                    e.party().map(|p| p.to_string())
                        .unwrap_or_else(|| "-".into())
                );
            }
            // Per-link accounting keyed by the ids that actually
            // joined, carried across any rejoin transport swaps (the
            // registry rows were charged forward at each swap).
            println!("{:<8} {:>10} {:>10} {:>8} {:>8}", "link",
                     "wire B", "raw B", "msgs", "ratio");
            for row in registry.link_rows() {
                let s = row.stats;
                println!(
                    "{}->{:<4} {:>10} {:>10} {:>8} {:>8.2}",
                    row.src.0, row.dst.0, s.bytes, s.raw_bytes,
                    s.messages, s.compression_ratio()
                );
            }
        }
        "a" | "feature" => {
            let k = cfg.feature_parties();
            anyhow::ensure!(
                party >= 1 && (party as usize) <= k,
                "--party {party} out of range for --parties {} \
                 (valid feature ids: 1..={k})", cfg.parties
            );
            // A feature party's own snapshot (DESIGN.md §9): validate
            // that it belongs to this party and this logical session
            // before any artifact work, so a wrong-file mistake fails
            // in milliseconds.
            let snapshot = if resume != "-" && !resume.is_empty() {
                let snap = FeatureSnapshot::load(resume)?;
                anyhow::ensure!(
                    snap.party == party,
                    "{resume} is party {}'s snapshot, this process is \
                     --party {party}", snap.party
                );
                anyhow::ensure!(
                    snap.parties == cfg.parties as u16,
                    "{resume} is from a {}-party session, this config \
                     says --parties {}", snap.parties, cfg.parties
                );
                anyhow::ensure!(
                    snap.epoch == session_epoch(cfg.seed),
                    "{resume} belongs to a different logical session \
                     (epoch {:#x}, this config derives {:#x}) — \
                     seed/config mismatch?", snap.epoch,
                    session_epoch(cfg.seed)
                );
                log::info!(
                    "resuming from {resume}: round {}, epoch {:#x}",
                    snap.round, snap.epoch
                );
                Some(snap)
            } else {
                None
            };
            anyhow::ensure!(
                !(cfg.data_format.is_streaming() && snapshot.is_some()),
                "--resume requires the in-memory data plane (synthetic \
                 format): streaming feeds cannot replay completed rounds"
            );
            let set = load_set(cfg)?;
            // Every process computes the same deterministic plan and
            // keeps only its own slice — no feature data ever moves.
            // Streaming formats read this party's columns of the file;
            // synthetic splits the generated table vertically.
            let (feed, test) = if cfg.data_format.is_streaming() {
                feature_stream_plan(cfg, &set, party as usize - 1)?
            } else {
                let data = load_data(cfg, &set)?;
                let (mut train_slices, mut test_slices) =
                    feature_slices(cfg, &set, data.train_a, data.test_a)?;
                let train = train_slices.swap_remove(party as usize - 1);
                let test = test_slices.swap_remove(party as usize - 1);
                feature_memory_plan(cfg, &set, train, test)?
            };
            let dialer = SessionDialer::new(connect, PartyId(party))
                .with_timeout(join_timeout);
            // Resumable join: with a snapshot, lead with Rejoin
            // claiming its completed-round cursor; without one, fall
            // back to Rejoin only if the label restarted in resume
            // mode. Either way the returned round is where lock-step
            // actually resumes.
            let (link, start_round) = dialer.establish_resumable_from(
                cfg,
                snapshot.as_ref().map_or(0, |s| s.round),
            )?;
            let session = SessionBuilder::new(cfg, PartyId(party))
                .link_full(link)
                .build()?;
            let report = session.run_feature_data(
                set,
                feed,
                test,
                FeatureRunOpts {
                    rejoin: Some(RejoinPolicy {
                        addr: connect.to_string(),
                        timeout: join_timeout,
                    }),
                    start_round,
                    resume: snapshot,
                    registry: None, // run_feature_data injects
                },
            )?;
            // The session registry's single (party → label) row holds
            // the cumulative accounting, rejoin swaps included.
            let stats = session
                .registry()
                .link_rows()
                .first()
                .map(|r| r.stats)
                .unwrap_or_default();
            println!(
                "feature party {} done: rounds={} local_updates={} \
                 ssl_updates={} rejoins={} sent={}B (raw {}B, \
                 ratio {:.2})",
                report.party, report.comm_rounds, report.local_updates,
                report.ssl_updates, report.rejoins, stats.bytes,
                stats.raw_bytes, stats.compression_ratio()
            );
        }
        other => anyhow::bail!(
            "role must be 'feature'/'a' or 'label'/'b', got '{other}'"),
    }
    Ok(())
}
