//! Ablation & sensitivity harness — Figure 5 and Table 2 of the paper.
//!
//! The paper trains WDL on Criteo and measures the number of
//! communication rounds to a target validation AUC while varying one
//! technique at a time: the local-update count R (Fig 5a), the workset
//! size W with round-robin vs consecutive sampling (Fig 5b), and the
//! instance-weighting threshold ξ (Fig 5c); Fig 5d plots the cosine-
//! similarity quantiles the weighting mechanism sees.
//!
//! Beyond the paper's grid, `sweep_compress` opens the wire-compression
//! scenario (DESIGN.md §5): convergence and bytes-on-wire per codec,
//! with `compression_bytes_per_round` providing the artifact-free
//! protocol-level byte accounting. `sweep_parties` does the same for
//! the session topology (DESIGN.md §6): convergence vs the party count
//! K, with `mesh_bytes_per_round` giving the artifact-free per-link
//! accounting of the K-party star.

use crate::compress::CodecKind;
use crate::config::{Algorithm, RunConfig};
use crate::coordinator::trainer::run_trials;
use crate::protocol::{outbound_stats, Lane, FRAME_V2_OVERHEAD};
use crate::tensor::Tensor;

use super::SweepResult;

/// Run all trials for each (label, config) variant.
pub fn run_variants(variants: Vec<(String, RunConfig)>)
                    -> anyhow::Result<Vec<SweepResult>> {
    let mut out = Vec::with_capacity(variants.len());
    for (label, cfg) in variants {
        log::info!("=== variant {label} ===");
        let outcomes = run_trials(&cfg)?;
        out.push(SweepResult {
            label,
            records: outcomes.into_iter().map(|o| o.record).collect(),
        });
    }
    Ok(out)
}

/// Fig 5(a): vary R at fixed W, ξ. `r = 0` encodes the Vanilla baseline
/// ("No Local").
pub fn sweep_r(base: &RunConfig, rs: &[usize])
               -> anyhow::Result<Vec<SweepResult>> {
    let variants = rs
        .iter()
        .map(|&r| {
            let mut c = base.clone();
            if r == 0 {
                c.algorithm = Algorithm::Vanilla;
                ("NoLocal(R=1)".to_string(), c)
            } else {
                c.algorithm = Algorithm::CeluVfl;
                c.r_local = r;
                (format!("R={r}"), c)
            }
        })
        .collect();
    run_variants(variants)
}

/// Fig 5(b): vary W at fixed R, ξ. `w = 1` runs the consecutive
/// (FedBCD-style) sampler; `w > 1` runs round-robin.
pub fn sweep_w(base: &RunConfig, ws: &[usize])
               -> anyhow::Result<Vec<SweepResult>> {
    let variants = ws
        .iter()
        .map(|&w| {
            let mut c = base.clone();
            if w <= 1 {
                // Consecutive reuse of the newest batch — still weighted
                // (the paper's "Consecutive (W=1)" row keeps ξ).
                c.algorithm = Algorithm::CeluVfl;
                c.w_workset = 1;
                ("Consecutive(W=1)".to_string(), c)
            } else {
                c.algorithm = Algorithm::CeluVfl;
                c.w_workset = w;
                (format!("W={w}"), c)
            }
        })
        .collect();
    run_variants(variants)
}

/// Fig 5(c): vary ξ at fixed R, W. `xi = 180` disables weighting
/// ("No Weights").
pub fn sweep_xi(base: &RunConfig, xis: &[f64])
                -> anyhow::Result<Vec<SweepResult>> {
    let variants = xis
        .iter()
        .map(|&xi| {
            let mut c = base.clone();
            c.algorithm = Algorithm::CeluVfl;
            c.xi_degrees = xi;
            let label = if xi >= 180.0 {
                "NoWeights".to_string()
            } else {
                format!("xi={xi:.0}deg")
            };
            (label, c)
        })
        .collect();
    run_variants(variants)
}

/// The full Table 2 grid: one section per technique. Returns
/// (section, Vec<(label, cell)>) rows ready for printing, given a target
/// AUC.
pub fn table2(base: &RunConfig, target: f64)
              -> anyhow::Result<Vec<(String, Vec<(String, String)>)>> {
    let mut sections = Vec::new();

    // Local update: No Local vs R ∈ {3,5,8}, at W=5 ξ=90° and ξ=60°.
    for xi in [90.0, 60.0] {
        let mut b = base.clone();
        b.w_workset = 5;
        b.xi_degrees = xi;
        let sweeps = sweep_r(&b, &[0, 3, 5, 8])?;
        sections.push((format!("Local Update (W=5, ξ={xi:.0}°)"),
                       summarize(&sweeps, target)));
    }

    // Local sampling: consecutive vs W ∈ {3,5,8}, at R=5.
    for xi in [90.0, 60.0] {
        let mut b = base.clone();
        b.r_local = 5;
        b.xi_degrees = xi;
        let sweeps = sweep_w(&b, &[1, 3, 5, 8])?;
        sections.push((format!("Local Sampling (R=5, ξ={xi:.0}°)"),
                       summarize(&sweeps, target)));
    }

    // Instance weighting: none vs ξ ∈ {90°, 60°, 30°}.
    for (w, r) in [(3usize, 3usize), (5, 5)] {
        let mut b = base.clone();
        b.w_workset = w;
        b.r_local = r;
        let sweeps = sweep_xi(&b, &[180.0, 90.0, 60.0, 30.0])?;
        sections.push((format!("Instance Weighting (W={w}, R={r})"),
                       summarize(&sweeps, target)));
    }

    Ok(sections)
}

/// Summarize sweeps into Table-2 cells; the FIRST variant is the
/// baseline the ↓% columns are computed against (as in the paper).
pub fn summarize(sweeps: &[SweepResult], target: f64)
                 -> Vec<(String, String)> {
    let baseline = sweeps
        .first()
        .map(|s| s.rounds_summary(target).0)
        .unwrap_or(0.0);
    sweeps
        .iter()
        .map(|s| {
            let (mean, std, frac) = s.rounds_summary(target);
            (s.label.clone(), super::table_cell(mean, std, frac, baseline))
        })
        .collect()
}

/// Fig 5(d): the cosine-similarity quantile profile of a single CELU run
/// (median over local steps of [min,q10,q25,q50,q75,q90,mean,frac≥ξ]).
pub fn cosine_profile(cfg: &RunConfig)
                      -> anyhow::Result<(Option<[f64; 8]>, Option<[f64; 8]>)> {
    let outcome = crate::coordinator::run_training(cfg)?;
    Ok((outcome.record.cosine.summary(), outcome.record.cosine_b.summary()))
}

/// Wire-compression ablation: convergence vs codec at otherwise fixed
/// hyper-parameters. The first variant is the identity baseline, so
/// `summarize` reports rounds-to-target deltas against uncompressed and
/// the per-record `wire_bytes_per_round`/`compression_ratio` give the
/// bytes axis.
pub fn sweep_compress(base: &RunConfig, codecs: &[CodecKind])
                      -> anyhow::Result<Vec<SweepResult>> {
    let variants = codecs
        .iter()
        .map(|&codec| {
            let mut c = base.clone();
            c.compress = codec;
            (codec.label(), c)
        })
        .collect();
    run_variants(variants)
}

/// Artifact-free byte accounting for one communication round at shape
/// [batch, z_dim]: the framed wire size of the Z_A + ∇Z_A exchange
/// under each codec, with the uncompressed size for comparison. Returns
/// (codec label, wire bytes/round, raw bytes/round).
pub fn compression_bytes_per_round(batch: usize, z_dim: usize,
                                   codecs: &[CodecKind])
                                   -> anyhow::Result<Vec<(String, usize,
                                                          usize)>> {
    // Deterministic pseudo-statistics: smooth, mixed-sign values of the
    // magnitude the bottom models actually emit.
    let synth = |seed: f32| -> Tensor {
        let v: Vec<f32> = (0..batch * z_dim)
            .map(|i| ((i as f32 * 0.37 + seed).sin()) * 0.8)
            .collect();
        Tensor::f32(vec![batch, z_dim], v)
    };
    let za = synth(0.0);
    let dza = synth(1.7);
    let mut out = Vec::with_capacity(codecs.len());
    for &codec in codecs {
        let (act, _) =
            outbound_stats(codec, Lane::Activation, 0, za.clone())?;
        let (der, _) =
            outbound_stats(codec, Lane::Derivative, 0, dza.clone())?;
        out.push((
            codec.label(),
            act.wire_bytes() + der.wire_bytes(),
            act.raw_bytes() + der.raw_bytes(),
        ));
    }
    Ok(out)
}

/// Topology ablation: convergence vs the session party count at
/// otherwise fixed hyper-parameters. The first entry is the two-party
/// baseline, so `summarize` reports rounds-to-target deltas against
/// the classic protocol. (K > 2 requires artifacts compiled for the
/// per-party feature slice — see `trainer::run_training`.)
pub fn sweep_parties(base: &RunConfig, parties: &[usize])
                     -> anyhow::Result<Vec<SweepResult>> {
    let variants = parties
        .iter()
        .map(|&k| {
            let mut c = base.clone();
            c.parties = k;
            (format!("K={k}"), c)
        })
        .collect();
    run_variants(variants)
}

/// Artifact-free byte accounting for one communication round of a
/// K-party star at statistics shape [batch, z_dim]: per-link rows
/// (label `src`/`dst` by party id) of the framed Z_k + ∇Z exchange,
/// v2 envelope included whenever the session spans more than two
/// parties. Returns (link label, wire bytes/round) rows plus the
/// session total — the protocol-level cost model behind
/// `sweep_parties`.
pub fn mesh_bytes_per_round(parties: usize, batch: usize, z_dim: usize)
                            -> anyhow::Result<(Vec<(String, usize)>,
                                               usize)> {
    anyhow::ensure!(parties >= 2, "a session needs ≥ 2 parties");
    let k = parties - 1;
    let envelope = if parties > 2 { FRAME_V2_OVERHEAD } else { 0 };
    let synth = |seed: f32| -> Tensor {
        let v: Vec<f32> = (0..batch * z_dim)
            .map(|i| ((i as f32 * 0.37 + seed).sin()) * 0.8)
            .collect();
        Tensor::f32(vec![batch, z_dim], v)
    };
    let mut rows = Vec::with_capacity(2 * k);
    let mut total = 0usize;
    for f in 1..=k {
        let (act, _) = outbound_stats(CodecKind::Identity,
                                      Lane::Activation, 0,
                                      synth(f as f32))?;
        let (der, _) = outbound_stats(CodecKind::Identity,
                                      Lane::Derivative, 0,
                                      synth(f as f32 + 0.5))?;
        let up = act.wire_bytes() + envelope;
        let down = der.wire_bytes() + envelope;
        rows.push((format!("{f}->0"), up));
        rows.push((format!("0->{f}"), down));
        total += up + down;
    }
    Ok((rows, total))
}

/// Limited-overlap ablation (DESIGN.md §12): convergence vs the
/// aligned (PSI-intersection) row fraction at otherwise fixed
/// hyper-parameters. Put `1.0` first so `summarize` reports deltas
/// against the fully-aligned baseline; below 1.0 the feature parties
/// additionally run self-supervised updates on their unaligned rows
/// (`ssl_ratio`), which show up in each record's `feature_ssl_updates`
/// without adding a byte of wire traffic.
pub fn sweep_overlap(base: &RunConfig, overlaps: &[f64])
                     -> anyhow::Result<Vec<SweepResult>> {
    let variants = overlaps
        .iter()
        .map(|&o| {
            let mut c = base.clone();
            c.overlap = o;
            let label = if o >= 1.0 {
                "FullOverlap".to_string()
            } else {
                format!("overlap={o:.2}")
            };
            (label, c)
        })
        .collect();
    run_variants(variants)
}

/// Artifact-free cost model behind `sweep_overlap`: over one pass of an
/// `n`-row stream, only aligned rows form batches and only batches pay
/// the per-round mesh cost — unaligned rows cost zero wire bytes by
/// construction. Returns (label, comm rounds/pass, wire bytes/pass)
/// rows; the bytes column scales linearly with the overlap fraction.
pub fn overlap_bytes_per_pass(parties: usize, batch: usize, z_dim: usize,
                              n: usize, overlaps: &[f64])
                              -> anyhow::Result<Vec<(String, u64,
                                                     usize)>> {
    anyhow::ensure!(batch > 0, "batch must be positive");
    let (_, per_round) = mesh_bytes_per_round(parties, batch, z_dim)?;
    overlaps
        .iter()
        .map(|&o| {
            anyhow::ensure!(o > 0.0 && o <= 1.0,
                            "overlap must be in (0, 1], got {o}");
            let rounds = ((n as f64 * o) as usize / batch) as u64;
            Ok((format!("overlap={o:.2}"), rounds,
                rounds as usize * per_round))
        })
        .collect()
}

#[cfg(test)]
mod overlap_tests {
    use super::*;

    #[test]
    fn overlap_bytes_scale_linearly_with_the_aligned_fraction() {
        let rows = overlap_bytes_per_pass(
            3, 64, 16, 64_000, &[0.1, 0.3, 1.0]).unwrap();
        assert_eq!(rows.len(), 3);
        let (full_rounds, full_bytes) = (rows[2].1, rows[2].2);
        assert_eq!(full_rounds, 1000);
        // 0.3 and 0.1 of the rows → 0.3 and 0.1 of the rounds & bytes.
        assert_eq!(rows[1].1, 300);
        assert_eq!(rows[1].2, full_bytes * 3 / 10);
        assert_eq!(rows[0].1, 100);
        assert_eq!(rows[0].2, full_bytes / 10);
        // Hostile fractions are refused, not silently clamped.
        assert!(overlap_bytes_per_pass(3, 64, 16, 1000, &[0.0]).is_err());
        assert!(overlap_bytes_per_pass(3, 64, 16, 1000, &[1.5]).is_err());
    }

    #[test]
    fn sweep_overlap_builds_labelled_variants() {
        // Config-plumbing check (run_variants needs artifacts, so only
        // the variant construction is exercised here).
        let base = RunConfig::quick();
        for o in [0.1, 0.3, 1.0] {
            let mut c = base.clone();
            c.overlap = o;
            assert!(c.validate().is_ok(), "overlap {o} rejected");
        }
        let mut bad = base.clone();
        bad.overlap = 0.0;
        assert!(bad.validate().is_err());
    }
}

#[cfg(test)]
mod parties_tests {
    use super::*;

    #[test]
    fn mesh_bytes_scale_with_the_feature_party_count() {
        // Per-round traffic of the star grows linearly in K−1 (every
        // feature party exchanges one Z/∇Z pair per round) plus the v2
        // envelope on every frame once the session leaves two-party
        // mode.
        let (rows2, total2) = mesh_bytes_per_round(2, 64, 16).unwrap();
        let (rows3, total3) = mesh_bytes_per_round(3, 64, 16).unwrap();
        let (rows5, total5) = mesh_bytes_per_round(5, 64, 16).unwrap();
        assert_eq!(rows2.len(), 2);
        assert_eq!(rows3.len(), 4);
        assert_eq!(rows5.len(), 8);
        // Two-party: no envelope — exactly the historic per-round cost.
        let per_link2 = total2;
        assert_eq!(rows2[0].1 + rows2[1].1, per_link2);
        // K-party: each of the K−1 links pays the two-party cost plus
        // two envelopes per round.
        let per_link_v2 = per_link2 + 2 * FRAME_V2_OVERHEAD;
        assert_eq!(total3, 2 * per_link_v2);
        assert_eq!(total5, 4 * per_link_v2);
        assert!(mesh_bytes_per_round(1, 64, 16).is_err());
    }

    #[test]
    fn sweep_parties_builds_labelled_variants() {
        // Config-plumbing check (run_variants needs artifacts, so only
        // the variant construction is exercised here).
        let base = RunConfig::quick();
        let mut c2 = base.clone();
        c2.parties = 2;
        let mut c4 = base.clone();
        c4.parties = 4;
        assert!(c2.validate().is_ok());
        assert!(c4.validate().is_ok());
        assert_eq!(c4.feature_parties(), 3);
    }
}

#[cfg(test)]
mod compress_tests {
    use super::*;

    #[test]
    fn int8_and_topk_use_strictly_fewer_wire_bytes_than_identity() {
        // The acceptance criterion for the compression scenario, checked
        // at the protocol layer (no artifacts needed): every lossy codec
        // must beat the identity bytes-per-round, int8 by ~4×.
        let codecs = [CodecKind::Identity, CodecKind::Fp16,
                      CodecKind::QuantInt8, CodecKind::TopK(256)];
        let rows = compression_bytes_per_round(256, 64, &codecs).unwrap();
        let ident = rows[0].1;
        assert_eq!(rows[0].1, rows[0].2, "identity wire == raw");
        for (label, wire, raw) in &rows[1..] {
            assert!(*wire < ident,
                    "{label}: wire {wire} !< identity {ident}");
            assert_eq!(*raw, ident, "{label}: raw must equal identity");
        }
        // int8 ≈ 4× smaller (1 byte/elem + per-row sidecar vs 4).
        let int8 = rows[2].1;
        assert!((int8 as f64) < ident as f64 / 3.0,
                "int8 {int8} not ~4× below {ident}");
        // topk:256 keeps 1/64 of the elements → far below identity.
        let topk = rows[3].1;
        assert!((topk as f64) < ident as f64 / 8.0,
                "topk {topk} not sparse enough vs {ident}");
    }

    #[test]
    fn sweep_compress_builds_labelled_variants() {
        // Config-plumbing check (run_variants needs artifacts, so only
        // the variant construction is exercised here).
        let base = RunConfig::quick();
        let codecs = [CodecKind::Identity, CodecKind::TopK(8)];
        let labels: Vec<String> =
            codecs.iter().map(|c| c.label()).collect();
        assert_eq!(labels, vec!["none", "topk:8"]);
        let mut c = base.clone();
        c.compress = codecs[1];
        assert_eq!(c.compress, CodecKind::TopK(8));
    }
}
