//! Experiment harnesses: one per table/figure of the paper's evaluation
//! (§5), shared by the examples and the bench targets.
//!
//! | paper artifact | harness |
//! |---|---|
//! | Fig 5(a) local-update sweep (R)        | `ablation::sweep_r` |
//! | Fig 5(b) local-sampling sweep (W)      | `ablation::sweep_w` |
//! | Fig 5(c) instance-weighting sweep (ξ)  | `ablation::sweep_xi` |
//! | Fig 5(d) cosine-similarity quantiles   | `ablation::cosine_profile` |
//! | Table 2 comm-rounds-to-target grid     | `ablation::table2` |
//! | Fig 6 end-to-end time-to-AUC           | `endtoend::fig6` |
//! | Thm 1 ρ-vs-staleness probe             | `theory::rho_probe` |
//! | §1 comm-fraction claim                 | `endtoend` comm column |
//! | wire-compression sweep (DESIGN.md §5)  | `ablation::sweep_compress`, `ablation::compression_bytes_per_round` |
//! | K-party topology sweep (DESIGN.md §6)  | `ablation::sweep_parties`, `ablation::mesh_bytes_per_round` |
//! | chaos-campaign sweep (DESIGN.md §13)   | `crate::campaign::run_campaign` |

pub mod ablation;
pub mod endtoend;
pub mod serve;
pub mod tcp;
pub mod theory;

use crate::metrics::RunRecord;
use crate::util::stats::mean_std;

/// One sweep variant: label + the per-trial records.
pub struct SweepResult {
    pub label: String,
    pub records: Vec<RunRecord>,
}

impl SweepResult {
    /// Rounds to target AUC per trial (None = never reached).
    pub fn rounds_to(&self, target: f64) -> Vec<Option<u64>> {
        self.records.iter().map(|r| r.rounds_to_auc(target)).collect()
    }

    /// Mean ± std of rounds-to-target over the trials that reached it,
    /// plus the fraction that did.
    pub fn rounds_summary(&self, target: f64) -> (f64, f64, f64) {
        let reached: Vec<f64> = self
            .rounds_to(target)
            .into_iter()
            .flatten()
            .map(|r| r as f64)
            .collect();
        let frac = reached.len() as f64 / self.records.len().max(1) as f64;
        let (mean, std) = mean_std(&reached);
        (mean, std, frac)
    }

    /// Mean ± std of wall-clock seconds to target AUC.
    pub fn time_summary(&self, target: f64) -> (f64, f64, f64) {
        let reached: Vec<f64> = self
            .records
            .iter()
            .filter_map(|r| r.time_to_auc(target))
            .collect();
        let frac = reached.len() as f64 / self.records.len().max(1) as f64;
        let (mean, std) = mean_std(&reached);
        (mean, std, frac)
    }

    pub fn best_auc_mean(&self) -> f64 {
        let aucs: Vec<f64> =
            self.records.iter().map(|r| r.best_auc()).collect();
        mean_std(&aucs).0
    }
}

/// Render a Table-2-style cell: `mean ± std (↓ pct%)` against a baseline.
pub fn table_cell(mean: f64, std: f64, frac: f64, baseline: f64) -> String {
    if frac == 0.0 {
        return "diverged/NR".to_string();
    }
    let mut s = format!("{mean:.0} ± {std:.1}");
    if baseline > 0.0 && mean > 0.0 && (baseline - mean).abs() > 1e-9 {
        let pct = 100.0 * (baseline - mean) / baseline;
        if pct >= 0.0 {
            s.push_str(&format!(" (↓{pct:.1}%)"));
        } else {
            s.push_str(&format!(" (↑{:.1}%)", -pct));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::SeriesPoint;

    fn rec(aucs: &[f64]) -> RunRecord {
        let mut r = RunRecord::default();
        for (i, &a) in aucs.iter().enumerate() {
            r.series.push(SeriesPoint {
                comm_round: (i as u64 + 1) * 100,
                wall_s: (i as f64 + 1.0) * 5.0,
                auc: a,
                loss: 0.0,
                updates: 0,
            });
        }
        r
    }

    #[test]
    fn rounds_summary_ignores_unreached() {
        let s = SweepResult {
            label: "t".into(),
            records: vec![rec(&[0.5, 0.7]), rec(&[0.5, 0.55])],
        };
        let (mean, _std, frac) = s.rounds_summary(0.65);
        assert_eq!(mean, 200.0);
        assert_eq!(frac, 0.5);
    }

    #[test]
    fn table_cell_formats() {
        assert_eq!(table_cell(0.0, 0.0, 0.0, 100.0), "diverged/NR");
        let c = table_cell(50.0, 2.0, 1.0, 100.0);
        assert!(c.contains("50") && c.contains("↓50.0%"), "{c}");
        let c = table_cell(150.0, 2.0, 1.0, 100.0);
        assert!(c.contains("↑50.0%"), "{c}");
    }
}
