//! End-to-end harness — Figure 6 of the paper.
//!
//! Compares CELU-VFL against FedBCD and Vanilla on wall-clock time under
//! the simulated WAN, per (model, dataset) pair, and reports the paper's
//! headline speedup ratios plus the §1 communication-fraction claim.

use crate::config::{Algorithm, RunConfig};
use crate::coordinator::trainer::run_trials;

use super::SweepResult;

/// One Figure-6 panel: (model, dataset) with the three competitors.
pub struct Fig6Panel {
    pub model: String,
    pub dataset: String,
    pub results: Vec<SweepResult>, // [vanilla, fedbcd, celu]
    pub target: f64,
}

impl Fig6Panel {
    /// (label, time_mean, time_std, frac_reached, comm_fraction) rows.
    pub fn rows(&self) -> Vec<(String, f64, f64, f64, f64)> {
        self.results
            .iter()
            .map(|s| {
                let (m, sd, frac) = s.time_summary(self.target);
                let comm: f64 = s
                    .records
                    .iter()
                    .map(|r| r.comm_fraction())
                    .sum::<f64>()
                    / s.records.len().max(1) as f64;
                (s.label.clone(), m, sd, frac, comm)
            })
            .collect()
    }

    /// CELU speedup vs each competitor (None if either diverged).
    pub fn speedups(&self) -> Vec<(String, Option<f64>)> {
        let celu = self
            .results
            .iter()
            .find(|s| s.label.starts_with("celu"))
            .map(|s| s.time_summary(self.target));
        self.results
            .iter()
            .filter(|s| !s.label.starts_with("celu"))
            .map(|s| {
                let (m, _, frac) = s.time_summary(self.target);
                let speedup = match celu {
                    Some((cm, _, cf)) if cf > 0.0 && frac > 0.0 && cm > 0.0 =>
                        Some(m / cm),
                    _ => None,
                };
                (s.label.clone(), speedup)
            })
            .collect()
    }
}

/// Build the three competitor configs for one panel.
pub fn competitors(base: &RunConfig, r: usize, w: usize, xi: f64)
                   -> Vec<(String, RunConfig)> {
    let mut vanilla = base.clone();
    vanilla.algorithm = Algorithm::Vanilla;
    let mut fedbcd = base.clone();
    fedbcd.algorithm = Algorithm::FedBcd;
    fedbcd.r_local = r;
    let mut celu = base.clone();
    celu.algorithm = Algorithm::CeluVfl;
    celu.r_local = r;
    celu.w_workset = w;
    celu.xi_degrees = xi;
    vec![
        ("vanilla".to_string(), vanilla),
        (format!("fedbcd(R={r})"), fedbcd),
        (format!("celu(R={r},W={w},ξ={xi:.0}°)"), celu),
    ]
}

/// Run one Figure-6 panel. The paper fixes W=5, ξ=60° and R ∈ {5, 8}.
pub fn fig6_panel(base: &RunConfig, model: &str, dataset: &str, r: usize,
                  target: f64) -> anyhow::Result<Fig6Panel> {
    let mut b = base.clone();
    b.model = model.to_string();
    b.dataset = dataset.to_string();
    b.target_auc = target;
    let mut results = Vec::new();
    for (label, cfg) in competitors(&b, r, 5, 60.0) {
        log::info!("=== fig6 {model}/{dataset} {label} ===");
        let outcomes = run_trials(&cfg)?;
        results.push(SweepResult {
            label,
            records: outcomes.into_iter().map(|o| o.record).collect(),
        });
    }
    Ok(Fig6Panel {
        model: model.to_string(),
        dataset: dataset.to_string(),
        results,
        target,
    })
}

/// Pretty-print one panel to stdout (the bench/example output format).
pub fn print_panel(panel: &Fig6Panel) {
    println!("--- {} / {} (target AUC {:.3}) ---", panel.model,
             panel.dataset, panel.target);
    println!("{:<26} {:>12} {:>8} {:>9} {:>10}", "algorithm",
             "time-to-AUC", "±std", "reached", "comm-frac");
    for (label, m, sd, frac, comm) in panel.rows() {
        if frac == 0.0 {
            println!("{label:<26} {:>12} {:>8} {:>9} {comm:>9.0}%",
                     "n/a", "-", "0%", comm = 100.0 * comm);
        } else {
            println!(
                "{label:<26} {m:>11.1}s {sd:>7.1}s {:>8.0}% {:>9.0}%",
                100.0 * frac,
                100.0 * comm
            );
        }
    }
    for (vs, speedup) in panel.speedups() {
        match speedup {
            Some(x) => println!("  CELU speedup vs {vs}: {x:.2}×"),
            None => println!("  CELU speedup vs {vs}: n/a (diverged)"),
        }
    }
}
