//! Statistics wire compression: codecs for the exchanged Z_A / ∇Z_A.
//!
//! CELU-VFL cuts WAN cost by *reducing rounds* (cached local updates);
//! this layer adds the orthogonal lever of *shrinking each round's
//! payload* (Compressed-VFL, Castiglia et al. — PAPERS.md). Codecs are
//! applied at the protocol boundary (`protocol::outbound_stats` /
//! `Message::into_plain`): the workset cache on BOTH parties stores the
//! *dequantized* statistics, so the staleness-weighting math is
//! untouched and the two parties train against bit-identical cached
//! tensors (the sender round-trips its own payload before caching).
//!
//! Codecs (`StatCodec`):
//! - `Identity`   — raw little-endian f32 (4 B/elem, exact).
//! - `Fp16`       — IEEE-754 binary16 with round-to-nearest-even and
//!   saturation to ±65504. Error bound: relative ≤ 2⁻¹¹ (half ulp) in
//!   the f16 normal range, absolute ≤ 2⁻²⁵ below it (2 B/elem).
//! - `QuantInt8`  — per-row affine quantization. Each row stores
//!   (scale, min) as f32 and one byte per element; error bound per
//!   element: ≤ scale/2 where scale = (rowmax − rowmin)/255 (1 B/elem
//!   + 8 B/row).
//! - `TopK`       — magnitude sparsification: the k largest-|x| elements
//!   as (u32 index, f32 value) pairs, remaining elements decode to 0.
//!   Support recovery is exact; ties break toward the lower index
//!   (8 B per kept element).
//!
//! Which codec actually runs is *negotiated*: each party advertises a
//! capability bitmask in the protocol `Hello` frame and `negotiate`
//! downgrades to `Identity` whenever the peer cannot decode the request
//! — old peers (which never send `Hello`) keep the exact pre-compression
//! byte stream. See DESIGN.md §5.

use crate::tensor::Tensor;

// -- codec selection --------------------------------------------------------

/// Wire codec identity + parameters. `code()`/`param()` are the on-wire
/// representation (see protocol frame layout); the capability bitmask
/// used by the handshake is bit `code` per codec family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecKind {
    Identity,
    Fp16,
    QuantInt8,
    TopK(u32),
}

/// Human-readable list for error messages and --help text.
pub const VALID_CODECS: &str = "none, fp16, int8, topk:<k>";

impl CodecKind {
    /// Parse a CLI/TOML codec spec. The error names every valid value.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        if let Some(k) = s.strip_prefix("topk:") {
            let k: u32 = k.parse().map_err(|_| {
                anyhow::anyhow!(
                    "invalid top-k count '{k}' in codec '{s}' — valid \
                     values: {VALID_CODECS}"
                )
            })?;
            anyhow::ensure!(
                k > 0,
                "top-k count must be ≥ 1 in codec '{s}' — valid values: \
                 {VALID_CODECS}"
            );
            return Ok(CodecKind::TopK(k));
        }
        match s {
            "none" | "identity" => Ok(CodecKind::Identity),
            "fp16" => Ok(CodecKind::Fp16),
            "int8" => Ok(CodecKind::QuantInt8),
            _ => anyhow::bail!(
                "unknown codec '{s}' — valid values: {VALID_CODECS}"
            ),
        }
    }

    /// Canonical spec string (`parse(label())` round-trips).
    pub fn label(&self) -> String {
        match self {
            CodecKind::Identity => "none".to_string(),
            CodecKind::Fp16 => "fp16".to_string(),
            CodecKind::QuantInt8 => "int8".to_string(),
            CodecKind::TopK(k) => format!("topk:{k}"),
        }
    }

    /// On-wire codec family code.
    pub fn code(&self) -> u8 {
        match self {
            CodecKind::Identity => 0,
            CodecKind::Fp16 => 1,
            CodecKind::QuantInt8 => 2,
            CodecKind::TopK(_) => 3,
        }
    }

    /// On-wire codec parameter (k for top-k, 0 otherwise).
    pub fn param(&self) -> u32 {
        match self {
            CodecKind::TopK(k) => *k,
            _ => 0,
        }
    }

    /// Rebuild from the wire pair; rejects unknown codes and
    /// non-canonical parameters (hostile-header guard — a decoded frame
    /// must re-encode to the same bytes).
    pub fn from_wire(code: u8, param: u32) -> anyhow::Result<Self> {
        if code != 3 && param != 0 {
            anyhow::bail!("codec code {code} takes no parameter, \
                           got {param}");
        }
        match code {
            0 => Ok(CodecKind::Identity),
            1 => Ok(CodecKind::Fp16),
            2 => Ok(CodecKind::QuantInt8),
            3 => {
                anyhow::ensure!(param > 0, "top-k frame with k = 0");
                Ok(CodecKind::TopK(param))
            }
            _ => anyhow::bail!("unknown codec code {code}"),
        }
    }

    /// True for codecs whose decode is not bit-exact.
    pub fn is_lossy(&self) -> bool {
        !matches!(self, CodecKind::Identity)
    }
}

/// Capability bitmask this build can decode (bit per codec family).
pub fn supported_mask() -> u32 {
    (1 << CodecKind::Identity.code())
        | (1 << CodecKind::Fp16.code())
        | (1 << CodecKind::QuantInt8.code())
        | (1 << CodecKind::TopK(1).code())
}

/// Pick the effective send codec given the peer's advertised mask.
/// `None` means the peer never sent a `Hello` (pre-compression build):
/// fall back to `Identity` so the byte stream stays decodable.
pub fn negotiate(requested: CodecKind, peer_mask: Option<u32>)
                 -> CodecKind {
    match peer_mask {
        Some(mask) if mask & (1 << requested.code()) != 0 => requested,
        _ => CodecKind::Identity,
    }
}

// -- compressed representation ----------------------------------------------

/// One compressed statistics tensor, exactly as framed on the wire:
/// codec-specific side data (`extra`, e.g. per-row scales) + packed
/// payload. Produced by [`StatCodec::compress`], validated and
/// reconstructed by [`StatCodec::decompress`].
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedStats {
    pub kind: CodecKind,
    pub shape: Vec<usize>,
    pub extra: Vec<u8>,
    pub payload: Vec<u8>,
}

impl CompressedStats {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Bytes this block occupies inside a protocol frame:
    /// codec(1) + param(4) + ndim(1) + dims(4·ndim) + extra_len(4)
    /// + extra + payload.
    pub fn wire_block_bytes(&self) -> usize {
        1 + 4 + 1 + 4 * self.shape.len() + 4 + self.extra.len()
            + self.payload.len()
    }
}

/// Expected (extra, payload) byte lengths for a codec over `shape`, with
/// overflow-checked arithmetic — called by the frame decoder BEFORE any
/// allocation so hostile headers cannot drive huge reservations.
pub fn expected_lens(kind: CodecKind, shape: &[usize])
                     -> anyhow::Result<(usize, usize)> {
    let numel: usize = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| anyhow::anyhow!("shape overflow"))?;
    let mul = |a: usize, b: usize| {
        a.checked_mul(b)
            .ok_or_else(|| anyhow::anyhow!("length overflow"))
    };
    match kind {
        CodecKind::Identity => Ok((0, mul(numel, 4)?)),
        CodecKind::Fp16 => Ok((0, mul(numel, 2)?)),
        CodecKind::QuantInt8 => {
            let rows = row_count(shape);
            Ok((mul(rows, 8)?, numel))
        }
        CodecKind::TopK(k) => {
            anyhow::ensure!(
                (k as usize) <= numel.max(1),
                "top-k frame keeps {k} of {numel} elements"
            );
            Ok((0, mul(k as usize, 8)?))
        }
    }
}

/// Rows of a [B, D…] statistics tensor (scalars count as one row).
fn row_count(shape: &[usize]) -> usize {
    shape.first().copied().unwrap_or(1)
}

// -- the codecs -------------------------------------------------------------

/// A statistics codec: tensor → wire block → (dequantized) tensor.
pub trait StatCodec {
    fn kind(&self) -> CodecKind;
    fn compress(&self, t: &Tensor) -> anyhow::Result<CompressedStats>;
    fn decompress(&self, c: &CompressedStats) -> anyhow::Result<Tensor>;
}

/// Shared validation for decompress implementations.
fn check_block(kind: CodecKind, c: &CompressedStats)
               -> anyhow::Result<usize> {
    anyhow::ensure!(
        c.kind == kind,
        "codec mismatch: block is {}, codec is {}",
        c.kind.label(),
        kind.label()
    );
    let (extra, payload) = expected_lens(kind, &c.shape)?;
    anyhow::ensure!(
        c.extra.len() == extra && c.payload.len() == payload,
        "corrupt {} block: extra {} (want {extra}), payload {} \
         (want {payload})",
        kind.label(),
        c.extra.len(),
        c.payload.len()
    );
    Ok(c.numel())
}

/// Raw little-endian f32 — exact, 4 B/elem. Exists so the codec lattice
/// has a measurable baseline; negotiated-identity sends use the plain
/// (pre-compression) frames instead of identity blocks.
pub struct Identity;

impl StatCodec for Identity {
    fn kind(&self) -> CodecKind {
        CodecKind::Identity
    }

    fn compress(&self, t: &Tensor) -> anyhow::Result<CompressedStats> {
        let v = t.as_f32()?;
        Ok(CompressedStats {
            kind: CodecKind::Identity,
            shape: t.shape.clone(),
            extra: Vec::new(),
            payload: f32s_to_le_bytes(v),
        })
    }

    fn decompress(&self, c: &CompressedStats) -> anyhow::Result<Tensor> {
        check_block(CodecKind::Identity, c)?;
        let data: Vec<f32> = c
            .payload
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        Ok(Tensor::f32(c.shape.clone(), data))
    }
}

/// IEEE-754 binary16, round-to-nearest-even, saturating to ±65504.
pub struct Fp16;

impl StatCodec for Fp16 {
    fn kind(&self) -> CodecKind {
        CodecKind::Fp16
    }

    fn compress(&self, t: &Tensor) -> anyhow::Result<CompressedStats> {
        let v = t.as_f32()?;
        let mut payload = Vec::with_capacity(v.len() * 2);
        for &x in v {
            payload.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
        }
        Ok(CompressedStats {
            kind: CodecKind::Fp16,
            shape: t.shape.clone(),
            extra: Vec::new(),
            payload,
        })
    }

    fn decompress(&self, c: &CompressedStats) -> anyhow::Result<Tensor> {
        check_block(CodecKind::Fp16, c)?;
        let data: Vec<f32> = c
            .payload
            .chunks_exact(2)
            .map(|b| f16_bits_to_f32(u16::from_le_bytes([b[0], b[1]])))
            .collect();
        Ok(Tensor::f32(c.shape.clone(), data))
    }
}

/// Per-row affine u8 quantization: x̂ = min + q·scale,
/// scale = (max − min)/255.
pub struct QuantInt8;

impl StatCodec for QuantInt8 {
    fn kind(&self) -> CodecKind {
        CodecKind::QuantInt8
    }

    fn compress(&self, t: &Tensor) -> anyhow::Result<CompressedStats> {
        let v = t.as_f32()?;
        let rows = row_count(&t.shape);
        let d = if rows == 0 { 0 } else { v.len() / rows };
        debug_assert_eq!(rows * d, v.len());
        let mut extra = Vec::with_capacity(rows * 8);
        let mut payload = Vec::with_capacity(v.len());
        for r in 0..rows {
            let row = &v[r * d..(r + 1) * d];
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for &x in row {
                lo = lo.min(x);
                hi = hi.max(x);
            }
            // Range arithmetic in f64: (hi − lo) can overflow f32 to
            // infinity for extreme rows, which would silently collapse
            // the row to a constant. The stored scale stays f32 (wire
            // format), and quantization uses that stored value so the
            // sender and receiver see identical math.
            let mut scale = ((hi as f64 - lo as f64) / 255.0) as f32;
            if !(scale.is_finite() && scale > 0.0) || !lo.is_finite() {
                // Constant, empty or non-finite row: store it as the
                // constant `lo` (or 0) with scale 0.
                scale = 0.0;
                lo = if lo.is_finite() { lo } else { 0.0 };
            }
            extra.extend_from_slice(&scale.to_le_bytes());
            extra.extend_from_slice(&lo.to_le_bytes());
            for &x in row {
                let q = if scale > 0.0 {
                    ((x as f64 - lo as f64) / scale as f64)
                        .round()
                        .clamp(0.0, 255.0)
                } else {
                    0.0
                };
                payload.push(q as u8);
            }
        }
        Ok(CompressedStats {
            kind: CodecKind::QuantInt8,
            shape: t.shape.clone(),
            extra,
            payload,
        })
    }

    fn decompress(&self, c: &CompressedStats) -> anyhow::Result<Tensor> {
        let numel = check_block(CodecKind::QuantInt8, c)?;
        let rows = row_count(&c.shape);
        let d = if rows == 0 { 0 } else { numel / rows };
        let mut data = Vec::with_capacity(numel);
        for r in 0..rows {
            let e = &c.extra[r * 8..r * 8 + 8];
            let scale = f32::from_le_bytes(e[0..4].try_into().unwrap());
            let lo = f32::from_le_bytes(e[4..8].try_into().unwrap());
            for &q in &c.payload[r * d..(r + 1) * d] {
                // f64 accumulate: q·scale alone can overflow f32 for
                // extreme rows even though the result is in range.
                data.push((lo as f64 + q as f64 * scale as f64) as f32);
            }
        }
        Ok(Tensor::f32(c.shape.clone(), data))
    }
}

/// Magnitude top-k sparsification: (u32 index, f32 value) pairs sorted
/// by index; everything else decodes to zero.
pub struct TopK {
    pub k: u32,
}

impl StatCodec for TopK {
    fn kind(&self) -> CodecKind {
        CodecKind::TopK(self.k)
    }

    fn compress(&self, t: &Tensor) -> anyhow::Result<CompressedStats> {
        let v = t.as_f32()?;
        anyhow::ensure!(!v.is_empty(), "top-k needs a non-empty tensor");
        anyhow::ensure!(self.k > 0, "top-k needs k ≥ 1");
        let k = (self.k as usize).min(v.len());
        let mut order: Vec<u32> = (0..v.len() as u32).collect();
        // Descending |x|, ties toward the lower index (deterministic
        // wire bytes → stable golden fixtures).
        order.sort_unstable_by(|&a, &b| {
            v[b as usize]
                .abs()
                .total_cmp(&v[a as usize].abs())
                .then(a.cmp(&b))
        });
        let mut kept = order[..k].to_vec();
        kept.sort_unstable();
        let mut payload = Vec::with_capacity(k * 8);
        for idx in kept {
            payload.extend_from_slice(&idx.to_le_bytes());
            payload.extend_from_slice(&v[idx as usize].to_le_bytes());
        }
        Ok(CompressedStats {
            kind: CodecKind::TopK(k as u32),
            shape: t.shape.clone(),
            extra: Vec::new(),
            payload,
        })
    }

    fn decompress(&self, c: &CompressedStats) -> anyhow::Result<Tensor> {
        let numel = check_block(c.kind, c)?;
        let mut data = vec![0.0f32; numel];
        let mut prev: Option<u32> = None;
        for pair in c.payload.chunks_exact(8) {
            let idx = u32::from_le_bytes(pair[0..4].try_into().unwrap());
            let val = f32::from_le_bytes(pair[4..8].try_into().unwrap());
            anyhow::ensure!(
                (idx as usize) < numel,
                "top-k index {idx} out of range for {numel} elements"
            );
            if let Some(p) = prev {
                anyhow::ensure!(
                    idx > p,
                    "top-k indices must be strictly increasing"
                );
            }
            prev = Some(idx);
            data[idx as usize] = val;
        }
        Ok(Tensor::f32(c.shape.clone(), data))
    }
}

// -- kind-level dispatch (no per-call boxing) --------------------------------

/// Compress `t` with `kind`.
pub fn compress_tensor(kind: CodecKind, t: &Tensor)
                       -> anyhow::Result<CompressedStats> {
    match kind {
        CodecKind::Identity => Identity.compress(t),
        CodecKind::Fp16 => Fp16.compress(t),
        CodecKind::QuantInt8 => QuantInt8.compress(t),
        CodecKind::TopK(k) => TopK { k }.compress(t),
    }
}

/// Reconstruct the dequantized tensor from a wire block.
pub fn decompress_stats(c: &CompressedStats) -> anyhow::Result<Tensor> {
    match c.kind {
        CodecKind::Identity => Identity.decompress(c),
        CodecKind::Fp16 => Fp16.decompress(c),
        CodecKind::QuantInt8 => QuantInt8.decompress(c),
        CodecKind::TopK(k) => TopK { k }.decompress(c),
    }
}

/// Boxed codec for trait-object users (benches, extension points).
pub fn codec_for(kind: CodecKind) -> Box<dyn StatCodec> {
    match kind {
        CodecKind::Identity => Box::new(Identity),
        CodecKind::Fp16 => Box::new(Fp16),
        CodecKind::QuantInt8 => Box::new(QuantInt8),
        CodecKind::TopK(k) => Box::new(TopK { k }),
    }
}

// -- f16 conversion ----------------------------------------------------------
//
// Hand-rolled binary16 (the `half` crate is unavailable offline).
// Encoding rounds to nearest-even and SATURATES overflow to ±65504
// instead of ±inf — a quantized statistic should stay finite.

/// f32 → binary16 bits (round-to-nearest-even, saturating).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // inf → saturate; NaN → canonical qNaN.
        return if mant == 0 { sign | 0x7bff } else { sign | 0x7e00 };
    }
    let unbiased = exp - 127;
    if unbiased >= 16 {
        return sign | 0x7bff; // overflow: clamp to ±65504
    }
    if unbiased >= -14 {
        // Normal f16: drop 13 mantissa bits with round-to-nearest-even.
        let mut out = (((unbiased + 15) as u32) << 10) | (mant >> 13);
        let rem = mant & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (out & 1) == 1) {
            out += 1; // may carry into the exponent — still well-formed
        }
        if out >= 0x7c00 {
            return sign | 0x7bff; // rounded up past 65504: clamp
        }
        return sign | out as u16;
    }
    if unbiased >= -25 {
        // Subnormal f16: value = m·2⁻²⁴ for integer m, round-to-even.
        let m = mant | 0x0080_0000; // implicit leading 1
        let shift = (13 + (-14 - unbiased)) as u32;
        let out = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let out = if rem > half || (rem == half && (out & 1) == 1) {
            out + 1 // may round up into the normal range (0x0400): fine
        } else {
            out
        };
        return sign | out as u16;
    }
    sign // underflow to ±0
}

/// binary16 bits → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    const SUBNORMAL_SCALE: f32 = 5.960_464_5e-8; // 2⁻²⁴
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    match exp {
        0 => {
            let mag = mant as f32 * SUBNORMAL_SCALE;
            if sign != 0 {
                -mag
            } else {
                mag
            }
        }
        0x1f => {
            if mant == 0 {
                if sign != 0 {
                    f32::NEG_INFINITY
                } else {
                    f32::INFINITY
                }
            } else {
                f32::NAN
            }
        }
        e => f32::from_bits(sign | ((e + 127 - 15) << 23) | (mant << 13)),
    }
}

// -- bulk LE helpers ---------------------------------------------------------

#[cfg(target_endian = "little")]
fn f32s_to_le_bytes(v: &[f32]) -> Vec<u8> {
    // SAFETY: f32 is 4 bytes with no padding; the slice is valid for
    // v.len() * 4 bytes of reads (mirrors protocol::write_f32s_le).
    let bytes = unsafe {
        std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 4)
    };
    bytes.to_vec()
}

#[cfg(not(target_endian = "little"))]
fn f32s_to_le_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2x4() -> Tensor {
        Tensor::f32(vec![2, 4],
                    vec![0.0, 1.5, -2.25, 100.0, -0.001, 7.0, 7.0, -7.5])
    }

    #[test]
    fn parse_roundtrips_and_lists_valid_values_on_error() {
        for s in ["none", "fp16", "int8", "topk:32"] {
            let k = CodecKind::parse(s).unwrap();
            assert_eq!(CodecKind::parse(&k.label()).unwrap(), k);
        }
        assert_eq!(CodecKind::parse("identity").unwrap(),
                   CodecKind::Identity);
        for bad in ["gzip", "topk:", "topk:0", "topk:-3", "Int8", ""] {
            let e = CodecKind::parse(bad).unwrap_err().to_string();
            for valid in ["none", "fp16", "int8", "topk:<k>"] {
                assert!(e.contains(valid),
                        "error for '{bad}' must list '{valid}': {e}");
            }
        }
    }

    #[test]
    fn wire_code_param_roundtrip() {
        for k in [CodecKind::Identity, CodecKind::Fp16,
                  CodecKind::QuantInt8, CodecKind::TopK(17)] {
            assert_eq!(CodecKind::from_wire(k.code(), k.param()).unwrap(),
                       k);
        }
        assert!(CodecKind::from_wire(9, 0).is_err());
        assert!(CodecKind::from_wire(3, 0).is_err(), "topk k=0 rejected");
    }

    #[test]
    fn negotiation_downgrades_to_identity() {
        let all = supported_mask();
        assert_eq!(negotiate(CodecKind::QuantInt8, Some(all)),
                   CodecKind::QuantInt8);
        assert_eq!(negotiate(CodecKind::TopK(8), Some(all)),
                   CodecKind::TopK(8));
        // Peer without int8 support.
        let no_int8 = all & !(1 << CodecKind::QuantInt8.code());
        assert_eq!(negotiate(CodecKind::QuantInt8, Some(no_int8)),
                   CodecKind::Identity);
        // Pre-compression peer (no Hello at all).
        assert_eq!(negotiate(CodecKind::Fp16, None), CodecKind::Identity);
        assert_eq!(negotiate(CodecKind::Identity, None),
                   CodecKind::Identity);
    }

    #[test]
    fn identity_roundtrip_is_exact() {
        let t = t2x4();
        let c = compress_tensor(CodecKind::Identity, &t).unwrap();
        assert_eq!(c.payload.len(), t.len() * 4);
        assert_eq!(decompress_stats(&c).unwrap(), t);
    }

    #[test]
    fn fp16_known_pairs() {
        for (x, bits) in [(0.0f32, 0x0000u16), (1.0, 0x3c00),
                          (0.5, 0x3800), (-2.0, 0xc000),
                          (65504.0, 0x7bff)] {
            assert_eq!(f32_to_f16_bits(x), bits, "encode {x}");
            assert_eq!(f16_bits_to_f32(bits), x, "decode {x}");
        }
        // Saturation instead of infinity.
        assert_eq!(f32_to_f16_bits(1e9), 0x7bff);
        assert_eq!(f32_to_f16_bits(-1e9), 0xfbff);
        assert_eq!(f16_bits_to_f32(0xfbff), -65504.0);
        // Smallest subnormal.
        assert_eq!(f16_bits_to_f32(0x0001), 5.960_464_5e-8);
        // NaN stays NaN.
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn fp16_roundtrip_error_bound() {
        let t = t2x4();
        let c = compress_tensor(CodecKind::Fp16, &t).unwrap();
        assert_eq!(c.payload.len(), t.len() * 2);
        let back = decompress_stats(&c).unwrap();
        for (x, y) in t.as_f32().unwrap().iter()
                       .zip(back.as_f32().unwrap()) {
            let bound = x.abs() * (1.0 / 1024.0) + 1e-7;
            assert!((x - y).abs() <= bound, "{x} → {y}");
        }
    }

    #[test]
    fn int8_roundtrip_error_bound_per_row() {
        let t = t2x4();
        let c = compress_tensor(CodecKind::QuantInt8, &t).unwrap();
        assert_eq!(c.extra.len(), 2 * 8);
        assert_eq!(c.payload.len(), t.len());
        let back = decompress_stats(&c).unwrap();
        let v = t.as_f32().unwrap();
        let w = back.as_f32().unwrap();
        for r in 0..2 {
            let row = &v[r * 4..(r + 1) * 4];
            let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let half_step = (hi - lo) / 255.0 / 2.0;
            for (i, &x) in row.iter().enumerate() {
                let y = w[r * 4 + i];
                assert!((x - y).abs() <= half_step * 1.0001 + 1e-4,
                        "row {r}: {x} → {y} (half-step {half_step})");
            }
        }
    }

    #[test]
    fn int8_survives_extreme_row_ranges() {
        // (hi − lo) overflows f32 here; the f64 range path must still
        // quantize the row instead of collapsing it to the constant lo.
        let t = Tensor::f32(vec![1, 4], vec![3.0e38, -3.0e38, 0.0, 1.0e38]);
        let c = compress_tensor(CodecKind::QuantInt8, &t).unwrap();
        let back = decompress_stats(&c).unwrap();
        let w = back.as_f32().unwrap();
        assert!(w.iter().all(|x| x.is_finite()), "{w:?}");
        let step = (3.0e38f64 - (-3.0e38f64)) / 255.0;
        for (x, y) in t.as_f32().unwrap().iter().zip(w) {
            assert!((*x as f64 - *y as f64).abs() <= step * 0.5001,
                    "{x} → {y}");
        }
        // Endpoints land on the outermost grid points (within a couple
        // ulp of the stored f32 scale — far inside the half-step bound
        // asserted above), and crucially the row was NOT collapsed.
        assert!(w[0] > 2.9e38 && w[1] < -2.9e38, "{w:?}");
    }

    #[test]
    fn int8_constant_row_is_exact() {
        let t = Tensor::f32(vec![2, 3], vec![4.5; 6]);
        let c = compress_tensor(CodecKind::QuantInt8, &t).unwrap();
        assert_eq!(decompress_stats(&c).unwrap(), t);
    }

    #[test]
    fn topk_exact_support_recovery() {
        let t = Tensor::f32(vec![2, 4],
                            vec![0.1, -9.0, 0.2, 3.0, -0.3, 0.0, 8.0, 1.0]);
        let c = compress_tensor(CodecKind::TopK(3), &t).unwrap();
        assert_eq!(c.payload.len(), 3 * 8);
        let back = decompress_stats(&c).unwrap();
        // |−9| > |8| > |3| are the top 3; everything else is zero.
        assert_eq!(back.as_f32().unwrap(),
                   &[0.0, -9.0, 0.0, 3.0, 0.0, 0.0, 8.0, 0.0]);
    }

    #[test]
    fn topk_clamps_k_to_numel_and_breaks_ties_low_index() {
        let t = Tensor::f32(vec![3], vec![2.0, -2.0, 1.0]);
        let c = compress_tensor(CodecKind::TopK(100), &t).unwrap();
        assert_eq!(c.kind, CodecKind::TopK(3));
        assert_eq!(decompress_stats(&c).unwrap(), t);
        let c1 = compress_tensor(CodecKind::TopK(1), &t).unwrap();
        // Tie between |2.0| (idx 0) and |−2.0| (idx 1): idx 0 wins.
        assert_eq!(decompress_stats(&c1).unwrap().as_f32().unwrap(),
                   &[2.0, 0.0, 0.0]);
    }

    #[test]
    fn topk_decode_rejects_corrupt_indices() {
        let t = Tensor::f32(vec![4], vec![1.0, 2.0, 3.0, 4.0]);
        let mut c = compress_tensor(CodecKind::TopK(2), &t).unwrap();
        // Out-of-range index.
        c.payload[0..4].copy_from_slice(&99u32.to_le_bytes());
        assert!(decompress_stats(&c).is_err());
        // Non-increasing indices.
        let mut c = compress_tensor(CodecKind::TopK(2), &t).unwrap();
        let first = c.payload[0..8].to_vec();
        c.payload[8..16].copy_from_slice(&first);
        assert!(decompress_stats(&c).is_err());
    }

    #[test]
    fn decompress_rejects_length_mismatches() {
        let t = t2x4();
        for kind in [CodecKind::Identity, CodecKind::Fp16,
                     CodecKind::QuantInt8, CodecKind::TopK(2)] {
            let mut c = compress_tensor(kind, &t).unwrap();
            c.payload.push(0);
            assert!(decompress_stats(&c).is_err(), "{}", kind.label());
        }
        let mut c = compress_tensor(CodecKind::QuantInt8, &t).unwrap();
        c.extra.truncate(8);
        assert!(decompress_stats(&c).is_err());
    }

    #[test]
    fn lossy_codecs_shrink_the_block() {
        let t = Tensor::f32(vec![256, 64],
                            (0..256 * 64).map(|i| (i as f32).sin())
                                          .collect::<Vec<_>>());
        let id = compress_tensor(CodecKind::Identity, &t).unwrap()
            .wire_block_bytes();
        for kind in [CodecKind::Fp16, CodecKind::QuantInt8,
                     CodecKind::TopK(1024)] {
            let c = compress_tensor(kind, &t).unwrap();
            assert!(c.wire_block_bytes() < id,
                    "{} block {} !< identity {}", kind.label(),
                    c.wire_block_bytes(), id);
        }
    }

    #[test]
    fn expected_lens_guards_overflow() {
        assert!(expected_lens(CodecKind::Identity,
                              &[usize::MAX, usize::MAX]).is_err());
        assert!(expected_lens(CodecKind::Fp16, &[usize::MAX / 2, 4])
            .is_err());
        assert!(expected_lens(CodecKind::TopK(100), &[4]).is_err(),
                "k > numel rejected");
    }
}

#[cfg(test)]
mod prop_tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::prop;

    fn random_stats(rng: &mut crate::util::rng::Pcg) -> Tensor {
        let rows = 1 + rng.gen_range(12) as usize;
        let cols = 1 + rng.gen_range(24) as usize;
        let scale = 10f32.powi(rng.gen_range(7) as i32 - 3);
        let v: Vec<f32> = (0..rows * cols)
            .map(|_| rng.next_normal() * scale)
            .collect();
        Tensor::f32(vec![rows, cols], v)
    }

    #[test]
    fn prop_fp16_error_within_documented_bound() {
        prop::check("fp16 bound", |rng| {
            let t = random_stats(rng);
            let c = compress_tensor(CodecKind::Fp16, &t)
                .map_err(|e| e.to_string())?;
            let back = decompress_stats(&c).map_err(|e| e.to_string())?;
            for (x, y) in t.as_f32().unwrap().iter()
                           .zip(back.as_f32().unwrap()) {
                let bound = x.abs() / 1024.0 + 6e-8;
                prop_assert!((x - y).abs() <= bound,
                             "fp16 {x} → {y} exceeds {bound}");
            }
            Ok(())
        });
    }

    #[test]
    fn prop_int8_error_within_half_step() {
        prop::check("int8 bound", |rng| {
            let t = random_stats(rng);
            let cols = t.shape[1];
            let c = compress_tensor(CodecKind::QuantInt8, &t)
                .map_err(|e| e.to_string())?;
            let back = decompress_stats(&c).map_err(|e| e.to_string())?;
            let v = t.as_f32().unwrap();
            let w = back.as_f32().unwrap();
            for r in 0..t.shape[0] {
                let row = &v[r * cols..(r + 1) * cols];
                let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
                let hi =
                    row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let tol = (hi - lo) / 255.0 * 0.5001
                    + hi.abs().max(lo.abs()) * 1e-6;
                for (i, &x) in row.iter().enumerate() {
                    let y = w[r * cols + i];
                    prop_assert!((x - y).abs() <= tol,
                                 "int8 row {r}: {x} → {y} (tol {tol})");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_topk_recovers_exact_support() {
        prop::check("topk support", |rng| {
            let t = random_stats(rng);
            let n = t.len();
            let k = 1 + rng.gen_range(n as u32);
            let c = compress_tensor(CodecKind::TopK(k), &t)
                .map_err(|e| e.to_string())?;
            let back = decompress_stats(&c).map_err(|e| e.to_string())?;
            let v = t.as_f32().unwrap();
            let w = back.as_f32().unwrap();
            let kept: Vec<usize> =
                (0..n).filter(|&i| w[i] != 0.0).collect();
            // Kept values are bit-exact.
            for &i in &kept {
                prop_assert!(v[i] == w[i], "kept value changed at {i}");
            }
            // No dropped |x| strictly exceeds a kept |x| (support is a
            // true top-k set; zero-valued inputs may be "kept" as zeros).
            let min_kept = kept
                .iter()
                .map(|&i| v[i].abs())
                .fold(f32::INFINITY, f32::min);
            for i in 0..n {
                if w[i] == 0.0 && v[i] != 0.0 {
                    prop_assert!(
                        v[i].abs() <= min_kept,
                        "dropped |{}| at {i} exceeds kept min {min_kept}",
                        v[i]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_sender_roundtrip_matches_receiver_decode() {
        // The cache-consistency invariant: the tensor the sender caches
        // (local roundtrip) is bit-identical to what the receiver
        // decodes from the same block.
        prop::check("sender/receiver agree", |rng| {
            let t = random_stats(rng);
            for kind in [CodecKind::Fp16, CodecKind::QuantInt8,
                         CodecKind::TopK(1 + rng.gen_range(64))] {
                let block = compress_tensor(kind, &t)
                    .map_err(|e| e.to_string())?;
                let sender = decompress_stats(&block)
                    .map_err(|e| e.to_string())?;
                let receiver = decompress_stats(&block)
                    .map_err(|e| e.to_string())?;
                prop_assert!(sender == receiver,
                             "{} divergence", kind.label());
            }
            Ok(())
        });
    }
}
