//! Host-side tensor type: the unit of cross-party exchange and caching.
//!
//! `Tensor` is deliberately XLA-free: the protocol codec, the WAN
//! simulator and the workset table all operate on host tensors; only the
//! runtime layer (rust/src/runtime) converts to/from `xla::Literal` at the
//! PJRT boundary.
//!
//! The payload is a shared `Arc<[T]>` buffer (see DESIGN.md §4): cloning a
//! `Tensor` bumps a refcount instead of copying `batch × dim` elements, so
//! the workset table, the protocol layer and both coordinator workers can
//! hold handles to one allocation. The buffers are immutable once
//! constructed — sharing is safe by construction, no interior mutability.

use std::sync::Arc;

/// Element type. The VFL wire only ever carries f32 statistics and i32
/// feature ids, matching the artifact ABI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        4
    }

    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
        }
    }

    pub fn from_code(c: u8) -> anyhow::Result<Self> {
        match c {
            0 => Ok(DType::F32),
            1 => Ok(DType::I32),
            _ => anyhow::bail!("unknown dtype code {c}"),
        }
    }
}

/// Shared, immutable payload. `Clone` is a refcount bump, never a copy.
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Arc<[f32]>),
    I32(Arc<[i32]>),
}

/// Dense host tensor (row-major). `Clone` shares the payload allocation
/// (O(ndim), independent of element count).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    /// Build an f32 tensor. Accepts a `Vec<f32>` (moved into a fresh
    /// shared buffer) or an existing `Arc<[f32]>` (shared, zero-copy).
    pub fn f32(shape: Vec<usize>, data: impl Into<Arc<[f32]>>) -> Self {
        let data = data.into();
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape/data mismatch");
        Tensor { shape, data: Data::F32(data) }
    }

    /// Build an i32 tensor. Accepts `Vec<i32>` or `Arc<[i32]>`.
    pub fn i32(shape: Vec<usize>, data: impl Into<Arc<[i32]>>) -> Self {
        let data = data.into();
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape/data mismatch");
        Tensor { shape, data: Data::I32(data) }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn scalar_f32(x: f32) -> Self {
        Tensor::f32(vec![], vec![x])
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Payload size on the wire (excluding framing/shape header) — the
    /// quantity the WAN simulator charges bandwidth for.
    pub fn size_bytes(&self) -> usize {
        self.len() * self.dtype().size_bytes()
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => anyhow::bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => anyhow::bail!("expected i32 tensor"),
        }
    }

    /// True when both tensors are handles onto the same payload allocation
    /// — the zero-copy invariant the workset/codec tests assert.
    pub fn shares_data(&self, other: &Tensor) -> bool {
        match (&self.data, &other.data) {
            (Data::F32(a), Data::F32(b)) => Arc::ptr_eq(a, b),
            (Data::I32(a), Data::I32(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }

    /// Row-wise view helpers for [B, D] matrices.
    pub fn rows(&self) -> usize {
        *self.shape.first().unwrap_or(&1)
    }

    /// Row `r` of a [B, D…] f32 tensor as a flat slice. Errors (instead of
    /// panicking) on non-f32 tensors and out-of-range rows; scalars and
    /// 1-D tensors are treated as [1, 1] and [B, 1] respectively.
    pub fn row_f32(&self, r: usize) -> anyhow::Result<&[f32]> {
        let v = self.as_f32()?;
        let rows = self.rows();
        anyhow::ensure!(
            r < rows,
            "row index {r} out of range for shape {:?}", self.shape
        );
        let d: usize = match self.shape.get(1..) {
            Some(rest) => rest.iter().product(),
            None => 1,
        };
        let start = r * d;
        let end = start + d;
        anyhow::ensure!(
            end <= v.len(),
            "row {r} exceeds payload (shape {:?}, len {})",
            self.shape, v.len()
        );
        Ok(&v[start..end])
    }

    /// Element-wise sum of identically-shaped f32 tensors — the label
    /// party's Σ_k Z_k aggregation over K activation lanes. A
    /// single-element slice returns a shared handle (no copy), so the
    /// two-party path through this function stays zero-copy; K > 1
    /// performs exactly one allocation for the accumulator.
    pub fn sum_f32(parts: &[Tensor]) -> anyhow::Result<Tensor> {
        let first = parts
            .first()
            .ok_or_else(|| anyhow::anyhow!("sum_f32 over zero tensors"))?;
        if parts.len() == 1 {
            first.as_f32()?; // dtype check even on the zero-copy path
            return Ok(first.clone());
        }
        let mut acc: Vec<f32> = first.as_f32()?.to_vec();
        for t in &parts[1..] {
            anyhow::ensure!(
                t.shape == first.shape,
                "sum_f32 shape mismatch: {:?} vs {:?}", t.shape,
                first.shape
            );
            for (a, x) in acc.iter_mut().zip(t.as_f32()?) {
                *a += *x;
            }
        }
        Ok(Tensor::f32(first.shape.clone(), acc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.len(), 6);
        assert_eq!(t.size_bytes(), 24);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.row_f32(1).unwrap().len(), 3);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn rejects_shape_mismatch() {
        Tensor::f32(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn scalar_has_empty_shape() {
        let t = Tensor::scalar_f32(1.5);
        assert!(t.shape.is_empty());
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn dtype_codes_roundtrip() {
        for d in [DType::F32, DType::I32] {
            assert_eq!(DType::from_code(d.code()).unwrap(), d);
        }
        assert!(DType::from_code(9).is_err());
    }

    #[test]
    fn sum_f32_aggregates_and_stays_zero_copy_for_one() {
        let a = Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::f32(vec![2, 2], vec![0.5, -2.0, 1.0, 0.0]);
        let c = Tensor::f32(vec![2, 2], vec![-1.5, 0.0, 0.0, 1.0]);
        let s = Tensor::sum_f32(&[a.clone(), b, c]).unwrap();
        assert_eq!(s.as_f32().unwrap(), &[0.0, 0.0, 4.0, 5.0]);
        // K = 1: handle share, not a copy.
        let one = Tensor::sum_f32(std::slice::from_ref(&a)).unwrap();
        assert!(one.shares_data(&a));
        // Errors, not panics, on misuse.
        assert!(Tensor::sum_f32(&[]).is_err());
        let short = Tensor::f32(vec![3], vec![0.0; 3]);
        assert!(Tensor::sum_f32(&[a.clone(), short]).is_err());
        let ids = Tensor::i32(vec![1], vec![3]);
        assert!(Tensor::sum_f32(&[ids]).is_err());
    }

    #[test]
    fn clone_shares_payload() {
        let t = Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let u = t.clone();
        assert!(t.shares_data(&u));
        assert_eq!(t, u);
        // Independent allocations with equal contents compare equal but
        // do not share.
        let w = Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t, w);
        assert!(!t.shares_data(&w));
    }

    #[test]
    fn construct_from_shared_buffer_is_zero_copy() {
        let buf: std::sync::Arc<[f32]> = vec![1.0f32, 2.0, 3.0].into();
        let t = Tensor::f32(vec![3], buf.clone());
        match &t.data {
            Data::F32(v) => assert!(std::sync::Arc::ptr_eq(v, &buf)),
            _ => panic!("expected f32"),
        }
    }
}

#[cfg(test)]
mod extra_tests {
    use super::*;

    #[test]
    fn row_view_matches_manual_slice() {
        let t = Tensor::f32(vec![3, 4], (0..12).map(|x| x as f32)
                                                .collect::<Vec<_>>());
        assert_eq!(t.row_f32(0).unwrap(), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(t.row_f32(2).unwrap(), &[8.0, 9.0, 10.0, 11.0]);
    }

    #[test]
    fn i32_accessor_rejects_f32_and_vice_versa() {
        let f = Tensor::zeros_f32(vec![2]);
        assert!(f.as_i32().is_err());
        let i = Tensor::i32(vec![2], vec![1, 2]);
        assert!(i.as_f32().is_err());
        assert!(i.row_f32(0).is_err());
    }

    #[test]
    fn size_bytes_counts_payload() {
        assert_eq!(Tensor::zeros_f32(vec![10, 10]).size_bytes(), 400);
        assert_eq!(Tensor::i32(vec![3], vec![0; 3]).size_bytes(), 12);
    }

    #[test]
    fn row_f32_bounds_checked() {
        let t = Tensor::f32(vec![3, 4], vec![0.0; 12]);
        assert!(t.row_f32(2).is_ok());
        assert!(t.row_f32(3).is_err());
        assert!(t.row_f32(usize::MAX).is_err());
    }

    #[test]
    fn row_f32_handles_scalar_and_1d_shapes() {
        // Scalar: one row of one element.
        let s = Tensor::scalar_f32(7.0);
        assert_eq!(s.row_f32(0).unwrap(), &[7.0]);
        assert!(s.row_f32(1).is_err());
        // 1-D: each row is one element.
        let v = Tensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        assert_eq!(v.row_f32(1).unwrap(), &[2.0]);
        assert!(v.row_f32(3).is_err());
    }
}
