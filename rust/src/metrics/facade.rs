//! Lock-free metrics facade (DESIGN.md §10).
//!
//! The observability plane splits into a *recorder* side (this module)
//! and an *exporter* side ([`super::exporters`]), modeled on the
//! metrics-rs facade/exporter split but hand-rolled per the vendoring
//! discipline: the hot path needs exactly three handle types and a
//! relaxed `fetch_add`, not an ecosystem.
//!
//! - [`Counter`] / [`Gauge`] / [`Histogram`] — cheap cloneable handles
//!   over `Arc<AtomicU64>` cells. Registration (name → cell) happens
//!   once, outside the hot path, behind a `Mutex`; a bump through a
//!   held handle is a single relaxed atomic op — no lock, no
//!   allocation, no name hashing.
//! - [`LinkHandles`] — the pre-registered handle bundle that replaced
//!   the transport-private stats struct: per-link messages, wire
//!   bytes, raw bytes, and busy nanoseconds. Transports always own a
//!   (detached) bundle; [`Registry::bind_link`] late-binds the same
//!   cells into the session registry, so enabling observability never
//!   changes a transport constructor or the wire.
//! - [`Registry`] — the session-wide cell store every exporter
//!   snapshots: named scalars, the per-link map, the current round,
//!   and the bounded [`SessionEvent`] log.
//! - [`EventSink`] — how lifecycle events reach the registry. The
//!   supervisor, checkpoint retry, and rejoin paths all emit through
//!   this trait; the bounded log is just the [`Registry`]'s
//!   implementation of it, and tests can subscribe a [`ChannelSink`]
//!   instead of scraping `RunRecord`.
//!
//! Everything here is additive at run time: a session that never binds
//! a registry and never installs an exporter performs the same atomic
//! bumps as before (`bench_hotpath` §7 pins this) and puts identical
//! bytes on the wire.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::session::supervisor::SessionEvent;
use crate::session::PartyId;
use crate::transport::LinkStats;

/// Cap on retained lifecycle events: a run that flaps for hours must
/// not grow an unbounded event log. Beyond the cap events are counted
/// ([`Registry::dropped_events`]), not stored.
pub const EVENTS_CAP: usize = 4096;

// ---- handles ---------------------------------------------------------------

/// Monotonic counter handle. Clones share the cell.
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// A fresh cell not (yet) visible to any registry.
    pub fn detached() -> Self {
        Counter::default()
    }

    /// Hot path: one relaxed atomic add.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value gauge handle storing `f64` bits. Clones share the cell.
/// The zeroed default decodes as `0.0`.
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn detached() -> Self {
        Gauge::default()
    }

    /// Hot path: one relaxed atomic store.
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Streaming histogram handle: count, sum, max. Enough for "how long
/// does a round take" without bucket configuration; the sum is an f64
/// maintained by a CAS loop (contention is per-observation, and
/// observations are per-round — not per-message — so the loop never
/// spins in practice).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    count: Arc<AtomicU64>,
    sum_bits: Arc<AtomicU64>,
    max_bits: Arc<AtomicU64>,
}

/// Point-in-time view of a [`Histogram`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: f64,
    pub max: f64,
}

impl Histogram {
    pub fn detached() -> Self {
        Histogram::default()
    }

    pub fn observe(&self, v: f64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        let mut cur = self.max_bits.load(Ordering::Relaxed);
        while v > f64::from_bits(cur) {
            match self.max_bits.compare_exchange_weak(
                cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
            max: f64::from_bits(self.max_bits.load(Ordering::Relaxed)),
        }
    }
}

// ---- per-link handle bundle ------------------------------------------------

/// The pre-registered handle bundle for one directed link (what
/// `LinkStats` *was* as a by-value struct). Transports bump these four
/// cells on every send; everything else — session registry, scrape
/// endpoint, push stream, `RunRecord` — reads the same cells.
#[derive(Clone, Debug, Default)]
pub struct LinkHandles {
    pub messages: Counter,
    pub wire_bytes: Counter,
    pub raw_bytes: Counter,
    pub busy_nanos: Counter,
    /// Faults a chaos wrapper injected on this link
    /// ([`crate::transport::fault::FaultTransport`] bumps it; zero on
    /// any undisturbed link). Outside `LinkStats` on purpose: byte
    /// parity assertions compare `snapshot()` triples, and an injected
    /// fault must never disturb those. Like `busy`, the count is
    /// per-transport-incarnation — a rejoin's transport swap starts a
    /// fresh cell (the swap charges `stats()`, which carries no fault
    /// count).
    pub faults_injected: Counter,
}

impl LinkHandles {
    /// Fresh cells not (yet) bound to any registry. Every transport
    /// starts detached; [`Registry::bind_link`] makes the cells
    /// observable without touching the transport.
    pub fn detached() -> Self {
        LinkHandles::default()
    }

    /// Hot path: exactly four relaxed `fetch_add`s — identical to the
    /// historic transport-private counter struct.
    #[inline]
    pub fn record(&self, wire_bytes: usize, raw_bytes: usize,
                  busy: Duration) {
        self.messages.add(1);
        self.wire_bytes.add(wire_bytes as u64);
        self.raw_bytes.add(raw_bytes as u64);
        self.busy_nanos.add(busy.as_nanos() as u64);
    }

    /// Point-in-time totals as the classic stats value.
    pub fn snapshot(&self) -> LinkStats {
        LinkStats {
            messages: self.messages.get(),
            bytes: self.wire_bytes.get(),
            raw_bytes: self.raw_bytes.get(),
            busy: Duration::from_nanos(self.busy_nanos.get()),
        }
    }

    /// One-time bulk add of a predecessor's totals. This is how a
    /// `Rejoin` transport swap keeps a lane's accounting continuous:
    /// charge the replacement's fresh cells with the dead transport's
    /// final snapshot, then keep counting.
    pub fn charge(&self, s: LinkStats) {
        self.messages.add(s.messages);
        self.wire_bytes.add(s.bytes);
        self.raw_bytes.add(s.raw_bytes);
        self.busy_nanos.add(s.busy.as_nanos() as u64);
    }
}

/// One directed link's registry row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkRow {
    pub src: PartyId,
    pub dst: PartyId,
    pub stats: LinkStats,
    /// Injected-fault count of the bound handles (0 on clean links).
    pub faults: u64,
}

// ---- event sinks -----------------------------------------------------------

/// Where lifecycle events go. Producers (supervisor edges, straggler
/// timeouts, checkpoint retry, rejoin paths) call [`EventSink::emit`];
/// what happens next is the sink's business: the [`Registry`] keeps a
/// bounded log plus per-kind counters, a [`CounterSink`] keeps only
/// the counters, a [`ChannelSink`] forwards to a test.
pub trait EventSink: Send + Sync {
    fn emit(&self, event: &SessionEvent);
}

/// Discards events (the unsupervised/undetached default).
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl EventSink for NullSink {
    fn emit(&self, _event: &SessionEvent) {}
}

/// Bumps the registry's per-kind event counters without appending to
/// its log. Feature parties in a shared-registry (in-proc) session use
/// this so `RunRecord.events` stays the label party's fault history,
/// exactly as before the facade.
#[derive(Clone)]
pub struct CounterSink(pub Arc<Registry>);

impl EventSink for CounterSink {
    fn emit(&self, event: &SessionEvent) {
        self.0.count_event(event);
    }
}

/// Forwards every event over an mpsc channel (tests subscribe this
/// instead of scraping `RunRecord`). A dropped receiver is ignored:
/// observability must never fail the session.
pub struct ChannelSink(Mutex<Sender<SessionEvent>>);

impl ChannelSink {
    pub fn new(tx: Sender<SessionEvent>) -> Self {
        ChannelSink(Mutex::new(tx))
    }
}

impl EventSink for ChannelSink {
    fn emit(&self, event: &SessionEvent) {
        let _ = self.0.lock().unwrap().send(event.clone());
    }
}

/// Emits to every inner sink in order.
#[derive(Default)]
pub struct FanSink(pub Vec<Arc<dyn EventSink>>);

impl EventSink for FanSink {
    fn emit(&self, event: &SessionEvent) {
        for s in &self.0 {
            s.emit(event);
        }
    }
}

// ---- registry --------------------------------------------------------------

/// The session-wide metric store. All maps are name → shared cell;
/// lookups (registration) take a `Mutex` and happen outside the hot
/// path, bumps go through handles and never touch the registry again.
///
/// Exporters read via [`Registry::snapshot`] /
/// [`Registry::link_rows`]; the snapshot is not atomic across cells
/// (each load is), which is the standard scrape contract.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Counter>>,
    gauges: Mutex<BTreeMap<String, Gauge>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
    links: Mutex<BTreeMap<(u16, u16), LinkHandles>>,
    round: AtomicU64,
    events: Mutex<Vec<SessionEvent>>,
    dropped_events: AtomicU64,
}

/// Point-in-time view of every named scalar plus the link rows.
#[derive(Debug, Clone, Default)]
pub struct RegistrySnapshot {
    pub round: u64,
    pub links: Vec<LinkRow>,
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub histograms: Vec<(String, HistogramSnapshot)>,
}

impl Registry {
    pub fn new() -> Arc<Self> {
        Arc::new(Registry::default())
    }

    /// Get-or-register the counter `name` (cold path). `name` may carry
    /// a Prometheus-style label block: `celu_events_total{kind="x"}`.
    pub fn counter(&self, name: &str) -> Counter {
        self.counters.lock().unwrap()
            .entry(name.to_string()).or_default().clone()
    }

    /// Get-or-register the gauge `name` (cold path).
    pub fn gauge(&self, name: &str) -> Gauge {
        self.gauges.lock().unwrap()
            .entry(name.to_string()).or_default().clone()
    }

    /// Get-or-register the histogram `name` (cold path).
    pub fn histogram(&self, name: &str) -> Histogram {
        self.histograms.lock().unwrap()
            .entry(name.to_string()).or_default().clone()
    }

    /// Late-bind a transport's handle bundle as the registry's row for
    /// the directed link `src → dst`. Idempotent; rebinding (a `Rejoin`
    /// transport swap) replaces the row — last bound wins — so pair it
    /// with [`LinkHandles::charge`] to keep totals continuous.
    pub fn bind_link(&self, src: PartyId, dst: PartyId, h: &LinkHandles) {
        self.links.lock().unwrap().insert((src.0, dst.0), h.clone());
    }

    /// The bound handle bundle for `src → dst`, if any.
    pub fn link(&self, src: PartyId, dst: PartyId) -> Option<LinkHandles> {
        self.links.lock().unwrap().get(&(src.0, dst.0)).cloned()
    }

    /// Every bound link's current totals, ordered by (src, dst).
    pub fn link_rows(&self) -> Vec<LinkRow> {
        self.links.lock().unwrap()
            .iter()
            .map(|(&(src, dst), h)| LinkRow {
                src: PartyId(src),
                dst: PartyId(dst),
                stats: h.snapshot(),
                faults: h.faults_injected.get(),
            })
            .collect()
    }

    /// Publish the session's current communication round.
    pub fn set_round(&self, round: u64) {
        self.round.store(round, Ordering::Relaxed);
    }

    pub fn round(&self) -> u64 {
        self.round.load(Ordering::Relaxed)
    }

    /// Bump the per-kind event counter without logging the event (the
    /// [`CounterSink`] path; also the overflow path past `EVENTS_CAP`).
    fn count_event(&self, event: &SessionEvent) {
        self.counter(&format!("celu_events_total{{kind=\"{}\"}}",
                              event.kind()))
            .inc();
    }

    /// Retained lifecycle events (bounded by [`EVENTS_CAP`]).
    pub fn events(&self) -> Vec<SessionEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Drain the retained events (the terminal `RunRecord` observer).
    pub fn take_events(&self) -> Vec<SessionEvent> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }

    /// Events counted but not retained (log at capacity).
    pub fn dropped_events(&self) -> u64 {
        self.dropped_events.load(Ordering::Relaxed)
    }

    /// Point-in-time view of everything named plus the link rows.
    pub fn snapshot(&self) -> RegistrySnapshot {
        RegistrySnapshot {
            round: self.round(),
            links: self.link_rows(),
            counters: self.counters.lock().unwrap()
                .iter().map(|(n, c)| (n.clone(), c.get())).collect(),
            gauges: self.gauges.lock().unwrap()
                .iter().map(|(n, g)| (n.clone(), g.get())).collect(),
            histograms: self.histograms.lock().unwrap()
                .iter().map(|(n, h)| (n.clone(), h.snapshot())).collect(),
        }
    }
}

impl EventSink for Registry {
    /// The bounded log + per-kind counters: the historic
    /// `Supervisor::record` behaviour as one sink implementation.
    fn emit(&self, event: &SessionEvent) {
        log::info!("session event: {} (party {:?}, round {})",
                   event.kind(), event.party(), event.round());
        self.count_event(event);
        let mut log = self.events.lock().unwrap();
        if log.len() >= EVENTS_CAP {
            self.dropped_events.fetch_add(1, Ordering::Relaxed);
            return;
        }
        log.push(event.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_handles_share_the_cell() {
        let reg = Registry::new();
        let a = reg.counter("x_total");
        let b = reg.counter("x_total");
        a.add(3);
        b.inc();
        assert_eq!(a.get(), 4);
        assert_eq!(reg.counter("x_total").get(), 4);
        // A different name is a different cell.
        assert_eq!(reg.counter("y_total").get(), 0);
    }

    #[test]
    fn gauge_roundtrips_f64() {
        let g = Gauge::detached();
        assert_eq!(g.get(), 0.0);
        g.set(-3.75e9);
        assert_eq!(g.get(), -3.75e9);
    }

    #[test]
    fn histogram_tracks_count_sum_max() {
        let h = Histogram::detached();
        h.observe(2.0);
        h.observe(5.0);
        h.observe(1.0);
        let s = h.snapshot();
        assert_eq!(s.count, 3);
        assert_eq!(s.sum, 8.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn hammer_counter_sums_are_exact() {
        // The acceptance bar for a lock-free recorder: concurrent bumps
        // through independently-cloned handles lose nothing.
        const THREADS: usize = 8;
        const BUMPS: u64 = 100_000;
        let reg = Registry::new();
        let c = reg.counter("hammer_total");
        let h = reg.histogram("hammer_obs");
        let link = LinkHandles::detached();
        reg.bind_link(PartyId(1), PartyId(0), &link);
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let c = c.clone();
                let h = h.clone();
                let link = link.clone();
                std::thread::spawn(move || {
                    for _ in 0..BUMPS {
                        c.inc();
                        link.record(7, 11, Duration::from_nanos(3));
                    }
                    // Histogram contention is per-observation; keep it
                    // integer-valued so the f64 sum is exact.
                    for _ in 0..1_000 {
                        h.observe(1.0);
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        let n = THREADS as u64 * BUMPS;
        assert_eq!(c.get(), n);
        assert_eq!(h.snapshot(),
                   HistogramSnapshot { count: THREADS as u64 * 1_000,
                                       sum: (THREADS * 1_000) as f64,
                                       max: 1.0 });
        let row = &reg.link_rows()[0];
        assert_eq!((row.src, row.dst), (PartyId(1), PartyId(0)));
        assert_eq!(row.stats.messages, n);
        assert_eq!(row.stats.bytes, 7 * n);
        assert_eq!(row.stats.raw_bytes, 11 * n);
        assert_eq!(row.stats.busy, Duration::from_nanos(3 * n));
    }

    #[test]
    fn rebind_with_charge_keeps_totals_continuous() {
        // The rejoin discipline: a replacement transport's fresh cells
        // are charged with the dead one's final snapshot, then rebound.
        let reg = Registry::new();
        let old = LinkHandles::detached();
        reg.bind_link(PartyId(2), PartyId(0), &old);
        old.record(100, 200, Duration::from_millis(5));
        old.record(100, 200, Duration::from_millis(5));

        let fresh = LinkHandles::detached();
        fresh.charge(old.snapshot());
        reg.bind_link(PartyId(2), PartyId(0), &fresh);
        fresh.record(50, 50, Duration::from_millis(1));

        let rows = reg.link_rows();
        assert_eq!(rows.len(), 1, "rebind must replace, not append");
        assert_eq!(rows[0].stats.messages, 3);
        assert_eq!(rows[0].stats.bytes, 250);
        assert_eq!(rows[0].stats.raw_bytes, 450);
        assert_eq!(rows[0].stats.busy, Duration::from_millis(11));
        // The snapshot-as-LinkStats path agrees.
        assert_eq!(reg.link(PartyId(2), PartyId(0)).unwrap().snapshot(),
                   rows[0].stats);
    }

    #[test]
    fn registry_sink_logs_and_counts() {
        let reg = Registry::new();
        let e = SessionEvent::StragglerTimeout { party: PartyId(1),
                                                 round: 4 };
        reg.emit(&e);
        reg.emit(&SessionEvent::PeerLost { party: PartyId(2), round: 5 });
        assert_eq!(reg.events().len(), 2);
        assert_eq!(reg.events()[0], e);
        assert_eq!(
            reg.counter("celu_events_total{kind=\"straggler_timeout\"}")
                .get(),
            1);
        assert_eq!(reg.counter("celu_events_total{kind=\"peer_lost\"}")
                       .get(),
                   1);
        assert_eq!(reg.dropped_events(), 0);
    }

    #[test]
    fn event_log_is_bounded() {
        let reg = Registry::new();
        for r in 0..(EVENTS_CAP as u64 + 10) {
            reg.emit(&SessionEvent::StragglerTimeout {
                party: PartyId(1), round: r });
        }
        assert_eq!(reg.events().len(), EVENTS_CAP);
        assert_eq!(reg.dropped_events(), 10);
        // Overflowed events still count.
        assert_eq!(
            reg.counter("celu_events_total{kind=\"straggler_timeout\"}")
                .get(),
            EVENTS_CAP as u64 + 10);
    }

    #[test]
    fn counter_sink_counts_without_logging() {
        let reg = Registry::new();
        let sink = CounterSink(reg.clone());
        sink.emit(&SessionEvent::PeerRejoined { party: PartyId(1),
                                                round: 2 });
        assert!(reg.events().is_empty());
        assert_eq!(reg.counter("celu_events_total{kind=\"peer_rejoined\"}")
                       .get(),
                   1);
    }

    #[test]
    fn channel_and_fan_sinks_forward() {
        let reg = Registry::new();
        let (tx, rx) = std::sync::mpsc::channel();
        let fan = FanSink(vec![reg.clone() as Arc<dyn EventSink>,
                               Arc::new(ChannelSink::new(tx))]);
        let e = SessionEvent::CheckpointFailed {
            round: 9, error: "disk \"full\"".into() };
        fan.emit(&e);
        assert_eq!(rx.try_recv().unwrap(), e);
        assert_eq!(reg.events(), vec![e]);
        // A dropped receiver must not panic the producer.
        drop(rx);
        fan.emit(&SessionEvent::CheckpointWritten {
            round: 10, path: "p".into() });
    }

    #[test]
    fn snapshot_covers_all_maps() {
        let reg = Registry::new();
        reg.counter("a_total").add(5);
        reg.gauge("b").set(1.5);
        reg.histogram("c").observe(2.0);
        reg.set_round(42);
        let link = LinkHandles::detached();
        link.record(10, 20, Duration::ZERO);
        reg.bind_link(PartyId(1), PartyId(0), &link);
        let snap = reg.snapshot();
        assert_eq!(snap.round, 42);
        assert_eq!(snap.counters, vec![("a_total".into(), 5)]);
        assert_eq!(snap.gauges, vec![("b".into(), 1.5)]);
        assert_eq!(snap.histograms.len(), 1);
        assert_eq!(snap.histograms[0].1.count, 1);
        assert_eq!(snap.links.len(), 1);
        assert_eq!(snap.links[0].stats.bytes, 10);
    }
}
