//! Convergence series + staleness telemetry recorded during a run.
//!
//! `RunRecord` is the unit every experiment harness consumes: the AUC
//! series indexed by communication round *and* wall-clock time (the two
//! x-axes of Figures 5 and 6), the loss curve, the comm/compute time
//! split (the §1 ">90% communication" claim), and the cosine-similarity
//! quantiles per local step (Figure 5(d)).

use std::time::Duration;

use crate::util::json::{arr_f64, num, obj, Json};
use crate::util::stats::quantile;

/// One evaluation point on the convergence curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeriesPoint {
    /// Communication rounds completed when evaluated (paper Fig. 5 x-axis).
    pub comm_round: u64,
    /// Wall-clock seconds since training start (paper Fig. 6 x-axis).
    pub wall_s: f64,
    /// Validation AUC.
    pub auc: f64,
    /// Smoothed training loss.
    pub loss: f64,
    /// Total updates (exact + local) applied so far at Party B.
    pub updates: u64,
}

/// Cosine-similarity telemetry: per-local-step quantile rows (Fig. 5(d)).
#[derive(Debug, Clone, Default)]
pub struct CosineRecorder {
    /// (local_step, wstats[8]) rows as emitted by the artifacts:
    /// [min, q10, q25, q50, q75, q90, mean, frac_kept].
    pub rows: Vec<(u64, [f64; 8])>,
}

impl CosineRecorder {
    pub fn push(&mut self, local_step: u64, wstats: &[f32]) {
        debug_assert_eq!(wstats.len(), 8);
        let mut row = [0.0f64; 8];
        for (d, s) in row.iter_mut().zip(wstats) {
            *d = *s as f64;
        }
        self.rows.push((local_step, row));
    }

    /// Column-wise summary over training: returns the median across steps
    /// of each quantile column (the steady-state Fig. 5(d) profile).
    pub fn summary(&self) -> Option<[f64; 8]> {
        if self.rows.is_empty() {
            return None;
        }
        let mut out = [0.0f64; 8];
        for (c, slot) in out.iter_mut().enumerate() {
            let col: Vec<f64> = self.rows.iter().map(|(_, r)| r[c]).collect();
            *slot = quantile(&col, 0.5);
        }
        Some(out)
    }
}

/// Full record of one training run.
#[derive(Debug, Clone, Default)]
pub struct RunRecord {
    pub label: String,
    pub series: Vec<SeriesPoint>,
    /// Party A's wstats rows: cos(Z_A^(i,j), Z_A^(i)) — Fig. 5(d).
    pub cosine: CosineRecorder,
    /// Party B's wstats rows: cos(∇Z_A^(i,j), ∇Z_A^(i)).
    pub cosine_b: CosineRecorder,
    /// Total communication rounds executed.
    pub comm_rounds: u64,
    /// Exact updates / local updates applied (Party B counts).
    pub exact_updates: u64,
    pub local_updates: u64,
    /// Bytes sent per party (wire size: what occupied the link).
    pub bytes_a_to_b: u64,
    pub bytes_b_to_a: u64,
    /// What the same traffic would have occupied uncompressed (equal to
    /// the wire bytes when no codec is negotiated — DESIGN.md §5).
    pub raw_bytes_a_to_b: u64,
    pub raw_bytes_b_to_a: u64,
    /// Link busy time (sender side, both directions summed).
    pub comm_busy: Duration,
    /// Total wall time of the run.
    pub wall: Duration,
    /// Time Party B spent inside PJRT execute calls.
    pub compute_busy: Duration,
}

impl RunRecord {
    /// First communication round whose AUC reaches `target`; None if the
    /// run never got there. Interpolation-free (paper counts rounds).
    pub fn rounds_to_auc(&self, target: f64) -> Option<u64> {
        self.series
            .iter()
            .find(|p| p.auc >= target)
            .map(|p| p.comm_round)
    }

    /// First wall-clock time the AUC reaches `target` (Fig. 6 metric).
    pub fn time_to_auc(&self, target: f64) -> Option<f64> {
        self.series.iter().find(|p| p.auc >= target).map(|p| p.wall_s)
    }

    pub fn best_auc(&self) -> f64 {
        self.series.iter().map(|p| p.auc).fold(0.0, f64::max)
    }

    /// Fraction of wall time the (A→B + B→A) links were busy — the §1
    /// ">90% of training time is communication" measurement for Vanilla.
    pub fn comm_fraction(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.comm_busy.as_secs_f64() / self.wall.as_secs_f64()
    }

    /// Achieved wire compression ratio across both directions (1.0 when
    /// uncompressed or idle).
    pub fn compression_ratio(&self) -> f64 {
        let wire = self.bytes_a_to_b + self.bytes_b_to_a;
        if wire == 0 {
            return 1.0;
        }
        (self.raw_bytes_a_to_b + self.raw_bytes_b_to_a) as f64
            / wire as f64
    }

    /// Wire bytes per communication round, both directions summed.
    pub fn wire_bytes_per_round(&self) -> f64 {
        if self.comm_rounds == 0 {
            return 0.0;
        }
        (self.bytes_a_to_b + self.bytes_b_to_a) as f64
            / self.comm_rounds as f64
    }

    /// JSON dump for results/ artifacts.
    pub fn to_json(&self) -> Json {
        let series = Json::Arr(
            self.series
                .iter()
                .map(|p| {
                    obj(vec![
                        ("round", num(p.comm_round as f64)),
                        ("wall_s", num(p.wall_s)),
                        ("auc", num(p.auc)),
                        ("loss", num(p.loss)),
                        ("updates", num(p.updates as f64)),
                    ])
                })
                .collect(),
        );
        let cosine = Json::Arr(
            self.cosine
                .rows
                .iter()
                .map(|(step, row)| {
                    obj(vec![
                        ("step", num(*step as f64)),
                        ("q", arr_f64(row)),
                    ])
                })
                .collect(),
        );
        obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("comm_rounds", num(self.comm_rounds as f64)),
            ("exact_updates", num(self.exact_updates as f64)),
            ("local_updates", num(self.local_updates as f64)),
            ("bytes_a_to_b", num(self.bytes_a_to_b as f64)),
            ("bytes_b_to_a", num(self.bytes_b_to_a as f64)),
            ("raw_bytes_a_to_b", num(self.raw_bytes_a_to_b as f64)),
            ("raw_bytes_b_to_a", num(self.raw_bytes_b_to_a as f64)),
            ("compression_ratio", num(self.compression_ratio())),
            ("comm_busy_s", num(self.comm_busy.as_secs_f64())),
            ("compute_busy_s", num(self.compute_busy.as_secs_f64())),
            ("wall_s", num(self.wall.as_secs_f64())),
            ("comm_fraction", num(self.comm_fraction())),
            ("series", series),
            ("cosine", cosine),
            ("cosine_b", Json::Num(self.cosine_b.rows.len() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record_with_aucs(aucs: &[f64]) -> RunRecord {
        let mut r = RunRecord { label: "t".into(), ..Default::default() };
        for (i, &auc) in aucs.iter().enumerate() {
            r.series.push(SeriesPoint {
                comm_round: (i as u64 + 1) * 10,
                wall_s: i as f64 * 2.0,
                auc,
                loss: 0.5,
                updates: i as u64,
            });
        }
        r
    }

    #[test]
    fn rounds_to_auc_finds_first_crossing() {
        let r = record_with_aucs(&[0.5, 0.6, 0.7, 0.72]);
        assert_eq!(r.rounds_to_auc(0.65), Some(30));
        assert_eq!(r.time_to_auc(0.65), Some(4.0));
        assert_eq!(r.rounds_to_auc(0.9), None);
        assert_eq!(r.best_auc(), 0.72);
    }

    #[test]
    fn comm_fraction_sane() {
        let mut r = record_with_aucs(&[0.5]);
        r.wall = Duration::from_secs(10);
        r.comm_busy = Duration::from_secs(9);
        assert!((r.comm_fraction() - 0.9).abs() < 1e-12);
        let empty = RunRecord::default();
        assert_eq!(empty.comm_fraction(), 0.0);
    }

    #[test]
    fn cosine_recorder_summary_is_columnwise_median()
    {
        let mut c = CosineRecorder::default();
        c.push(1, &[0.0, 0.1, 0.2, 0.5, 0.8, 0.9, 0.5, 1.0]);
        c.push(2, &[0.2, 0.3, 0.4, 0.7, 1.0, 1.0, 0.7, 0.8]);
        c.push(3, &[0.4, 0.5, 0.6, 0.9, 1.2, 1.1, 0.9, 0.6]);
        let s = c.summary().unwrap();
        assert!((s[0] - 0.2).abs() < 1e-6);
        assert!((s[3] - 0.7).abs() < 1e-6);
        assert!((s[7] - 0.8).abs() < 1e-6);
        assert!(CosineRecorder::default().summary().is_none());
    }

    #[test]
    fn compression_ratio_and_bytes_per_round() {
        let mut r = RunRecord::default();
        assert_eq!(r.compression_ratio(), 1.0);
        assert_eq!(r.wire_bytes_per_round(), 0.0);
        r.comm_rounds = 10;
        r.bytes_a_to_b = 400;
        r.bytes_b_to_a = 600;
        r.raw_bytes_a_to_b = 1600;
        r.raw_bytes_b_to_a = 2400;
        assert!((r.compression_ratio() - 4.0).abs() < 1e-12);
        assert!((r.wire_bytes_per_round() - 100.0).abs() < 1e-12);
    }

    #[test]
    fn json_dump_parses_back() {
        let mut r = record_with_aucs(&[0.5, 0.7]);
        r.cosine.push(4, &[0.0; 8]);
        r.comm_rounds = 20;
        let j = r.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(parsed.expect("comm_rounds").unwrap().as_usize().unwrap(),
                   20);
        assert_eq!(parsed.expect("series").unwrap().as_arr().unwrap().len(),
                   2);
    }
}
