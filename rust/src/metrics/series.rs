//! Convergence series + staleness telemetry recorded during a run.
//!
//! `RunRecord` is the unit every experiment harness consumes: the AUC
//! series indexed by communication round *and* wall-clock time (the two
//! x-axes of Figures 5 and 6), the loss curve, the comm/compute time
//! split (the §1 ">90% communication" claim), and the cosine-similarity
//! quantiles per local step (Figure 5(d)).

use std::time::Duration;

use crate::session::supervisor::SessionEvent;
use crate::session::PartyId;
use crate::util::json::{arr_f64, num, obj, Json};
use crate::util::stats::quantile;

/// One evaluation point on the convergence curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeriesPoint {
    /// Communication rounds completed when evaluated (paper Fig. 5 x-axis).
    pub comm_round: u64,
    /// Wall-clock seconds since training start (paper Fig. 6 x-axis).
    pub wall_s: f64,
    /// Validation AUC.
    pub auc: f64,
    /// Smoothed training loss.
    pub loss: f64,
    /// Total updates (exact + local) applied so far at Party B.
    pub updates: u64,
}

/// Cosine-similarity telemetry: per-local-step quantile rows (Fig. 5(d)).
#[derive(Debug, Clone, Default)]
pub struct CosineRecorder {
    /// (local_step, wstats[8]) rows as emitted by the artifacts:
    /// [min, q10, q25, q50, q75, q90, mean, frac_kept].
    pub rows: Vec<(u64, [f64; 8])>,
}

impl CosineRecorder {
    pub fn push(&mut self, local_step: u64, wstats: &[f32]) {
        debug_assert_eq!(wstats.len(), 8);
        let mut row = [0.0f64; 8];
        for (d, s) in row.iter_mut().zip(wstats) {
            *d = *s as f64;
        }
        self.rows.push((local_step, row));
    }

    /// Column-wise summary over training: returns the median across steps
    /// of each quantile column (the steady-state Fig. 5(d) profile).
    pub fn summary(&self) -> Option<[f64; 8]> {
        if self.rows.is_empty() {
            return None;
        }
        let mut out = [0.0f64; 8];
        for (c, slot) in out.iter_mut().enumerate() {
            let col: Vec<f64> = self.rows.iter().map(|(_, r)| r[c]).collect();
            *slot = quantile(&col, 0.5);
        }
        Some(out)
    }
}

/// Sender-side traffic of one directed mesh link (`src` → `dst`).
/// `bytes` is what occupied the wire; `raw_bytes` is what the same
/// messages would have cost uncompressed (equal when no codec is
/// negotiated — DESIGN.md §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkRecord {
    pub src: PartyId,
    pub dst: PartyId,
    pub messages: u64,
    pub bytes: u64,
    pub raw_bytes: u64,
    /// Chaos faults injected on this link (0 outside fault campaigns —
    /// never part of byte-parity comparisons).
    pub faults: u64,
}

impl LinkRecord {
    /// This link's achieved compression ratio (1.0 when idle or
    /// uncompressed — never NaN/inf, even for a zero-round run).
    pub fn compression_ratio(&self) -> f64 {
        if self.bytes == 0 {
            return 1.0;
        }
        self.raw_bytes as f64 / self.bytes as f64
    }
}

/// Full record of one training run.
#[derive(Debug, Clone, Default)]
pub struct RunRecord {
    pub label: String,
    pub series: Vec<SeriesPoint>,
    /// Feature party 1's wstats rows: cos(Z^(i,j), Z^(i)) — Fig. 5(d).
    /// (K-party runs record the first feature party as representative;
    /// all parties run the same weighting kernel.)
    pub cosine: CosineRecorder,
    /// The label party's wstats rows: cos(∇Z^(i,j), ∇Z^(i)).
    pub cosine_b: CosineRecorder,
    /// Total communication rounds executed.
    pub comm_rounds: u64,
    /// Exact updates / local updates applied (label-party counts).
    pub exact_updates: u64,
    pub local_updates: u64,
    /// Local updates per feature party, in party-id order (index 0 is
    /// party 1). Two-party runs have exactly one entry.
    pub feature_local_updates: Vec<u64>,
    /// Self-supervised (denoising) updates per feature party on
    /// unaligned rows — zero wire traffic by construction, and all
    /// zeros unless the run carries a limited-overlap data plane
    /// (DESIGN.md §12).
    pub feature_ssl_updates: Vec<u64>,
    /// Per-link traffic rows, one per directed link of the session mesh
    /// (two-party runs have exactly [1→0, 0→1]). Aggregate totals are
    /// derived by [`Self::wire_bytes_total`] / [`Self::raw_bytes_total`]
    /// and preserved in the JSON output.
    pub links: Vec<LinkRecord>,
    /// Link busy time (sender side, all links summed).
    pub comm_busy: Duration,
    /// Total wall time of the run.
    pub wall: Duration,
    /// Time the label party spent inside PJRT execute calls.
    pub compute_busy: Duration,
    /// Lifecycle events observed by the label party's supervisor
    /// (peer losses/rejoins, straggler timeouts, checkpoints —
    /// DESIGN.md §8). Empty for an undisturbed run, so existing
    /// artifacts simply gain an empty array.
    pub events: Vec<SessionEvent>,
}

impl RunRecord {
    /// First communication round whose AUC reaches `target`; None if the
    /// run never got there. Interpolation-free (paper counts rounds).
    pub fn rounds_to_auc(&self, target: f64) -> Option<u64> {
        self.series
            .iter()
            .find(|p| p.auc >= target)
            .map(|p| p.comm_round)
    }

    /// First wall-clock time the AUC reaches `target` (Fig. 6 metric).
    pub fn time_to_auc(&self, target: f64) -> Option<f64> {
        self.series.iter().find(|p| p.auc >= target).map(|p| p.wall_s)
    }

    pub fn best_auc(&self) -> f64 {
        self.series.iter().map(|p| p.auc).fold(0.0, f64::max)
    }

    /// Fraction of wall time the (A→B + B→A) links were busy — the §1
    /// ">90% of training time is communication" measurement for Vanilla.
    pub fn comm_fraction(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.comm_busy.as_secs_f64() / self.wall.as_secs_f64()
    }

    /// Total wire bytes across every link of the mesh.
    pub fn wire_bytes_total(&self) -> u64 {
        self.links.iter().map(|l| l.bytes).sum()
    }

    /// Total uncompressed-equivalent bytes across every link.
    pub fn raw_bytes_total(&self) -> u64 {
        self.links.iter().map(|l| l.raw_bytes).sum()
    }

    /// Bytes sent by feature parties toward the label party (the
    /// historic "A→B" direction, summed over all feature links).
    pub fn bytes_to_label(&self) -> u64 {
        self.links
            .iter()
            .filter(|l| l.dst == PartyId(0))
            .map(|l| l.bytes)
            .sum()
    }

    /// Bytes sent by the label party toward feature parties (the
    /// historic "B→A" direction, summed over all feature links).
    pub fn bytes_from_label(&self) -> u64 {
        self.links
            .iter()
            .filter(|l| l.src == PartyId(0))
            .map(|l| l.bytes)
            .sum()
    }

    /// Achieved wire compression ratio across every link (1.0 when
    /// uncompressed or idle — guarded against zero wire bytes so a
    /// zero-round run never emits NaN/inf into the JSON artifact).
    pub fn compression_ratio(&self) -> f64 {
        let wire = self.wire_bytes_total();
        if wire == 0 {
            return 1.0;
        }
        self.raw_bytes_total() as f64 / wire as f64
    }

    /// Wire bytes per communication round, all links summed.
    pub fn wire_bytes_per_round(&self) -> f64 {
        if self.comm_rounds == 0 {
            return 0.0;
        }
        self.wire_bytes_total() as f64 / self.comm_rounds as f64
    }

    /// JSON dump for results/ artifacts.
    pub fn to_json(&self) -> Json {
        let series = Json::Arr(
            self.series
                .iter()
                .map(|p| {
                    obj(vec![
                        ("round", num(p.comm_round as f64)),
                        ("wall_s", num(p.wall_s)),
                        ("auc", num(p.auc)),
                        ("loss", num(p.loss)),
                        ("updates", num(p.updates as f64)),
                    ])
                })
                .collect(),
        );
        let cosine = Json::Arr(
            self.cosine
                .rows
                .iter()
                .map(|(step, row)| {
                    obj(vec![
                        ("step", num(*step as f64)),
                        ("q", arr_f64(row)),
                    ])
                })
                .collect(),
        );
        let links = Json::Arr(
            self.links
                .iter()
                .map(|l| {
                    obj(vec![
                        ("src", num(l.src.0 as f64)),
                        ("dst", num(l.dst.0 as f64)),
                        ("messages", num(l.messages as f64)),
                        ("bytes", num(l.bytes as f64)),
                        ("raw_bytes", num(l.raw_bytes as f64)),
                        ("faults", num(l.faults as f64)),
                        ("compression_ratio",
                         num(l.compression_ratio())),
                    ])
                })
                .collect(),
        );
        let events = Json::Arr(
            self.events
                .iter()
                .map(|e| {
                    let mut fields = vec![
                        ("kind", Json::Str(e.kind().to_string())),
                        ("round", num(e.round() as f64)),
                    ];
                    if let Some(p) = e.party() {
                        fields.push(("party", num(p.0 as f64)));
                    }
                    if let SessionEvent::CheckpointWritten { path, .. } = e
                    {
                        fields.push(("path", Json::Str(path.clone())));
                    }
                    if let SessionEvent::CheckpointFailed { error, .. } = e
                    {
                        fields.push(("error", Json::Str(error.clone())));
                    }
                    obj(fields)
                })
                .collect(),
        );
        obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("comm_rounds", num(self.comm_rounds as f64)),
            ("exact_updates", num(self.exact_updates as f64)),
            ("local_updates", num(self.local_updates as f64)),
            ("feature_local_updates",
             Json::Arr(self.feature_local_updates
                 .iter()
                 .map(|&u| num(u as f64))
                 .collect())),
            ("feature_ssl_updates",
             Json::Arr(self.feature_ssl_updates
                 .iter()
                 .map(|&u| num(u as f64))
                 .collect())),
            ("links", links),
            ("bytes_total", num(self.wire_bytes_total() as f64)),
            ("raw_bytes_total", num(self.raw_bytes_total() as f64)),
            ("bytes_to_label", num(self.bytes_to_label() as f64)),
            ("bytes_from_label", num(self.bytes_from_label() as f64)),
            ("compression_ratio", num(self.compression_ratio())),
            ("comm_busy_s", num(self.comm_busy.as_secs_f64())),
            ("compute_busy_s", num(self.compute_busy.as_secs_f64())),
            ("wall_s", num(self.wall.as_secs_f64())),
            ("comm_fraction", num(self.comm_fraction())),
            ("series", series),
            ("cosine", cosine),
            ("cosine_b", Json::Num(self.cosine_b.rows.len() as f64)),
            ("events", events),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record_with_aucs(aucs: &[f64]) -> RunRecord {
        let mut r = RunRecord { label: "t".into(), ..Default::default() };
        for (i, &auc) in aucs.iter().enumerate() {
            r.series.push(SeriesPoint {
                comm_round: (i as u64 + 1) * 10,
                wall_s: i as f64 * 2.0,
                auc,
                loss: 0.5,
                updates: i as u64,
            });
        }
        r
    }

    #[test]
    fn rounds_to_auc_finds_first_crossing() {
        let r = record_with_aucs(&[0.5, 0.6, 0.7, 0.72]);
        assert_eq!(r.rounds_to_auc(0.65), Some(30));
        assert_eq!(r.time_to_auc(0.65), Some(4.0));
        assert_eq!(r.rounds_to_auc(0.9), None);
        assert_eq!(r.best_auc(), 0.72);
    }

    #[test]
    fn comm_fraction_sane() {
        let mut r = record_with_aucs(&[0.5]);
        r.wall = Duration::from_secs(10);
        r.comm_busy = Duration::from_secs(9);
        assert!((r.comm_fraction() - 0.9).abs() < 1e-12);
        let empty = RunRecord::default();
        assert_eq!(empty.comm_fraction(), 0.0);
    }

    #[test]
    fn cosine_recorder_summary_is_columnwise_median()
    {
        let mut c = CosineRecorder::default();
        c.push(1, &[0.0, 0.1, 0.2, 0.5, 0.8, 0.9, 0.5, 1.0]);
        c.push(2, &[0.2, 0.3, 0.4, 0.7, 1.0, 1.0, 0.7, 0.8]);
        c.push(3, &[0.4, 0.5, 0.6, 0.9, 1.2, 1.1, 0.9, 0.6]);
        let s = c.summary().unwrap();
        assert!((s[0] - 0.2).abs() < 1e-6);
        assert!((s[3] - 0.7).abs() < 1e-6);
        assert!((s[7] - 0.8).abs() < 1e-6);
        assert!(CosineRecorder::default().summary().is_none());
    }

    fn link(src: u16, dst: u16, bytes: u64, raw: u64) -> LinkRecord {
        LinkRecord {
            src: PartyId(src),
            dst: PartyId(dst),
            messages: 1,
            bytes,
            raw_bytes: raw,
            faults: 0,
        }
    }

    #[test]
    fn compression_ratio_and_bytes_per_round() {
        let mut r = RunRecord::default();
        assert_eq!(r.compression_ratio(), 1.0);
        assert_eq!(r.wire_bytes_per_round(), 0.0);
        r.comm_rounds = 10;
        r.links = vec![link(1, 0, 400, 1600), link(0, 1, 600, 2400)];
        assert!((r.compression_ratio() - 4.0).abs() < 1e-12);
        assert!((r.wire_bytes_per_round() - 100.0).abs() < 1e-12);
        assert_eq!(r.wire_bytes_total(), 1000);
        assert_eq!(r.raw_bytes_total(), 4000);
        assert_eq!(r.bytes_to_label(), 400);
        assert_eq!(r.bytes_from_label(), 600);
    }

    #[test]
    fn compression_ratio_is_finite_with_zero_wire_bytes() {
        // Regression: a zero-round run (no traffic at all) must report
        // ratio 1.0 — not NaN or inf — in every accessor and in the
        // JSON artifact.
        let r = RunRecord::default();
        assert_eq!(r.compression_ratio(), 1.0);
        // Even with raw bytes recorded but zero wire bytes (cannot
        // happen on a real link, but the guard must hold), the ratio
        // stays finite.
        let mut r = RunRecord::default();
        r.links = vec![link(1, 0, 0, 0)];
        assert_eq!(r.compression_ratio(), 1.0);
        assert_eq!(r.links[0].compression_ratio(), 1.0);
        let j = r.to_json().to_string();
        assert!(!j.contains("NaN") && !j.contains("inf"),
                "non-finite ratio leaked into JSON: {j}");
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(parsed
                       .expect("compression_ratio").unwrap()
                       .as_f64().unwrap(),
                   1.0);
    }

    #[test]
    fn multi_party_links_aggregate_across_the_mesh() {
        let mut r = RunRecord::default();
        r.comm_rounds = 5;
        r.links = vec![
            link(1, 0, 100, 100),
            link(2, 0, 150, 300),
            link(0, 1, 200, 200),
            link(0, 2, 50, 100),
        ];
        assert_eq!(r.wire_bytes_total(), 500);
        assert_eq!(r.bytes_to_label(), 250);
        assert_eq!(r.bytes_from_label(), 250);
        assert!((r.compression_ratio() - 700.0 / 500.0).abs() < 1e-12);
        assert!((r.links[1].compression_ratio() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn lifecycle_events_land_in_the_json_artifact() {
        let mut r = record_with_aucs(&[0.5]);
        r.events = vec![
            SessionEvent::PeerLost { party: PartyId(2), round: 9 },
            SessionEvent::StragglerTimeout {
                party: PartyId(1),
                round: 10,
            },
            SessionEvent::PeerRejoined { party: PartyId(2), round: 14 },
            SessionEvent::CheckpointWritten {
                round: 20,
                path: "ckpts/ckpt_round_00000020.celuckpt".into(),
            },
            SessionEvent::CheckpointFailed {
                round: 25,
                error: "No space left on device".into(),
            },
        ];
        let j = r.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        let events = parsed.expect("events").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 5);
        assert_eq!(
            events[0].expect("kind").unwrap().as_str().unwrap(),
            "peer_lost"
        );
        assert_eq!(
            events[0].expect("party").unwrap().as_usize().unwrap(),
            2
        );
        assert_eq!(
            events[3].expect("kind").unwrap().as_str().unwrap(),
            "checkpoint_written"
        );
        assert!(events[3].expect("path").unwrap().as_str().unwrap()
            .contains("celuckpt"));
        assert_eq!(
            events[4].expect("kind").unwrap().as_str().unwrap(),
            "checkpoint_failed"
        );
        assert!(events[4].expect("error").unwrap().as_str().unwrap()
            .contains("space"));
        assert_eq!(
            events[4].expect("round").unwrap().as_usize().unwrap(),
            25
        );
        // An undisturbed run serializes an empty array, not a missing
        // key.
        let r = RunRecord::default();
        let parsed =
            crate::util::json::Json::parse(&r.to_json().to_string())
                .unwrap();
        assert_eq!(
            parsed.expect("events").unwrap().as_arr().unwrap().len(),
            0
        );
    }

    #[test]
    fn hostile_event_strings_survive_the_json_roundtrip() {
        // Regression pin: the only free-form strings in a RunRecord
        // are event payloads (checkpoint paths, OS error messages).
        // A path with quotes/backslashes (Windows, shell-quoted dirs)
        // or an error with newlines and control bytes must come back
        // from parse() verbatim and never produce unparseable JSON.
        let path = r#"ckpts\"weird dir"\ckpt_00000020.celuckpt"#;
        let error = "write failed:\n\t\"disk\" gone \u{1} \u{7f}";
        let mut r = record_with_aucs(&[0.5]);
        r.events = vec![
            SessionEvent::CheckpointWritten { round: 20,
                                              path: path.into() },
            SessionEvent::CheckpointFailed { round: 21,
                                             error: error.into() },
        ];
        let j = r.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&j)
            .expect("hostile event strings broke the artifact");
        let events = parsed.expect("events").unwrap().as_arr().unwrap();
        assert_eq!(
            events[0].expect("path").unwrap().as_str().unwrap(),
            path
        );
        assert_eq!(
            events[1].expect("error").unwrap().as_str().unwrap(),
            error
        );
        // Raw control bytes must not appear unescaped in the dump.
        assert!(!j.contains('\u{1}') && !j.contains('\n'),
                "unescaped control byte in JSON: {j}");
    }

    #[test]
    fn json_dump_parses_back() {
        let mut r = record_with_aucs(&[0.5, 0.7]);
        r.cosine.push(4, &[0.0; 8]);
        r.comm_rounds = 20;
        r.links = vec![link(1, 0, 400, 400), link(0, 1, 600, 600)];
        let j = r.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(parsed.expect("comm_rounds").unwrap().as_usize().unwrap(),
                   20);
        assert_eq!(parsed.expect("series").unwrap().as_arr().unwrap().len(),
                   2);
        // Per-link rows land in the artifact with aggregate totals
        // preserved alongside.
        let links = parsed.expect("links").unwrap().as_arr().unwrap();
        assert_eq!(links.len(), 2);
        assert_eq!(links[0].expect("src").unwrap().as_usize().unwrap(), 1);
        assert_eq!(links[0].expect("bytes").unwrap().as_usize().unwrap(),
                   400);
        assert_eq!(parsed.expect("bytes_total").unwrap()
                       .as_usize().unwrap(),
                   1000);
        assert_eq!(parsed.expect("raw_bytes_total").unwrap()
                       .as_usize().unwrap(),
                   1000);
    }
}
