//! Exporter side of the observability plane (DESIGN.md §10).
//!
//! Three observers, one trait: the facade's [`Registry`] is the single
//! source of truth, and everything downstream — the Prometheus-text
//! scrape endpoint, the control-lane push stream, the terminal
//! `RunRecord` artifact — is a [`MetricsExporter`] that reads the same
//! cells. None of them is allowed to perturb the session: exporting is
//! read-only, failures are the exporter's own problem, and a run with
//! no exporter installed does not change by a byte.

pub mod prometheus;
pub mod push;

use std::sync::Mutex;

use crate::metrics::facade::{LinkRow, Registry};
use crate::metrics::series::LinkRecord;
use crate::session::supervisor::SessionEvent;
use crate::session::LABEL_PARTY;

pub use prometheus::PrometheusExporter;
pub use push::PushExporter;

/// One observer of the metrics registry. `export` takes one
/// observation; what that means is the implementation's business — a
/// scrape renders text, a push stream writes a frame, a terminal
/// observer folds the registry into an artifact.
pub trait MetricsExporter: Send + Sync {
    fn name(&self) -> &'static str;
    fn export(&self, registry: &Registry) -> anyhow::Result<()>;
}

/// The registry's link rows in `RunRecord` order: feature→label rows
/// by source id, then label→feature rows by destination id — exactly
/// the order the trainer has always assembled (feature reports in
/// party order, then the label party's own lanes), so the JSON
/// artifact stays byte-compatible. Rows of a non-star topology (none
/// exist today) would follow in registry order.
pub fn run_record_links(registry: &Registry) -> Vec<LinkRecord> {
    let rows = registry.link_rows();
    let record = |r: &LinkRow| LinkRecord {
        src: r.src,
        dst: r.dst,
        messages: r.stats.messages,
        bytes: r.stats.bytes,
        raw_bytes: r.stats.raw_bytes,
        faults: r.faults,
    };
    let mut out = Vec::with_capacity(rows.len());
    let mut to_label: Vec<&LinkRow> =
        rows.iter().filter(|r| r.dst == LABEL_PARTY).collect();
    to_label.sort_by_key(|r| r.src);
    out.extend(to_label.into_iter().map(record));
    let mut from_label: Vec<&LinkRow> =
        rows.iter().filter(|r| r.src == LABEL_PARTY).collect();
    from_label.sort_by_key(|r| r.dst);
    out.extend(from_label.into_iter().map(record));
    out.extend(rows.iter()
        .filter(|r| r.src != LABEL_PARTY && r.dst != LABEL_PARTY)
        .map(record));
    out
}

/// The terminal observer: snapshots the registry once, at end of run,
/// into the rows and event log `RunRecord` is assembled from. The
/// trainer installs one of these where it used to hand-thread
/// `LinkStats` vectors and event `Vec`s out of every party report.
#[derive(Default)]
pub struct RunRecordObserver {
    links: Mutex<Vec<LinkRecord>>,
    events: Mutex<Vec<SessionEvent>>,
}

impl RunRecordObserver {
    pub fn new() -> Self {
        RunRecordObserver::default()
    }

    /// The observed link rows (empty until `export` runs).
    pub fn links(&self) -> Vec<LinkRecord> {
        self.links.lock().unwrap().clone()
    }

    /// The observed event log (empty until `export` runs).
    pub fn events(&self) -> Vec<SessionEvent> {
        self.events.lock().unwrap().clone()
    }
}

impl MetricsExporter for RunRecordObserver {
    fn name(&self) -> &'static str {
        "run-record"
    }

    fn export(&self, registry: &Registry) -> anyhow::Result<()> {
        *self.links.lock().unwrap() = run_record_links(registry);
        *self.events.lock().unwrap() = registry.events();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::facade::{EventSink, LinkHandles};
    use crate::session::PartyId;
    use std::time::Duration;

    fn charged(wire: u64, raw: u64, msgs: u64) -> LinkHandles {
        let h = LinkHandles::detached();
        h.charge(crate::transport::LinkStats {
            messages: msgs,
            bytes: wire,
            raw_bytes: raw,
            busy: Duration::ZERO,
        });
        h
    }

    #[test]
    fn run_record_links_order_matches_the_trainer() {
        // Registry iteration is (src, dst)-sorted: (0,1) (0,3) (1,0)
        // (3,0). RunRecord wants feature rows first (1→0, 3→0), then
        // label rows (0→1, 0→3).
        let reg = Registry::new();
        for (s, d, wire) in [(0u16, 1u16, 10u64), (3, 0, 40), (1, 0, 20),
                             (0, 3, 30)] {
            reg.bind_link(PartyId(s), PartyId(d),
                          &charged(wire, wire, 1));
        }
        let rows = run_record_links(&reg);
        let order: Vec<(u16, u16)> =
            rows.iter().map(|r| (r.src.0, r.dst.0)).collect();
        assert_eq!(order, vec![(1, 0), (3, 0), (0, 1), (0, 3)]);
        assert_eq!(rows[0].bytes, 20);
        assert_eq!(rows[1].bytes, 40);
    }

    #[test]
    fn run_record_observer_snapshots_links_and_events() {
        let reg = Registry::new();
        reg.bind_link(PartyId(1), PartyId(0), &charged(100, 200, 2));
        reg.emit(&SessionEvent::StragglerTimeout { party: PartyId(1),
                                                   round: 3 });
        let obs = RunRecordObserver::new();
        assert!(obs.links().is_empty() && obs.events().is_empty());
        obs.export(&reg).unwrap();
        let links = obs.links();
        assert_eq!(links.len(), 1);
        assert_eq!((links[0].messages, links[0].bytes,
                    links[0].raw_bytes),
                   (2, 100, 200));
        assert_eq!(obs.events().len(), 1);
        assert_eq!(obs.name(), "run-record");
    }
}
