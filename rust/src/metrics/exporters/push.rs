//! Control-lane push exporter (DESIGN.md §10).
//!
//! The streaming counterpart to the scrape endpoint: every `export`
//! writes one length-prefixed `Metrics` frame (protocol tag 14)
//! carrying the registry's *cumulative* per-link totals. Cumulative,
//! not deltas, so the stream is loss-tolerant — a watcher that joins
//! late or drops frames converges on the next one, and the last frame
//! of a run equals the final `RunRecord` link rows exactly (the K=3
//! parity gate in `scrape_k3`).

use std::io::{Read, Write};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::ensure;

use crate::metrics::facade::Registry;
use crate::protocol::{LinkMetricsRow, Message, MAX_METRICS_ROWS};
use crate::session::PartyId;
use crate::transport::LinkStats;

use super::MetricsExporter;

/// Upper bound on an incoming metrics frame. The largest legitimate
/// body is `1 + 8 + 1 + 1 + 36 * MAX_METRICS_ROWS` ≈ 4.6 KiB; anything
/// past this cap is a hostile or corrupt length word, rejected before
/// allocation.
pub const MAX_METRICS_FRAME: usize = 16 * 1024;

/// Build one cumulative `Metrics` frame from the registry's current
/// link rows. More rows than the wire format can carry (impossible in
/// a star mesh, which tops out at `2 * (MAX_PARTIES - 1)` directed
/// links) are truncated loudly rather than silently.
pub fn metrics_frame(registry: &Registry) -> Message {
    let rows = registry.link_rows();
    if rows.len() > MAX_METRICS_ROWS {
        log::warn!("metrics frame truncated: {} links > {} row cap",
                   rows.len(), MAX_METRICS_ROWS);
    }
    Message::Metrics {
        round: registry.round(),
        links: rows.iter()
            .take(MAX_METRICS_ROWS)
            .map(|r| LinkMetricsRow {
                src: r.src,
                dst: r.dst,
                messages: r.stats.messages,
                wire_bytes: r.stats.bytes,
                raw_bytes: r.stats.raw_bytes,
                busy_nanos: r.stats.busy.as_nanos() as u64,
            })
            .collect(),
    }
}

/// The rows of a received `Metrics` frame as classic per-link stats —
/// what the `watch` CLI renders and the parity gates compare against
/// `RunRecord`.
pub fn frame_rows(msg: &Message) -> Vec<(PartyId, PartyId, LinkStats)> {
    match msg {
        Message::Metrics { links, .. } => links.iter()
            .map(|r| (r.src, r.dst, LinkStats {
                messages: r.messages,
                bytes: r.wire_bytes,
                raw_bytes: r.raw_bytes,
                busy: Duration::from_nanos(r.busy_nanos),
            }))
            .collect(),
        _ => Vec::new(),
    }
}

/// Watch-client side: read one length-prefixed frame and insist it is
/// a `Metrics` frame. Hostile input hits the same tag-14 validation
/// the transports use; a hostile length word is rejected before any
/// allocation.
pub fn read_metrics_frame(r: &mut impl Read) -> anyhow::Result<Message> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)
        .map_err(|e| anyhow::anyhow!("reading metrics frame length: {e}"))?;
    let len = u32::from_le_bytes(len) as usize;
    ensure!(len > 0 && len <= MAX_METRICS_FRAME,
            "metrics frame length {len} outside (0, {MAX_METRICS_FRAME}]");
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)
        .map_err(|e| anyhow::anyhow!("reading metrics frame body: {e}"))?;
    let msg = Message::decode(&body)?;
    ensure!(matches!(msg, Message::Metrics { .. }),
            "expected a Metrics frame on the watch lane, got tag {}",
            msg.tag());
    Ok(msg)
}

/// Push exporter over any byte sink (a watch connection, a file, a
/// test buffer). Each `export` writes one complete frame; the scratch
/// buffer lives with the writer so steady-state exports do not
/// allocate.
pub struct PushExporter<W: Write + Send> {
    inner: Mutex<(W, Vec<u8>)>,
}

impl<W: Write + Send> PushExporter<W> {
    pub fn new(writer: W) -> Self {
        PushExporter { inner: Mutex::new((writer, Vec::new())) }
    }

    /// Hand the writer back (tests inspect the buffered bytes).
    pub fn into_inner(self) -> W {
        self.inner.into_inner().unwrap().0
    }
}

impl<W: Write + Send> MetricsExporter for PushExporter<W> {
    fn name(&self) -> &'static str {
        "push"
    }

    fn export(&self, registry: &Registry) -> anyhow::Result<()> {
        let msg = metrics_frame(registry);
        let mut guard = self.inner.lock().unwrap();
        let (writer, scratch) = &mut *guard;
        msg.encode_into(scratch);
        writer.write_all(scratch)
            .map_err(|e| anyhow::anyhow!("pushing metrics frame: {e}"))?;
        writer.flush()
            .map_err(|e| anyhow::anyhow!("flushing metrics frame: {e}"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::facade::LinkHandles;
    use std::io::Cursor;

    fn star_registry() -> std::sync::Arc<Registry> {
        let reg = Registry::new();
        for (s, d) in [(1u16, 0u16), (2, 0), (0, 1), (0, 2)] {
            let h = LinkHandles::detached();
            h.charge(LinkStats {
                messages: u64::from(s + d),
                bytes: 100 * u64::from(s + 10 * d),
                raw_bytes: 250 * u64::from(s + 10 * d),
                busy: Duration::from_micros(u64::from(s) + 7),
            });
            reg.bind_link(PartyId(s), PartyId(d), &h);
        }
        reg.set_round(11);
        reg
    }

    #[test]
    fn pushed_stream_replays_to_registry_rows() {
        let reg = star_registry();
        let push = PushExporter::new(Vec::new());
        push.export(&reg).unwrap();
        // The registry keeps moving between ticks; frames stay
        // cumulative.
        reg.link(PartyId(1), PartyId(0)).unwrap()
            .record(40, 80, Duration::from_micros(2));
        reg.set_round(12);
        push.export(&reg).unwrap();

        let bytes = push.into_inner();
        let mut r = Cursor::new(bytes);
        let first = read_metrics_frame(&mut r).unwrap();
        let last = read_metrics_frame(&mut r).unwrap();
        assert_eq!(first.round(), 11);
        assert_eq!(last.round(), 12);
        assert_eq!(r.position() as usize, r.get_ref().len(),
                   "stream fully consumed");

        // A watcher that dropped every frame but the last still ends
        // at the registry's (and therefore RunRecord's) exact totals.
        let final_rows: Vec<_> = reg.link_rows().iter()
            .map(|r| (r.src, r.dst, r.stats))
            .collect();
        assert_eq!(frame_rows(&last), final_rows);
        assert_ne!(frame_rows(&first), final_rows);
    }

    #[test]
    fn reader_rejects_hostile_lengths_and_foreign_tags() {
        // Zero length.
        let err = read_metrics_frame(&mut Cursor::new(
            0u32.to_le_bytes().to_vec())).unwrap_err();
        assert!(err.to_string().contains("outside"), "{err}");
        // Absurd length word is refused before allocation.
        let err = read_metrics_frame(&mut Cursor::new(
            u32::MAX.to_le_bytes().to_vec())).unwrap_err();
        assert!(err.to_string().contains("outside"), "{err}");
        // A valid frame of the wrong kind.
        let mut buf = Vec::new();
        Message::Shutdown.encode_into(&mut buf);
        let err = read_metrics_frame(&mut Cursor::new(buf)).unwrap_err();
        assert!(err.to_string().contains("expected a Metrics frame"),
                "{err}");
        // Truncated body.
        let mut buf = Vec::new();
        metrics_frame(&star_registry()).encode_into(&mut buf);
        buf.truncate(buf.len() - 1);
        assert!(read_metrics_frame(&mut Cursor::new(buf)).is_err());
    }

    #[test]
    fn frame_rows_is_empty_for_non_metrics_messages() {
        assert!(frame_rows(&Message::Shutdown).is_empty());
    }
}
