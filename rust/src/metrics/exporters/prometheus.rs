//! Prometheus text exposition (DESIGN.md §10).
//!
//! [`render`] turns a registry snapshot into the text format any
//! Prometheus-compatible scraper understands — hand-rolled, because the
//! format is lines of `name{labels} value` and the vendoring discipline
//! says a format this small does not buy a client library.
//!
//! Family order is fixed so the exposition is deterministic and
//! golden-testable: session round, dropped-event counter, the six
//! per-link families (rows ordered by `(src, dst)`), then every
//! generically registered counter / gauge / histogram in name order.
//! Histograms export as `summary`-style `_count` / `_sum` lines plus a
//! `_max` convenience line.
//!
//! A multi-session server scrapes many registries off one port:
//! [`render_labeled`] injects a `session="<id>"` label into every
//! sample so the concatenated exposition keeps each mesh's series
//! distinct. [`render`] is the single-session exposition, byte-for-byte
//! unchanged.

use std::fmt::Write as _;
use std::sync::Mutex;

use crate::metrics::facade::Registry;

use super::MetricsExporter;

/// `name{labels}` → `name` (the TYPE line wants the family, not the
/// labeled instance).
fn base_name(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

/// Display-format floats: `1.5` stays `1.5`, `2.0` prints as `2` —
/// both are valid exposition values.
fn num(v: f64) -> String {
    format!("{v}")
}

/// Inject `session="<id>"` as the first label of a (possibly already
/// labeled) sample name. `None` is the identity — the single-session
/// exposition carries no session label.
fn labeled(name: &str, session: Option<&str>) -> String {
    match session {
        None => name.to_string(),
        Some(s) => match name.split_once('{') {
            Some((base, rest)) => {
                format!("{base}{{session=\"{s}\",{rest}")
            }
            None => format!("{name}{{session=\"{s}\"}}"),
        },
    }
}

/// Move a summary suffix inside the label block: `foo{a="b"}` +
/// `_count` renders as `foo_count{a="b"}`; unlabeled names just get
/// the suffix appended.
fn suffixed(name: &str, suffix: &str) -> String {
    match name.split_once('{') {
        Some((base, rest)) => format!("{base}{suffix}{{{rest}"),
        None => format!("{name}{suffix}"),
    }
}

/// Render the registry as Prometheus text exposition, version 0.0.4.
pub fn render(registry: &Registry) -> String {
    render_labeled(registry, None)
}

/// [`render`] with an optional `session="<id>"` label injected into
/// every sample line — how a [`SessionServer`](crate::session::server)
/// exposes many concurrent meshes on one `/metrics` endpoint without
/// their series colliding. The `# HELP`/`# TYPE` header lines name the
/// unlabeled family, as the exposition format requires.
pub fn render_labeled(registry: &Registry, session: Option<&str>)
                      -> String {
    let snap = registry.snapshot();
    let mut out = String::with_capacity(1024);

    out.push_str("# HELP celu_session_round Current communication \
                  round of the session.\n");
    out.push_str("# TYPE celu_session_round gauge\n");
    let _ = writeln!(out, "{} {}",
                     labeled("celu_session_round", session), snap.round);

    out.push_str("# HELP celu_events_dropped_total Lifecycle events \
                  dropped past the retention cap.\n");
    out.push_str("# TYPE celu_events_dropped_total counter\n");
    let _ = writeln!(out, "{} {}",
                     labeled("celu_events_dropped_total", session),
                     registry.dropped_events());

    if !snap.links.is_empty() {
        struct Family {
            name: &'static str,
            kind: &'static str,
            help: &'static str,
        }
        let families = [
            Family { name: "celu_link_messages_total", kind: "counter",
                     help: "Messages sent on a directed link." },
            Family { name: "celu_link_wire_bytes_total", kind: "counter",
                     help: "Bytes that crossed the wire on a directed \
                            link." },
            Family { name: "celu_link_raw_bytes_total", kind: "counter",
                     help: "Uncompressed cost of the same messages." },
            Family { name: "celu_link_busy_seconds_total",
                     kind: "counter",
                     help: "Sender-side link occupancy." },
            Family { name: "celu_link_faults_injected_total",
                     kind: "counter",
                     help: "Chaos faults injected on a directed link \
                            (0 outside fault campaigns)." },
            Family { name: "celu_link_compression_ratio", kind: "gauge",
                     help: "Achieved raw/wire compression ratio." },
        ];
        for f in &families {
            let _ = writeln!(out, "# HELP {} {}", f.name, f.help);
            let _ = writeln!(out, "# TYPE {} {}", f.name, f.kind);
            for row in &snap.links {
                let labels = match session {
                    Some(s) => format!(
                        "{{session=\"{s}\",src=\"{}\",dst=\"{}\"}}",
                        row.src.0, row.dst.0),
                    None => format!("{{src=\"{}\",dst=\"{}\"}}",
                                    row.src.0, row.dst.0),
                };
                let value = match f.name {
                    "celu_link_messages_total" =>
                        row.stats.messages.to_string(),
                    "celu_link_wire_bytes_total" =>
                        row.stats.bytes.to_string(),
                    "celu_link_raw_bytes_total" =>
                        row.stats.raw_bytes.to_string(),
                    "celu_link_busy_seconds_total" =>
                        num(row.stats.busy.as_secs_f64()),
                    "celu_link_faults_injected_total" =>
                        row.faults.to_string(),
                    _ => {
                        if row.stats.bytes == 0 {
                            continue;
                        }
                        num(row.stats.raw_bytes as f64
                            / row.stats.bytes as f64)
                    }
                };
                let _ = writeln!(out, "{}{} {}", f.name, labels, value);
            }
        }
    }

    let mut last_base = "";
    for (name, value) in &snap.counters {
        if base_name(name) != last_base {
            last_base = base_name(name);
            let _ = writeln!(out, "# TYPE {last_base} counter");
        }
        let _ = writeln!(out, "{} {value}", labeled(name, session));
    }
    let mut last_base = "";
    for (name, value) in &snap.gauges {
        if base_name(name) != last_base {
            last_base = base_name(name);
            let _ = writeln!(out, "# TYPE {last_base} gauge");
        }
        let _ = writeln!(out, "{} {}", labeled(name, session),
                         num(*value));
    }
    for (name, h) in &snap.histograms {
        let _ = writeln!(out, "# TYPE {} summary", base_name(name));
        let name = labeled(name, session);
        let _ = writeln!(out, "{} {}", suffixed(&name, "_count"),
                         h.count);
        let _ = writeln!(out, "{} {}", suffixed(&name, "_sum"),
                         num(h.sum));
        let _ = writeln!(out, "{} {}", suffixed(&name, "_max"),
                         num(h.max));
    }
    out
}

/// Scrape-side exporter: re-renders on every `export` and keeps the
/// latest exposition for whoever serves it (the label party's session
/// listener answers `GET /metrics` straight from [`render`]; this
/// wrapper exists for exporter-agnostic call sites and tests).
#[derive(Default)]
pub struct PrometheusExporter {
    latest: Mutex<String>,
}

impl PrometheusExporter {
    pub fn new() -> Self {
        PrometheusExporter::default()
    }

    /// The most recently exported exposition (empty before the first
    /// `export`).
    pub fn latest(&self) -> String {
        self.latest.lock().unwrap().clone()
    }
}

impl MetricsExporter for PrometheusExporter {
    fn name(&self) -> &'static str {
        "prometheus"
    }

    fn export(&self, registry: &Registry) -> anyhow::Result<()> {
        *self.latest.lock().unwrap() = render(registry);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::facade::{EventSink, LinkHandles};
    use crate::session::supervisor::SessionEvent;
    use crate::session::PartyId;
    use crate::transport::LinkStats;
    use std::time::Duration;

    #[test]
    fn golden_exposition_is_byte_identical() {
        let reg = Registry::new();
        reg.set_round(42);
        let a = LinkHandles::detached();
        a.charge(LinkStats { messages: 3, bytes: 1000, raw_bytes: 2000,
                             busy: Duration::from_millis(1500) });
        reg.bind_link(PartyId(1), PartyId(0), &a);
        let b = LinkHandles::detached();
        b.charge(LinkStats { messages: 1, bytes: 10, raw_bytes: 10,
                             busy: Duration::ZERO });
        reg.bind_link(PartyId(0), PartyId(2), &b);
        b.faults_injected.add(4);
        reg.emit(&SessionEvent::PeerLost { party: PartyId(1), round: 7 });
        reg.gauge("celu_workset_fill").set(0.5);
        let h = reg.histogram("celu_round_seconds");
        h.observe(0.25);
        h.observe(0.75);

        let expected = "\
# HELP celu_session_round Current communication round of the session.
# TYPE celu_session_round gauge
celu_session_round 42
# HELP celu_events_dropped_total Lifecycle events dropped past the retention cap.
# TYPE celu_events_dropped_total counter
celu_events_dropped_total 0
# HELP celu_link_messages_total Messages sent on a directed link.
# TYPE celu_link_messages_total counter
celu_link_messages_total{src=\"0\",dst=\"2\"} 1
celu_link_messages_total{src=\"1\",dst=\"0\"} 3
# HELP celu_link_wire_bytes_total Bytes that crossed the wire on a directed link.
# TYPE celu_link_wire_bytes_total counter
celu_link_wire_bytes_total{src=\"0\",dst=\"2\"} 10
celu_link_wire_bytes_total{src=\"1\",dst=\"0\"} 1000
# HELP celu_link_raw_bytes_total Uncompressed cost of the same messages.
# TYPE celu_link_raw_bytes_total counter
celu_link_raw_bytes_total{src=\"0\",dst=\"2\"} 10
celu_link_raw_bytes_total{src=\"1\",dst=\"0\"} 2000
# HELP celu_link_busy_seconds_total Sender-side link occupancy.
# TYPE celu_link_busy_seconds_total counter
celu_link_busy_seconds_total{src=\"0\",dst=\"2\"} 0
celu_link_busy_seconds_total{src=\"1\",dst=\"0\"} 1.5
# HELP celu_link_faults_injected_total Chaos faults injected on a directed link (0 outside fault campaigns).
# TYPE celu_link_faults_injected_total counter
celu_link_faults_injected_total{src=\"0\",dst=\"2\"} 4
celu_link_faults_injected_total{src=\"1\",dst=\"0\"} 0
# HELP celu_link_compression_ratio Achieved raw/wire compression ratio.
# TYPE celu_link_compression_ratio gauge
celu_link_compression_ratio{src=\"0\",dst=\"2\"} 1
celu_link_compression_ratio{src=\"1\",dst=\"0\"} 2
# TYPE celu_events_total counter
celu_events_total{kind=\"peer_lost\"} 1
# TYPE celu_workset_fill gauge
celu_workset_fill 0.5
# TYPE celu_round_seconds summary
celu_round_seconds_count 2
celu_round_seconds_sum 1
celu_round_seconds_max 0.75
";
        assert_eq!(render(&reg), expected);
    }

    #[test]
    fn labeled_exposition_injects_session_into_every_sample() {
        let reg = Registry::new();
        reg.set_round(3);
        let a = LinkHandles::detached();
        a.charge(LinkStats { messages: 2, bytes: 100, raw_bytes: 100,
                             busy: Duration::ZERO });
        reg.bind_link(PartyId(1), PartyId(0), &a);
        reg.emit(&SessionEvent::PeerLost { party: PartyId(1), round: 1 });
        reg.gauge("celu_workset_fill").set(0.25);
        reg.histogram("celu_round_seconds").observe(0.5);

        let text = render_labeled(&reg, Some("1a2b3c4d"));
        // Every sample line carries the session label; HELP/TYPE
        // headers name the unlabeled family.
        assert!(text.contains(
            "celu_session_round{session=\"1a2b3c4d\"} 3\n"));
        assert!(text.contains("# TYPE celu_session_round gauge\n"));
        assert!(text.contains(
            "celu_events_dropped_total{session=\"1a2b3c4d\"} 0\n"));
        assert!(text.contains(
            "celu_link_messages_total{session=\"1a2b3c4d\",src=\"1\",\
             dst=\"0\"} 2\n"));
        // An already-labeled name gets the session label prepended.
        assert!(text.contains(
            "celu_events_total{session=\"1a2b3c4d\",\
             kind=\"peer_lost\"} 1\n"));
        assert!(text.contains(
            "celu_workset_fill{session=\"1a2b3c4d\"} 0.25\n"));
        // Summary suffixes land on the base name, not after the label
        // block.
        assert!(text.contains(
            "celu_round_seconds_count{session=\"1a2b3c4d\"} 1\n"));
        assert!(text.contains(
            "celu_round_seconds_max{session=\"1a2b3c4d\"} 0.5\n"));
        // And the unlabeled render is the labeled render with no label.
        assert_eq!(render(&reg), render_labeled(&reg, None));
    }

    #[test]
    fn empty_registry_renders_headers_only() {
        let reg = Registry::new();
        let text = render(&reg);
        assert!(text.contains("celu_session_round 0\n"));
        assert!(text.contains("celu_events_dropped_total 0\n"));
        assert!(!text.contains("celu_link_"),
                "no link rows bound, no link families");
    }

    #[test]
    fn zero_wire_bytes_skips_the_ratio_line() {
        let reg = Registry::new();
        reg.bind_link(PartyId(1), PartyId(0), &LinkHandles::detached());
        let text = render(&reg);
        assert!(text.contains(
            "celu_link_messages_total{src=\"1\",dst=\"0\"} 0\n"));
        assert!(!text.contains("celu_link_compression_ratio{"),
                "a 0-byte link has no meaningful ratio");
    }

    #[test]
    fn exporter_wrapper_caches_the_latest_exposition() {
        let reg = Registry::new();
        let exp = PrometheusExporter::new();
        assert_eq!(exp.name(), "prometheus");
        assert!(exp.latest().is_empty());
        reg.set_round(9);
        exp.export(&reg).unwrap();
        assert!(exp.latest().contains("celu_session_round 9\n"));
    }
}
