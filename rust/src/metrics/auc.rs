//! Exact ROC-AUC via the rank-sum (Mann–Whitney U) identity, with proper
//! tie handling (mid-ranks) — the validation metric of every experiment
//! in the paper (§5).

/// Exact AUC of `scores` against binary `labels` (1.0 = positive).
/// O(n log n); ties receive mid-ranks. Returns 0.5 for degenerate inputs
/// (all-positive / all-negative), matching the "no information" reading.
pub fn auc_exact(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len(), "scores/labels length mismatch");
    let n = scores.len();
    if n == 0 {
        return 0.5;
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    let mut rank_sum_pos = 0.0f64;
    let mut pos = 0.0f64;
    let mut i = 0usize;
    while i < n {
        // Tie group [i, j)
        let mut j = i + 1;
        while j < n && scores[order[j]] == scores[order[i]] {
            j += 1;
        }
        let mid_rank = (i + 1 + j) as f64 / 2.0; // average of ranks i+1..=j
        for &k in &order[i..j] {
            if labels[k] == 1.0 {
                rank_sum_pos += mid_rank;
                pos += 1.0;
            }
        }
        i = j;
    }
    let neg = n as f64 - pos;
    if pos == 0.0 || neg == 0.0 {
        return 0.5;
    }
    (rank_sum_pos - pos * (pos + 1.0) / 2.0) / (pos * neg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Pcg;
    use crate::prop_assert;

    /// O(n²) pair-counting oracle.
    fn auc_naive(scores: &[f32], labels: &[f32]) -> f64 {
        let (mut wins, mut ties, mut pairs) = (0.0f64, 0.0f64, 0.0f64);
        for i in 0..scores.len() {
            if labels[i] != 1.0 {
                continue;
            }
            for j in 0..scores.len() {
                if labels[j] != 0.0 {
                    continue;
                }
                pairs += 1.0;
                if scores[i] > scores[j] {
                    wins += 1.0;
                } else if scores[i] == scores[j] {
                    ties += 1.0;
                }
            }
        }
        if pairs == 0.0 {
            0.5
        } else {
            (wins + ties / 2.0) / pairs
        }
    }

    #[test]
    fn perfect_and_inverted_rankings() {
        let scores = [0.1, 0.2, 0.8, 0.9];
        let labels = [0.0, 0.0, 1.0, 1.0];
        assert_eq!(auc_exact(&scores, &labels), 1.0);
        let inv = [0.0f32, 0.0, 1.0, 1.0];
        let lab_inv = [1.0f32, 1.0, 0.0, 0.0];
        assert_eq!(auc_exact(&inv, &lab_inv), 0.0);
    }

    #[test]
    fn ties_get_half_credit() {
        let scores = [0.5f32, 0.5, 0.5, 0.5];
        let labels = [1.0f32, 0.0, 1.0, 0.0];
        assert!((auc_exact(&scores, &labels) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(auc_exact(&[], &[]), 0.5);
        assert_eq!(auc_exact(&[0.3, 0.7], &[1.0, 1.0]), 0.5);
        assert_eq!(auc_exact(&[0.3, 0.7], &[0.0, 0.0]), 0.5);
    }

    #[test]
    fn prop_matches_naive_oracle() {
        prop::check("auc == naive pair count", |rng| {
            let n = 2 + rng.gen_range(60) as usize;
            let mut scores = Vec::with_capacity(n);
            let mut labels = Vec::with_capacity(n);
            for _ in 0..n {
                // Quantized scores to force tie groups.
                scores.push((rng.gen_range(10) as f32) / 10.0);
                labels.push(rng.gen_range(2) as f32);
            }
            let fast = auc_exact(&scores, &labels);
            let slow = auc_naive(&scores, &labels);
            prop_assert!((fast - slow).abs() < 1e-9,
                         "fast={fast} slow={slow}");
            Ok(())
        });
    }

    #[test]
    fn prop_invariant_under_monotone_transform() {
        prop::check("auc invariant under exp", |rng| {
            let n = 5 + rng.gen_range(40) as usize;
            let mut rng2 = Pcg::seeded(rng.next_u64());
            let scores: Vec<f32> =
                (0..n).map(|_| rng2.next_normal()).collect();
            let labels: Vec<f32> =
                (0..n).map(|_| rng2.gen_range(2) as f32).collect();
            let transformed: Vec<f32> =
                scores.iter().map(|x| x.exp()).collect();
            let a = auc_exact(&scores, &labels);
            let b = auc_exact(&transformed, &labels);
            prop_assert!((a - b).abs() < 1e-9, "a={a} b={b}");
            Ok(())
        });
    }
}
