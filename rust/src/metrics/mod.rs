//! Metrics: exact AUC, convergence series, staleness telemetry, and the
//! communication accounting behind the paper's headline numbers.

pub mod auc;
pub mod series;

pub use auc::auc_exact;
pub use series::{CosineRecorder, LinkRecord, RunRecord, SeriesPoint};
