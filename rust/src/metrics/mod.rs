//! Metrics: exact AUC, convergence series, staleness telemetry, and the
//! communication accounting behind the paper's headline numbers — plus
//! the live observability plane (lock-free recorder facade and its
//! scrape/push/terminal exporters, DESIGN.md §10).

pub mod auc;
pub mod exporters;
pub mod facade;
pub mod series;

pub use auc::auc_exact;
pub use exporters::{MetricsExporter, PrometheusExporter, PushExporter,
                    RunRecordObserver};
pub use facade::{ChannelSink, Counter, CounterSink, EventSink, FanSink,
                 Gauge, Histogram, LinkHandles, NullSink, Registry};
pub use series::{CosineRecorder, LinkRecord, RunRecord, SeriesPoint};
