//! Chaos campaign subsystem (DESIGN.md §13): randomized fault-plan
//! sweeps with automatic seed shrinking.
//!
//! The chaos matrix's hand-written fault tests each pin one
//! composition; a *campaign* explores the space instead. A root seed
//! expands — scenario by scenario, index by index — into many
//! [`CasePlan`]s ([`plan`]): multi-fault overlaps, frame reordering,
//! fault × codec cross-products, kills during rejoin handshakes, and
//! faults inside one multiplexed `SessionServer` session while its
//! neighbor trains on. The executor ([`exec`]) runs every plan
//! through a real session and judges it against three oracles —
//! no-panic/no-hang under a wall-clock budget, round-count parity,
//! and byte-identity of every surviving clean link against an
//! undisturbed reference. Failures shrink ([`shrink`]) to 1-minimal
//! reproducers, printed as ready-to-paste `FaultPlan` builder chains,
//! and the whole sweep serializes to a byte-reproducible JSON report
//! ([`report`]).
//!
//! Entry points: `celu-vfl campaign` on the command line,
//! [`run_campaign`] from code.

pub mod exec;
pub mod plan;
pub mod report;
pub mod shrink;

pub use exec::{run_campaign, run_case, CampaignOpts, CaseOutcome};
pub use plan::{CasePlan, FaultOp, LinkFault, Scenario};
pub use report::{CampaignReport, CaseReport};
pub use shrink::{shrink as shrink_case, ShrinkResult};
