//! Delta-debugging shrinker for failing chaos cases (DESIGN.md §13).
//!
//! Given a failing [`CasePlan`] and a predicate that re-runs a
//! candidate and reports whether it *still fails*, [`shrink`] greedily
//! minimizes the plan along four axes, repeated to a fixpoint:
//!
//! 1. drop whole faulted links,
//! 2. drop individual fault ops on the surviving links,
//! 3. tighten the round count (halve, then decrement),
//! 4. pull each op's anchor index toward zero (zero, halve,
//!    decrement).
//!
//! Every accepted candidate strictly decreases a well-founded measure
//! (fault count, op count, rounds, or an index sum), so the loop
//! terminates; the result is 1-minimal with respect to these moves —
//! no single move makes it smaller and still failing. The predicate
//! is the only arbiter of "fails": the executor's oracles for a real
//! reproduction, or any synthetic property under test.
//!
//! The shrinker never consults the RNG: it mutates the expanded plan
//! structurally, so the minimized case remains exactly reproducible
//! and prints as a ready-to-paste builder chain
//! ([`LinkFault::builder_chain`](crate::campaign::plan::LinkFault::builder_chain)).

use crate::campaign::plan::CasePlan;

/// The minimized plan plus how many predicate evaluations (i.e. case
/// re-runs) the search spent.
#[derive(Debug, Clone)]
pub struct ShrinkResult {
    pub plan: CasePlan,
    pub evals: u64,
}

/// Candidate values for pulling `v` toward zero, deduplicated and
/// strictly decreasing from `v`.
fn toward_zero(v: u64) -> Vec<u64> {
    let mut cands = vec![0, v / 2, v.saturating_sub(1)];
    cands.dedup(); // already nondecreasing for v >= 1
    cands.retain(|nv| *nv < v);
    cands
}

/// Minimize `seed_plan` under `still_fails` (true ⇒ the candidate
/// reproduces the failure). The caller guarantees the seed plan
/// itself fails; if it does not, the plan comes back unchanged.
pub fn shrink<F>(seed_plan: &CasePlan, mut still_fails: F) -> ShrinkResult
where
    F: FnMut(&CasePlan) -> bool,
{
    let mut best = seed_plan.clone();
    let mut evals: u64 = 0;
    loop {
        let mut reduced = false;

        // Pass 1: drop whole faulted links.
        let mut i = 0;
        while i < best.faults.len() {
            let mut cand = best.clone();
            cand.faults.remove(i);
            evals += 1;
            if still_fails(&cand) {
                best = cand;
                reduced = true;
            } else {
                i += 1;
            }
        }

        // Pass 2: drop individual ops (a single-op link is pass 1's
        // business — dropping its op and dropping the link coincide).
        let mut fi = 0;
        while fi < best.faults.len() {
            let mut oi = 0;
            while oi < best.faults[fi].ops.len() {
                if best.faults[fi].ops.len() == 1 {
                    break;
                }
                let mut cand = best.clone();
                cand.faults[fi].ops.remove(oi);
                evals += 1;
                if still_fails(&cand) {
                    best = cand;
                    reduced = true;
                } else {
                    oi += 1;
                }
            }
            fi += 1;
        }

        // Pass 3: tighten rounds — halve while that still fails, then
        // walk down by one.
        loop {
            let mut stepped = false;
            for cand_rounds in toward_zero(best.rounds) {
                if cand_rounds == 0 {
                    continue; // a zero-round session runs nothing
                }
                let mut cand = best.clone();
                cand.rounds = cand_rounds;
                evals += 1;
                if still_fails(&cand) {
                    best = cand;
                    reduced = true;
                    stepped = true;
                    break;
                }
            }
            if !stepped {
                break;
            }
        }

        // Pass 4: pull each op's anchor toward zero.
        for fi in 0..best.faults.len() {
            for oi in 0..best.faults[fi].ops.len() {
                loop {
                    let v = best.faults[fi].ops[oi].index();
                    let mut stepped = false;
                    for nv in toward_zero(v) {
                        let mut cand = best.clone();
                        cand.faults[fi].ops[oi] =
                            cand.faults[fi].ops[oi].with_index(nv);
                        evals += 1;
                        if still_fails(&cand) {
                            best = cand;
                            reduced = true;
                            stepped = true;
                            break;
                        }
                    }
                    if !stepped {
                        break;
                    }
                }
            }
        }

        if !reduced {
            break;
        }
    }
    ShrinkResult { plan: best, evals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::plan::{FaultOp, LinkFault, Scenario};

    fn fat_plan() -> CasePlan {
        CasePlan {
            scenario: Scenario::Single,
            root_seed: 42,
            index: 0,
            case_seed: 0xFEED,
            parties: 4,
            rounds: 9,
            codecs: Vec::new(),
            faults: vec![
                LinkFault {
                    party: 1,
                    ops: vec![
                        FaultOp::DelayMs(3, 100),
                        FaultOp::DropFrame(7),
                        FaultOp::DuplicateFrame(2),
                    ],
                },
                LinkFault {
                    party: 2,
                    ops: vec![FaultOp::CorruptFrame(5)],
                },
                LinkFault {
                    party: 3,
                    ops: vec![FaultOp::ReorderFrames(4)],
                },
            ],
        }
    }

    /// The "failure" only needs a DropFrame at index >= 2 and at
    /// least 3 rounds — everything else in the fat plan is noise the
    /// shrinker must strip.
    fn synthetic_failure(p: &CasePlan) -> bool {
        p.rounds >= 3
            && p.faults.iter().any(|f| {
                f.ops.iter().any(
                    |op| matches!(op, FaultOp::DropFrame(n) if *n >= 2))
            })
    }

    #[test]
    fn shrinks_a_fat_plan_to_the_minimal_reproducer() {
        let fat = fat_plan();
        assert!(synthetic_failure(&fat), "seed plan must fail");
        let r = shrink(&fat, synthetic_failure);
        assert_eq!(r.plan.rounds, 3, "rounds not tightened: {:?}",
                   r.plan);
        assert_eq!(
            r.plan.faults,
            vec![LinkFault { party: 1,
                             ops: vec![FaultOp::DropFrame(2)] }],
            "noise ops survived the shrink"
        );
        assert!(synthetic_failure(&r.plan),
                "shrinker returned a passing plan");
        assert!(r.evals > 0);
        // Everything the RNG expanded but the failure never needed is
        // untouched metadata.
        assert_eq!((r.plan.parties, r.plan.case_seed), (4, 0xFEED));
    }

    #[test]
    fn shrinking_is_idempotent_on_a_minimal_plan() {
        let minimal = shrink(&fat_plan(), synthetic_failure).plan;
        let again = shrink(&minimal, synthetic_failure);
        assert_eq!(again.plan, minimal);
    }

    #[test]
    fn a_non_failing_plan_comes_back_unchanged() {
        let fat = fat_plan();
        let r = shrink(&fat, |_| false);
        assert_eq!(r.plan, fat);
    }

    #[test]
    fn toward_zero_is_strictly_decreasing_and_deduplicated() {
        assert_eq!(toward_zero(0), Vec::<u64>::new());
        assert_eq!(toward_zero(1), vec![0]);
        assert_eq!(toward_zero(2), vec![0, 1]);
        assert_eq!(toward_zero(9), vec![0, 4, 8]);
    }
}
