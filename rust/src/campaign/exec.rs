//! Campaign executor and oracles (DESIGN.md §13).
//!
//! Runs one [`CasePlan`] through a *real* session — in-proc mesh,
//! loopback TCP with re-admission, or two sessions multiplexed behind
//! a [`SessionServer`] — and judges the run against three oracles:
//!
//! - **no-panic / no-hang**: the case runs on a worker thread under a
//!   wall-clock budget; a missing verdict is a hang, a dead channel a
//!   panic. (A timed-out worker is leaked, not reaped — the budget
//!   exists to produce a verdict, not to clean up a wedged session.)
//! - **round parity**: the label completes every planned round; an
//!   unkilled feature party completes all of them, a killed one
//!   completes exactly its kill round.
//! - **clean-link byte identity**: every *unfaulted* link's
//!   `(bytes, raw_bytes, messages)` triple — both directions — is
//!   byte-identical to an undisturbed in-proc reference run of the
//!   same config. Faulted links are exempt (their counts legitimately
//!   differ); the chaos may not perturb anyone else by a single byte.
//!
//! The feature loop here is deliberately *jump-tolerant*: it advances
//! to `r + 1` whenever a derivative for round `r >= round` arrives and
//! ignores older replays. That is exactly the discipline a real party
//! needs under partitions, drops, duplicates and reorders — the label
//! stales a missing round and moves on, and the party must follow the
//! label's clock, not its own.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::campaign::plan::{CasePlan, ExecMode, LinkFault, Scenario};
use crate::campaign::report::{CampaignReport, CaseReport};
use crate::campaign::shrink;
use crate::compress::{self, CodecKind};
use crate::config::RunConfig;
use crate::protocol::{outbound_stats, Lane, Message};
use crate::session::bootstrap::{
    inproc_mesh, rejoin_dial, Readmission, SessionDialer,
    SessionListener,
};
use crate::session::server::{SessionHandle, SessionServer};
use crate::session::supervisor::{session_epoch, LaneSet};
use crate::session::{Link, PartyId};
use crate::tensor::Tensor;
use crate::transport::fault::FaultTransport;
use crate::transport::{LinkStats, Transport};
use crate::util::rng::Pcg;

/// Synthetic activation geometry — small on purpose: the oracles
/// check protocol behavior, not arithmetic throughput.
const BATCH: usize = 4;
const Z_DIM: usize = 3;

const DIAL_TIMEOUT: Duration = Duration::from_secs(10);

/// One campaign invocation.
#[derive(Debug, Clone)]
pub struct CampaignOpts {
    pub scenarios: Vec<Scenario>,
    /// Cases per scenario (indices `0..seeds`).
    pub seeds: u64,
    pub root_seed: u64,
    /// Per-case wall-clock budget (the no-hang oracle).
    pub budget: Duration,
    /// Delta-debug failing cases down to minimal reproducers.
    pub shrink: bool,
}

impl Default for CampaignOpts {
    fn default() -> Self {
        CampaignOpts {
            scenarios: Scenario::all().to_vec(),
            seeds: 4,
            root_seed: 42,
            budget: Duration::from_secs(20),
            shrink: false,
        }
    }
}

/// The oracles' verdict on one case. Everything here is deterministic
/// for a given plan — no wall-clock readings, and `rejoined` is a
/// bool rather than a count because an aborted rejoin attempt may or
/// may not transiently seat.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CaseOutcome {
    pub passed: bool,
    pub failures: Vec<String>,
    /// Rounds the label drove to completion.
    pub rounds_completed: u64,
    pub rejoined: bool,
    /// Total injections across every `FaultTransport` in the case.
    pub faults_injected: u64,
    /// Directed clean links that passed byte-identity.
    pub clean_links_checked: usize,
}

impl CaseOutcome {
    fn infra(msg: String) -> CaseOutcome {
        CaseOutcome {
            passed: false,
            failures: vec![msg],
            rounds_completed: 0,
            rejoined: false,
            faults_injected: 0,
            clean_links_checked: 0,
        }
    }
}

// ---- shared protocol loops -------------------------------------------------

fn triple(s: LinkStats) -> (u64, u64, u64) {
    (s.bytes, s.raw_bytes, s.messages)
}

/// Deterministic per-`(seed, party, round)` activation payload.
fn synth(seed: u64, party: u16, round: u64) -> Tensor {
    let mut rng = Pcg::new(seed ^ ((party as u64) << 16), round + 1);
    let vals: Vec<f32> = (0..BATCH * Z_DIM)
        .map(|_| (rng.next_u32() % 1000) as f32 / 1000.0)
        .collect();
    Tensor::f32(vec![BATCH, Z_DIM], vals)
}

/// Drive rounds `from..to` of the feature side of a link, tolerating
/// every injectable disturbance. Returns the round the party reached:
/// `to` on a clean finish (after draining to the label's shutdown),
/// earlier iff the link died under it (a planned kill or teardown).
fn feature_segment(transport: &Arc<dyn Transport>, codec: CodecKind,
                   seed: u64, party: u16, from: u64, to: u64)
                   -> anyhow::Result<u64> {
    let mut round = from;
    while round < to {
        let za = synth(seed, party, round);
        let (msg, _) =
            outbound_stats(codec, Lane::Activation, round, za)?;
        if transport.send(msg).is_err() {
            return Ok(round); // the link died under us (e.g. a kill)
        }
        loop {
            let m = match transport.recv() {
                Ok(m) => m,
                Err(_) => return Ok(round),
            };
            match m.into_plain() {
                Ok(Message::Derivative { round: r, .. }) => {
                    if r >= round {
                        // The label may have staled past us (our frame
                        // was dropped/partitioned): follow its clock.
                        round = r + 1;
                        break;
                    }
                    // Older replay (duplicate / reorder tail): ignore.
                }
                Ok(Message::Shutdown) => return Ok(round),
                Ok(_) => {}
                Err(_) => {} // garbled inbound frame: skip it
            }
        }
    }
    loop {
        match transport.recv() {
            Ok(Message::Shutdown) | Err(_) => return Ok(to),
            Ok(_) => {}
        }
    }
}

/// What one feature party reports back to the oracles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct PartySide {
    completed: u64,
    injected: u64,
    /// `(bytes, raw_bytes, messages)` sent on the *inner* (unfaulted)
    /// endpoint — dropped or held frames are never charged, so this
    /// is what actually crossed the link.
    triple: (u64, u64, u64),
}

/// Wrap a link in its fault plan, if any, keeping a handle for the
/// injection counter.
fn wrap(link: &Link, fault: Option<&LinkFault>, case_seed: u64)
        -> (Arc<dyn Transport>, Option<Arc<FaultTransport>>) {
    match fault {
        Some(lf) => {
            let ft = Arc::new(FaultTransport::new(
                link.transport.clone(), lf.to_fault_plan(case_seed)));
            (ft.clone() as Arc<dyn Transport>, Some(ft))
        }
        None => (link.transport.clone(), None),
    }
}

/// The label side's rollup, measured just before shutdown (the same
/// instant in every run, so triples compare exactly).
struct LabelRollup {
    /// Per-lane `(party, (bytes, raw_bytes, messages))` sent
    /// label→party.
    lanes: Vec<(u16, (u64, u64, u64))>,
    rejoins: u64,
    rounds: u64,
}

/// Drive the label side over `LaneSet` for `rounds` rounds.
fn label_loop(cfg: &RunConfig, links: &[Link],
              readmission: Option<Readmission>, rounds: u64)
              -> anyhow::Result<LabelRollup> {
    let mut lanes = LaneSet::new(cfg, links, readmission);
    lanes.handshake(cfg, None)?;
    for round in 0..rounds {
        let inputs = lanes.collect(round)?;
        let zs: Vec<Tensor> =
            inputs.iter().filter_map(|i| i.tensor().cloned()).collect();
        let dza = Tensor::sum_f32(&zs)?;
        lanes.fan_out(round, &dza)?;
    }
    let rollup = LabelRollup {
        lanes: lanes
            .link_stats()
            .into_iter()
            .map(|(p, s)| (p.0, triple(s)))
            .collect(),
        rejoins: lanes.total_rejoins(),
        rounds,
    };
    lanes.shutdown();
    Ok(rollup)
}

// ---- mesh mode -------------------------------------------------------------

/// Run one in-proc session; `plan = None` is the undisturbed
/// reference.
fn mesh_run(cfg: &RunConfig, rounds: u64, plan: Option<&CasePlan>)
            -> anyhow::Result<(BTreeMap<u16, PartySide>, LabelRollup)> {
    let (label_bs, feature_bs) = inproc_mesh(cfg);
    let mut workers = Vec::new();
    for bs in feature_bs {
        let cfg = cfg.clone();
        let party = bs.id().0;
        let fault = plan.and_then(|p| p.fault_for(party).cloned());
        let case_seed = plan.map(|p| p.case_seed).unwrap_or(0);
        workers.push(std::thread::spawn(
            move || -> anyhow::Result<(u16, PartySide)> {
                let links = bs.establish(&cfg)?;
                let link = &links[0];
                let codec = compress::negotiate(cfg.codec_for(party),
                                                link.peer_codecs);
                let (t, ft) = wrap(link, fault.as_ref(), case_seed);
                let completed = feature_segment(
                    &t, codec, cfg.seed, party, 0, rounds)?;
                Ok((party, PartySide {
                    completed,
                    injected: ft.map(|f| f.injected()).unwrap_or(0),
                    triple: triple(link.transport.stats()),
                }))
            },
        ));
    }
    let links = label_bs.establish(cfg)?;
    let rollup = label_loop(cfg, &links, None, rounds)?;
    let mut parties = BTreeMap::new();
    for w in workers {
        let (p, side) = w
            .join()
            .map_err(|_| anyhow::anyhow!("feature worker panicked"))??;
        parties.insert(p, side);
    }
    Ok((parties, rollup))
}

/// Both-direction byte-identity for every clean link of one session.
fn clean_link_parity(plan: &CasePlan, faulted_session: bool,
                     parties: &BTreeMap<u16, PartySide>,
                     label: &LabelRollup,
                     ref_parties: &BTreeMap<u16, PartySide>,
                     ref_label: &LabelRollup, tag: &str,
                     failures: &mut Vec<String>) -> usize {
    let mut checked = 0;
    for (p, side) in parties {
        if faulted_session && plan.fault_for(*p).is_some() {
            continue;
        }
        match ref_parties.get(p) {
            Some(r) if r.triple == side.triple => checked += 1,
            Some(r) => failures.push(format!(
                "byte identity: {tag}P{p}→label {:?} != reference {:?}",
                side.triple, r.triple)),
            None => failures.push(format!(
                "byte identity: {tag}P{p} absent from reference")),
        }
        let got = label.lanes.iter().find(|(id, _)| id == p);
        let want = ref_label.lanes.iter().find(|(id, _)| id == p);
        match (got, want) {
            (Some((_, g)), Some((_, w))) if g == w => checked += 1,
            (Some((_, g)), Some((_, w))) => failures.push(format!(
                "byte identity: {tag}label→P{p} {g:?} != reference \
                 {w:?}")),
            _ => failures.push(format!(
                "byte identity: {tag}label lane P{p} missing")),
        }
    }
    checked
}

/// Round parity for one session's feature parties.
fn round_parity(plan: &CasePlan, faulted_session: bool,
                parties: &BTreeMap<u16, PartySide>, rounds: u64,
                tag: &str, failures: &mut Vec<String>) {
    for (p, side) in parties {
        let expect = match plan
            .fault_for(*p)
            .filter(|_| faulted_session)
            .and_then(|f| f.kill_round())
        {
            Some(k) => k,
            None => rounds,
        };
        if side.completed != expect {
            failures.push(format!(
                "round parity: {tag}P{p} completed {} rounds, \
                 expected {expect}", side.completed));
        }
    }
}

/// Every faulted link must have injected at least once — a plan that
/// never fires tests nothing.
fn injection_coverage(plan: &CasePlan,
                      parties: &BTreeMap<u16, PartySide>,
                      failures: &mut Vec<String>) {
    for f in &plan.faults {
        let injected =
            parties.get(&f.party).map(|s| s.injected).unwrap_or(0);
        if injected == 0 {
            failures.push(format!(
                "injection: P{} applied none of its {} fault ops",
                f.party, f.ops.len()));
        }
    }
}

fn execute_mesh(plan: &CasePlan) -> anyhow::Result<CaseOutcome> {
    let cfg = plan.cfg()?;
    let (ref_parties, ref_label) = mesh_run(&cfg, plan.rounds, None)?;
    let (parties, label) = mesh_run(&cfg, plan.rounds, Some(plan))?;
    let mut failures = Vec::new();
    round_parity(plan, true, &parties, plan.rounds, "", &mut failures);
    injection_coverage(plan, &parties, &mut failures);
    let checked = clean_link_parity(plan, true, &parties, &label,
                                    &ref_parties, &ref_label, "",
                                    &mut failures);
    Ok(CaseOutcome {
        passed: failures.is_empty(),
        failures,
        rounds_completed: label.rounds,
        rejoined: label.rejoins > 0,
        faults_injected: parties.values().map(|s| s.injected).sum(),
        clean_links_checked: checked,
    })
}

// ---- tcp mode (kill / kill-during-rejoin) ----------------------------------

fn execute_tcp(plan: &CasePlan) -> anyhow::Result<CaseOutcome> {
    let cfg = plan.cfg()?;
    let rounds = plan.rounds;
    let lf = plan
        .faults
        .first()
        .ok_or_else(|| anyhow::anyhow!("tcp case without a fault"))?
        .clone();
    let kill = lf.kill_round().ok_or_else(|| {
        anyhow::anyhow!("tcp scenario requires a kill op, got {:?}",
                        lf.ops)
    })?;
    let abort_first = plan.scenario == Scenario::RejoinAbort;
    // TCP framing is byte-identical to in-proc for the identity
    // codec, so the cheap in-proc run is a valid reference.
    let (ref_parties, ref_label) = mesh_run(&cfg, rounds, None)?;

    let listener = SessionListener::bind("127.0.0.1:0")?
        .with_timeout(DIAL_TIMEOUT);
    let addr = listener.local_addr()?.to_string();

    // The victim: join, die at the planned round, (optionally) abort
    // one rejoin handshake mid-flight, rejoin for real, finish.
    let victim = std::thread::spawn({
        let cfg = cfg.clone();
        let addr = addr.clone();
        let lf = lf.clone();
        let case_seed = plan.case_seed;
        move || -> anyhow::Result<(u64, PartySide)> {
            let party = PartyId(lf.party);
            let (link, start) = SessionDialer::new(&addr, party)
                .with_timeout(DIAL_TIMEOUT)
                .establish_resumable(&cfg)?;
            anyhow::ensure!(start == 0,
                            "victim resumed at {start} on first join");
            let codec = compress::negotiate(cfg.codec_for(party.0),
                                            link.peer_codecs);
            let epoch = session_epoch(cfg.seed);
            let (t, ft) = wrap(&link, Some(&lf), case_seed);
            let died = feature_segment(&t, codec, cfg.seed, party.0,
                                       0, rounds)?;
            anyhow::ensure!(died == kill,
                            "victim died at {died}, planned {kill}");
            let injected =
                ft.map(|f| f.injected()).unwrap_or(0);
            drop(t);
            drop(link);
            if abort_first {
                // A valid Rejoin frame whose socket dies before the
                // ack is read: the kill-during-rejoin composition.
                let mut s = std::net::TcpStream::connect(&addr)?;
                crate::session::bootstrap::send_bootstrap_frame(
                    &mut s,
                    &Message::Rejoin {
                        party,
                        parties: cfg.parties as u16,
                        epoch,
                        last_round: died,
                        codecs: compress::supported_mask(),
                    })?;
                drop(s);
                // Let the aborted contact clear the vetting workers so
                // the two attempts cannot seat out of order.
                std::thread::sleep(Duration::from_millis(150));
            }
            let (fresh, resume, replays) = rejoin_dial(
                &addr, party, &cfg, epoch, died, DIAL_TIMEOUT)?;
            anyhow::ensure!(resume >= kill && resume <= rounds,
                            "resumed at {resume}, outside \
                             [{kill}, {rounds}]");
            for _ in 0..replays {
                let _ = fresh.recv()?; // stale in-flight derivatives
            }
            let completed = feature_segment(&fresh, codec, cfg.seed,
                                            party.0, resume, rounds)?;
            Ok((resume, PartySide {
                completed,
                injected,
                triple: triple(fresh.stats()),
            }))
        }
    });

    let mut others = Vec::new();
    for p in 1..cfg.parties as u16 {
        if p == lf.party {
            continue;
        }
        let cfg = cfg.clone();
        let addr = addr.clone();
        others.push(std::thread::spawn(
            move || -> anyhow::Result<(u16, PartySide)> {
                let (link, start) = SessionDialer::new(&addr,
                                                       PartyId(p))
                    .with_timeout(DIAL_TIMEOUT)
                    .establish_resumable(&cfg)?;
                anyhow::ensure!(start == 0, "P{p} resumed at {start}");
                let codec = compress::negotiate(cfg.codec_for(p),
                                                link.peer_codecs);
                let completed = feature_segment(
                    &link.transport, codec, cfg.seed, p, 0, rounds)?;
                Ok((p, PartySide {
                    completed,
                    injected: 0,
                    triple: triple(link.transport.stats()),
                }))
            },
        ));
    }

    let (links, readmission, _epoch, start) =
        listener.establish_supervised(&cfg)?;
    anyhow::ensure!(start == 0, "label resumed at {start}");
    let label = label_loop(&cfg, &links, Some(readmission), rounds)?;

    let mut failures = Vec::new();
    let mut parties = BTreeMap::new();
    for w in others {
        let (p, side) = w
            .join()
            .map_err(|_| anyhow::anyhow!("feature worker panicked"))??;
        parties.insert(p, side);
    }
    let (resume, victim_side) = victim
        .join()
        .map_err(|_| anyhow::anyhow!("victim worker panicked"))??;

    round_parity(plan, false, &parties, rounds, "", &mut failures);
    if victim_side.completed != rounds {
        failures.push(format!(
            "round parity: victim P{} finished at {} after resuming \
             at {resume}, expected {rounds}",
            lf.party, victim_side.completed));
    }
    if victim_side.injected == 0 {
        failures.push(format!(
            "injection: victim P{} never applied its kill", lf.party));
    }
    if label.rejoins == 0 {
        failures.push("rejoin: the label seated no rejoin".into());
    }
    let checked = clean_link_parity(plan, true, &parties, &label,
                                    &ref_parties, &ref_label, "",
                                    &mut failures);
    // The victim's post-resume link is fresh, so its ledger holds
    // exactly the surviving rounds' frames: the reference run divides
    // evenly per round and scales to `rounds - resume` of them.
    match ref_parties.get(&lf.party) {
        Some(r) if r.triple.2 == rounds
            && r.triple.0 % rounds == 0
            && r.triple.1 % rounds == 0 =>
        {
            let survived = rounds - resume;
            let want = (r.triple.0 / rounds * survived,
                        r.triple.1 / rounds * survived, survived);
            if victim_side.triple != want {
                failures.push(format!(
                    "byte identity: victim P{} post-resume {:?} != \
                     per-round reference {:?}",
                    lf.party, victim_side.triple, want));
            }
        }
        _ => failures.push(format!(
            "byte identity: reference for P{} is not per-round \
             uniform: {:?}",
            lf.party, ref_parties.get(&lf.party))),
    }
    Ok(CaseOutcome {
        passed: failures.is_empty(),
        failures,
        rounds_completed: label.rounds,
        rejoined: label.rejoins > 0,
        faults_injected: victim_side.injected,
        clean_links_checked: checked,
    })
}

// ---- serve mode ------------------------------------------------------------

fn execute_serve(plan: &CasePlan) -> anyhow::Result<CaseOutcome> {
    let rounds = plan.rounds;
    let cfg_a = plan.cfg()?; // the faulted session
    let mut cfg_b = plan.cfg()?; // its clean neighbor
    cfg_b.seed = plan.case_seed ^ 0x5EB; // distinct epoch, same shape
    let (ref_a_parties, ref_a_label) = mesh_run(&cfg_a, rounds, None)?;
    let (ref_b_parties, ref_b_label) = mesh_run(&cfg_b, rounds, None)?;

    let mut server =
        SessionServer::bind("127.0.0.1:0")?.with_join_timeout(
            DIAL_TIMEOUT);
    server.host(cfg_a.clone())?;
    server.host(cfg_b.clone())?;
    let addr = server.local_addr()?.to_string();

    let mut workers = Vec::new();
    for (cfg, faulted) in [(cfg_a.clone(), true),
                           (cfg_b.clone(), false)] {
        for p in 1..cfg.parties as u16 {
            let cfg = cfg.clone();
            let addr = addr.clone();
            let fault = if faulted {
                plan.fault_for(p).cloned()
            } else {
                None
            };
            let case_seed = plan.case_seed;
            workers.push(std::thread::spawn(
                move || -> anyhow::Result<(u64, u16, PartySide)> {
                    let (link, start) =
                        SessionDialer::new(&addr, PartyId(p))
                            .with_timeout(DIAL_TIMEOUT)
                            .establish_resumable(&cfg)?;
                    anyhow::ensure!(start == 0,
                                    "P{p} resumed at {start}");
                    let codec = compress::negotiate(
                        cfg.codec_for(p), link.peer_codecs);
                    let (t, ft) = wrap(&link, fault.as_ref(),
                                       case_seed);
                    let completed = feature_segment(
                        &t, codec, cfg.seed, p, 0, rounds)?;
                    Ok((cfg.seed, p, PartySide {
                        completed,
                        injected:
                            ft.map(|f| f.injected()).unwrap_or(0),
                        triple: triple(link.transport.stats()),
                    }))
                },
            ));
        }
    }

    let rollups: Arc<Mutex<BTreeMap<u64, LabelRollup>>> =
        Arc::new(Mutex::new(BTreeMap::new()));
    let outcomes = server.serve({
        let rollups = rollups.clone();
        move |h: SessionHandle| -> anyhow::Result<()> {
            let SessionHandle { cfg, links, readmission, .. } = h;
            let rollup = label_loop(&cfg, &links, Some(readmission),
                                    rounds)?;
            rollups.lock().unwrap().insert(cfg.seed, rollup);
            Ok(())
        }
    })?;

    let mut failures = Vec::new();
    for o in &outcomes {
        if let Err(e) = &o.result {
            failures.push(format!(
                "serve: session {} failed: {e:#}", o.label));
        }
    }
    let mut sessions: BTreeMap<u64, BTreeMap<u16, PartySide>> =
        BTreeMap::new();
    for w in workers {
        let (seed, p, side) = w
            .join()
            .map_err(|_| anyhow::anyhow!("feature worker panicked"))??;
        sessions.entry(seed).or_default().insert(p, side);
    }
    let rollups = rollups.lock().unwrap();

    let mut checked = 0;
    let mut injected = 0;
    let mut rounds_completed = rounds;
    for (seed, tag, faulted, ref_parties, ref_label) in [
        (cfg_a.seed, "faulted:", true, &ref_a_parties, &ref_a_label),
        (cfg_b.seed, "neighbor:", false, &ref_b_parties,
         &ref_b_label),
    ] {
        let parties = match sessions.get(&seed) {
            Some(p) => p,
            None => {
                failures.push(format!(
                    "serve: no feature reports for session {tag}"));
                continue;
            }
        };
        injected += parties.values().map(|s| s.injected).sum::<u64>();
        round_parity(plan, faulted, parties, rounds, tag,
                     &mut failures);
        match rollups.get(&seed) {
            Some(label) => {
                rounds_completed = rounds_completed.min(label.rounds);
                checked += clean_link_parity(
                    plan, faulted, parties, label, ref_parties,
                    ref_label, tag, &mut failures);
            }
            None => failures.push(format!(
                "serve: label rollup missing for session {tag}")),
        }
        if faulted {
            injection_coverage(plan, parties, &mut failures);
        }
    }
    Ok(CaseOutcome {
        passed: failures.is_empty(),
        failures,
        rounds_completed,
        rejoined: false,
        faults_injected: injected,
        clean_links_checked: checked,
    })
}

// ---- the budgeted driver ---------------------------------------------------

fn execute(plan: &CasePlan) -> anyhow::Result<CaseOutcome> {
    match plan.scenario.mode() {
        ExecMode::Mesh => execute_mesh(plan),
        ExecMode::Tcp => execute_tcp(plan),
        ExecMode::Serve => execute_serve(plan),
    }
}

/// Run one case under the no-panic / no-hang oracle: the session runs
/// on a worker thread; no verdict within `budget` is a hang (the
/// worker is leaked), a dropped channel without a verdict is a panic.
pub fn run_case(plan: &CasePlan, budget: Duration) -> CaseOutcome {
    let (tx, rx) = mpsc::channel();
    let p = plan.clone();
    std::thread::spawn(move || {
        let _ = tx.send(execute(&p));
    });
    match rx.recv_timeout(budget) {
        Ok(Ok(outcome)) => outcome,
        Ok(Err(e)) => CaseOutcome::infra(format!("error: {e:#}")),
        Err(mpsc::RecvTimeoutError::Timeout) => {
            CaseOutcome::infra(format!(
                "hang: no verdict within the {}ms wall-clock budget",
                budget.as_millis()))
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => CaseOutcome::infra(
            "panic: the case worker died without a verdict".into()),
    }
}

/// Sweep the whole `scenarios × seeds` grid, shrinking failures when
/// asked. The report is byte-for-byte reproducible for a fixed
/// `(scenarios, seeds, root_seed)`.
pub fn run_campaign(opts: &CampaignOpts) -> CampaignReport {
    let mut cases = Vec::new();
    for &sc in &opts.scenarios {
        for index in 0..opts.seeds {
            let plan = CasePlan::generate(sc, opts.root_seed, index);
            log::info!("campaign: running {}", plan.id());
            let outcome = run_case(&plan, opts.budget);
            let (shrunk, shrink_evals) = if !outcome.passed
                && opts.shrink
            {
                log::info!("campaign: shrinking {}", plan.id());
                let budget = opts.budget;
                let r = shrink::shrink(&plan, |cand| {
                    cand.executable() && !run_case(cand, budget).passed
                });
                (Some(r.plan), r.evals)
            } else {
                (None, 0)
            };
            cases.push(CaseReport { plan, outcome, shrunk,
                                    shrink_evals });
        }
    }
    CampaignReport { root_seed: opts.root_seed, cases }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::plan::FaultOp;

    const TEST_BUDGET: Duration = Duration::from_secs(60);

    #[test]
    fn mesh_single_fault_case_passes_and_is_deterministic() {
        let plan = CasePlan::generate(Scenario::Single, 42, 0);
        let a = run_case(&plan, TEST_BUDGET);
        assert!(a.passed, "{:?}", a.failures);
        assert!(a.faults_injected >= 1);
        assert_eq!(a.rounds_completed, plan.rounds);
        assert!(a.clean_links_checked >= 2,
                "both directions of the clean lane must be checked");
        let b = run_case(&plan, TEST_BUDGET);
        assert_eq!(a, b, "the same plan produced different outcomes");
    }

    #[test]
    fn mesh_multi_fault_and_codec_cross_cases_pass() {
        for sc in [Scenario::Multi, Scenario::Codec,
                   Scenario::Reorder] {
            let plan = CasePlan::generate(sc, 42, 1);
            let out = run_case(&plan, TEST_BUDGET);
            assert!(out.passed, "{}: {:?}", plan.id(), out.failures);
            assert!(out.faults_injected >= 1, "{}", plan.id());
        }
    }

    #[test]
    fn tcp_kill_case_heals_by_rejoin_and_passes() {
        let plan = CasePlan::generate(Scenario::Kill, 42, 0);
        let out = run_case(&plan, TEST_BUDGET);
        assert!(out.passed, "{}: {:?}", plan.id(), out.failures);
        assert!(out.rejoined, "the victim never rejoined");
        assert_eq!(out.rounds_completed, plan.rounds);
    }

    #[test]
    fn serve_case_keeps_the_neighbor_session_byte_identical() {
        let plan = CasePlan::generate(Scenario::Serve, 42, 0);
        let out = run_case(&plan, TEST_BUDGET);
        assert!(out.passed, "{}: {:?}", plan.id(), out.failures);
        // Session A's clean lane + all of session B, both directions.
        assert!(out.clean_links_checked >= 6,
                "checked only {} directed links",
                out.clean_links_checked);
    }

    #[test]
    fn a_malformed_plan_is_an_infra_failure_not_a_panic() {
        // A tcp scenario whose fault has no kill op: the executor
        // must return a failed outcome, not crash the process.
        let mut plan = CasePlan::generate(Scenario::Kill, 42, 0);
        plan.faults[0].ops = vec![FaultOp::DropFrame(1)];
        let out = run_case(&plan, TEST_BUDGET);
        assert!(!out.passed);
        assert!(out.failures[0].contains("kill op"),
                "{:?}", out.failures);
    }

    #[test]
    fn the_budget_oracle_reports_a_hang() {
        let plan = CasePlan::generate(Scenario::Single, 42, 2);
        let out = run_case(&plan, Duration::from_millis(1));
        assert!(!out.passed);
        assert!(out.failures[0].starts_with("hang:"),
                "{:?}", out.failures);
    }

    #[test]
    fn a_fixed_campaign_reports_byte_identically_twice() {
        let opts = CampaignOpts {
            scenarios: vec![Scenario::Single],
            seeds: 2,
            root_seed: 7,
            budget: TEST_BUDGET,
            shrink: false,
        };
        let a = run_campaign(&opts).to_json().to_string();
        let b = run_campaign(&opts).to_json().to_string();
        assert_eq!(a, b);
        let parsed = crate::util::json::Json::parse(&a).unwrap();
        assert_eq!(parsed.expect("cases_failed").unwrap()
                       .as_f64().unwrap(), 0.0,
                   "fixed campaign found failures: {a}");
    }
}
