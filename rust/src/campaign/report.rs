//! Campaign reporting (DESIGN.md §13).
//!
//! One [`CampaignReport`] per sweep: every case's expanded plan, its
//! oracle verdict, and — for failures shrunk by
//! [`shrink`](crate::campaign::shrink::shrink) — the minimized
//! reproducer. The JSON rendering goes through [`crate::util::json`]
//! (BTreeMap-backed objects), so the same campaign always serializes
//! to the same bytes: no timestamps, no durations, no map-order
//! nondeterminism. Wall-clock chatter belongs on stderr, never in the
//! artifact.

use crate::campaign::exec::CaseOutcome;
use crate::campaign::plan::{CasePlan, Scenario};
use crate::util::json::{self, Json};

/// One executed case: the plan that ran, what the oracles said, and
/// the shrunk reproducer when the case failed under `--shrink`.
#[derive(Debug, Clone)]
pub struct CaseReport {
    pub plan: CasePlan,
    pub outcome: CaseOutcome,
    pub shrunk: Option<CasePlan>,
    /// Predicate evaluations (case re-runs) the shrink spent.
    pub shrink_evals: u64,
}

/// The shape of a plan inside the report: session geometry plus one
/// ready-to-paste builder chain per faulted link.
fn plan_json(p: &CasePlan) -> Json {
    json::obj(vec![
        ("parties", json::num(p.parties as f64)),
        ("rounds", json::num(p.rounds as f64)),
        ("codecs", Json::Arr(
            p.codecs
                .iter()
                .map(|(id, c)| Json::Str(format!("party{id}:{}",
                                                 c.label())))
                .collect(),
        )),
        ("faults", Json::Arr(
            p.faults
                .iter()
                .map(|f| json::obj(vec![
                    ("party", json::num(f.party as f64)),
                    ("builder",
                     Json::Str(f.builder_chain(p.case_seed))),
                ]))
                .collect(),
        )),
    ])
}

impl CaseReport {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::Str(self.plan.id())),
            ("scenario",
             Json::Str(self.plan.scenario.label().to_string())),
            ("index", json::num(self.plan.index as f64)),
            // Seeds render as strings: u64 does not survive f64.
            ("case_seed",
             Json::Str(format!("0x{:X}", self.plan.case_seed))),
            ("plan", plan_json(&self.plan)),
            ("passed", Json::Bool(self.outcome.passed)),
            ("failures", Json::Arr(
                self.outcome
                    .failures
                    .iter()
                    .map(|f| Json::Str(f.clone()))
                    .collect(),
            )),
            ("rounds_completed",
             json::num(self.outcome.rounds_completed as f64)),
            ("rejoined", Json::Bool(self.outcome.rejoined)),
            ("faults_injected",
             json::num(self.outcome.faults_injected as f64)),
            ("clean_links_checked",
             json::num(self.outcome.clean_links_checked as f64)),
        ];
        if let Some(s) = &self.shrunk {
            fields.push(("shrunk", plan_json(s)));
            fields.push(("shrink_evals",
                         json::num(self.shrink_evals as f64)));
        }
        json::obj(fields)
    }
}

/// A whole sweep's verdict, serializable byte-for-byte reproducibly.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub root_seed: u64,
    pub cases: Vec<CaseReport>,
}

impl CampaignReport {
    pub fn passed(&self) -> usize {
        self.cases.iter().filter(|c| c.outcome.passed).count()
    }

    pub fn failed(&self) -> usize {
        self.cases.len() - self.passed()
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("root_seed", Json::Str(self.root_seed.to_string())),
            ("cases_total", json::num(self.cases.len() as f64)),
            ("cases_passed", json::num(self.passed() as f64)),
            ("cases_failed", json::num(self.failed() as f64)),
            ("cases", Json::Arr(
                self.cases.iter().map(|c| c.to_json()).collect())),
        ])
    }

    /// Bench-style per-scenario summary (stdout): cases, verdicts,
    /// total injections, rejoin coverage.
    pub fn summary_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<14} {:>6} {:>7} {:>7} {:>9} {:>9}\n",
            "scenario", "cases", "passed", "failed", "injected",
            "rejoined"));
        let mut total = (0usize, 0usize, 0u64, 0u64);
        for sc in Scenario::all() {
            let rows: Vec<&CaseReport> = self
                .cases
                .iter()
                .filter(|c| c.plan.scenario == sc)
                .collect();
            if rows.is_empty() {
                continue;
            }
            let passed =
                rows.iter().filter(|c| c.outcome.passed).count();
            let injected: u64 = rows
                .iter()
                .map(|c| c.outcome.faults_injected)
                .sum();
            let rejoined = rows
                .iter()
                .filter(|c| c.outcome.rejoined)
                .count() as u64;
            out.push_str(&format!(
                "{:<14} {:>6} {:>7} {:>7} {:>9} {:>9}\n",
                sc.label(), rows.len(), passed, rows.len() - passed,
                injected, rejoined));
            total.0 += rows.len();
            total.1 += passed;
            total.2 += injected;
            total.3 += rejoined;
        }
        out.push_str(&format!(
            "{:<14} {:>6} {:>7} {:>7} {:>9} {:>9}\n",
            "total", total.0, total.1, total.0 - total.1, total.2,
            total.3));
        out
    }

    /// Human rendering of every failing case: the oracle complaints
    /// and the reproducer builder chains (shrunk when available).
    pub fn failure_details(&self) -> String {
        let mut out = String::new();
        for c in self.cases.iter().filter(|c| !c.outcome.passed) {
            out.push_str(&format!("FAILED {}\n", c.plan.id()));
            for f in &c.outcome.failures {
                out.push_str(&format!("  - {f}\n"));
            }
            let repro = c.shrunk.as_ref().unwrap_or(&c.plan);
            let tag = if c.shrunk.is_some() {
                format!("shrunk ({} evals)", c.shrink_evals)
            } else {
                "as generated".to_string()
            };
            out.push_str(&format!(
                "  reproducer [{tag}]: {} parties, {} rounds\n",
                repro.parties, repro.rounds));
            for lf in &repro.faults {
                out.push_str(&format!(
                    "    P{}: {}\n", lf.party,
                    lf.builder_chain(repro.case_seed)));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::plan::{FaultOp, LinkFault};

    fn case(scenario: Scenario, index: u64, passed: bool)
            -> CaseReport {
        let plan = CasePlan {
            scenario,
            root_seed: 42,
            index,
            case_seed: 0xAB,
            parties: 3,
            rounds: 5,
            codecs: Vec::new(),
            faults: vec![LinkFault {
                party: 1,
                ops: vec![FaultOp::DropFrame(2),
                          FaultOp::KillAtRound(4)],
            }],
        };
        CaseReport {
            plan,
            outcome: CaseOutcome {
                passed,
                failures: if passed {
                    Vec::new()
                } else {
                    vec!["round parity: P1 completed 3, expected 4"
                         .to_string()]
                },
                rounds_completed: 5,
                rejoined: false,
                faults_injected: 2,
                clean_links_checked: 2,
            },
            shrunk: None,
            shrink_evals: 0,
        }
    }

    #[test]
    fn report_json_is_byte_deterministic_and_parses_back() {
        let report = CampaignReport {
            root_seed: 42,
            cases: vec![case(Scenario::Single, 0, true),
                        case(Scenario::Kill, 1, false)],
        };
        let a = report.to_json().to_string();
        let b = report.to_json().to_string();
        assert_eq!(a, b);
        let parsed = Json::parse(&a).unwrap();
        assert_eq!(parsed.expect("cases_total").unwrap().as_f64()
                       .unwrap(), 2.0);
        assert_eq!(parsed.expect("cases_failed").unwrap().as_f64()
                       .unwrap(), 1.0);
        assert_eq!(parsed.expect("root_seed").unwrap().as_str()
                       .unwrap(), "42");
        let cases = parsed.expect("cases").unwrap().as_arr().unwrap();
        let builder = cases[0]
            .expect("plan").unwrap()
            .expect("faults").unwrap()
            .as_arr().unwrap()[0]
            .expect("builder").unwrap()
            .as_str().unwrap()
            .to_string();
        assert!(builder.contains(".drop_frame(2)")
                    && builder.contains(".kill_at_round(4)"),
                "{builder}");
    }

    #[test]
    fn summary_table_aggregates_per_scenario() {
        let report = CampaignReport {
            root_seed: 7,
            cases: vec![case(Scenario::Single, 0, true),
                        case(Scenario::Single, 1, false),
                        case(Scenario::Kill, 0, true)],
        };
        let table = report.summary_table();
        let single = table
            .lines()
            .find(|l| l.starts_with("single"))
            .unwrap();
        let cols: Vec<&str> = single.split_whitespace().collect();
        assert_eq!(cols, vec!["single", "2", "1", "1", "4", "0"]);
        let total =
            table.lines().find(|l| l.starts_with("total")).unwrap();
        let cols: Vec<&str> = total.split_whitespace().collect();
        assert_eq!(cols, vec!["total", "3", "2", "1", "6", "0"]);
        assert_eq!((report.passed(), report.failed()), (2, 1));
    }

    #[test]
    fn failure_details_print_the_builder_chain() {
        let mut failing = case(Scenario::Kill, 1, false);
        failing.shrunk = Some(CasePlan {
            faults: vec![LinkFault {
                party: 1,
                ops: vec![FaultOp::KillAtRound(4)],
            }],
            rounds: 5,
            ..failing.plan.clone()
        });
        failing.shrink_evals = 9;
        let report =
            CampaignReport { root_seed: 7, cases: vec![failing] };
        let text = report.failure_details();
        assert!(text.contains("FAILED kill#1@42"), "{text}");
        assert!(text.contains("round parity"), "{text}");
        assert!(text.contains("shrunk (9 evals)"), "{text}");
        assert!(text.contains("FaultPlan::new(0x"), "{text}");
        assert!(text.contains(".kill_at_round(4)"), "{text}");
        assert!(!text.contains(".drop_frame"),
                "shrunk chain still shows the dropped op: {text}");
    }
}
