//! Seeded fault-plan generation (DESIGN.md §13).
//!
//! A campaign is a grid of *cases*: `(scenario, root_seed, index)`
//! expands — through one Pcg stream, nothing else — into a
//! [`CasePlan`]: a declarative description of one chaos session (mesh
//! shape, round count, per-party codecs, and the fault schedule of
//! every afflicted link). The same triple always expands to the same
//! plan, so a failing case from a nightly sweep reproduces from three
//! integers, and the shrinker can mutate plans structurally without
//! touching the RNG.
//!
//! `CasePlan` mirrors [`FaultPlan`](crate::transport::fault::FaultPlan)
//! but stays declarative ([`FaultOp`] values instead of the builder's
//! private fields): the executor lowers it with
//! [`LinkFault::to_fault_plan`], and a failing case prints itself as a
//! ready-to-paste builder chain via [`LinkFault::builder_chain`].

use crate::compress::CodecKind;
use crate::config::RunConfig;
use crate::transport::fault::FaultPlan;
use crate::util::rng::Pcg;

/// Pcg stream tag for campaign case expansion.
pub const CAMPAIGN_STREAM: u64 = 0xCA_4411;

/// Weyl increment decorrelating consecutive case indices.
const INDEX_GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// The straggler window every campaign session runs under: long
/// enough that an undisturbed in-proc or loopback frame never misses
/// it (byte parity of clean lanes stays exact), short enough that a
/// faulted round stales in bounded time.
pub const CAMPAIGN_STRAGGLER_MS: u64 = 500;

/// The per-case RNG: reproducible from `(root_seed, scenario, index)`
/// alone — no generation-order coupling between cases.
pub fn case_rng(root_seed: u64, scenario: Scenario, index: u64) -> Pcg {
    Pcg::new(
        root_seed.wrapping_add(index.wrapping_mul(INDEX_GOLDEN)),
        CAMPAIGN_STREAM ^ scenario.tag(),
    )
}

/// One fault injection, declaratively. Mirrors the
/// `FaultPlan` builder surface one-to-one so lowering is mechanical.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultOp {
    KillAtRound(u64),
    DropFrame(u64),
    /// `(nth, millis)`.
    DelayMs(u64, u64),
    DuplicateFrame(u64),
    CorruptFrame(u64),
    ReorderFrames(u64),
    PartitionRounds { from: u64, to: u64, both_ways: bool },
}

impl FaultOp {
    /// Lower onto a `FaultPlan` builder.
    pub fn apply(&self, plan: FaultPlan) -> FaultPlan {
        match *self {
            FaultOp::KillAtRound(r) => plan.kill_at_round(r),
            FaultOp::DropFrame(n) => plan.drop_frame(n),
            FaultOp::DelayMs(n, ms) => plan.delay_ms(n, ms),
            FaultOp::DuplicateFrame(n) => plan.duplicate_frame(n),
            FaultOp::CorruptFrame(n) => plan.corrupt_frame(n),
            FaultOp::ReorderFrames(n) => plan.reorder_frames(n),
            FaultOp::PartitionRounds { from, to, both_ways: false } => {
                plan.partition_rounds(from, to)
            }
            FaultOp::PartitionRounds { from, to, both_ways: true } => {
                plan.partition_rounds_bidirectional(from, to)
            }
        }
    }

    /// The builder call this op renders to (appended to
    /// `FaultPlan::new(..)` by [`LinkFault::builder_chain`]).
    pub fn builder_call(&self) -> String {
        match *self {
            FaultOp::KillAtRound(r) => format!(".kill_at_round({r})"),
            FaultOp::DropFrame(n) => format!(".drop_frame({n})"),
            FaultOp::DelayMs(n, ms) => format!(".delay_ms({n}, {ms})"),
            FaultOp::DuplicateFrame(n) => {
                format!(".duplicate_frame({n})")
            }
            FaultOp::CorruptFrame(n) => format!(".corrupt_frame({n})"),
            FaultOp::ReorderFrames(n) => format!(".reorder_frames({n})"),
            FaultOp::PartitionRounds { from, to, both_ways: false } => {
                format!(".partition_rounds({from}, {to})")
            }
            FaultOp::PartitionRounds { from, to, both_ways: true } => {
                format!(".partition_rounds_bidirectional({from}, {to})")
            }
        }
    }

    /// The frame/round index the op anchors to — the shrinker's
    /// per-op minimization axis.
    pub fn index(&self) -> u64 {
        match *self {
            FaultOp::KillAtRound(r) => r,
            FaultOp::DropFrame(n)
            | FaultOp::DelayMs(n, _)
            | FaultOp::DuplicateFrame(n)
            | FaultOp::CorruptFrame(n)
            | FaultOp::ReorderFrames(n) => n,
            FaultOp::PartitionRounds { from, .. } => from,
        }
    }

    /// The same op re-anchored at index `v` (a partition keeps its
    /// width and direction).
    pub fn with_index(&self, v: u64) -> FaultOp {
        match *self {
            FaultOp::KillAtRound(_) => FaultOp::KillAtRound(v),
            FaultOp::DropFrame(_) => FaultOp::DropFrame(v),
            FaultOp::DelayMs(_, ms) => FaultOp::DelayMs(v, ms),
            FaultOp::DuplicateFrame(_) => FaultOp::DuplicateFrame(v),
            FaultOp::CorruptFrame(_) => FaultOp::CorruptFrame(v),
            FaultOp::ReorderFrames(_) => FaultOp::ReorderFrames(v),
            FaultOp::PartitionRounds { from, to, both_ways } => {
                FaultOp::PartitionRounds {
                    from: v,
                    to: v + (to - from),
                    both_ways,
                }
            }
        }
    }

    pub fn is_kill(&self) -> bool {
        matches!(self, FaultOp::KillAtRound(_))
    }
}

/// The fault schedule of one feature party's link (its outbound,
/// party → label direction — where the activation traffic lives).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LinkFault {
    pub party: u16,
    pub ops: Vec<FaultOp>,
}

impl LinkFault {
    /// The seed the lowered `FaultPlan` carries (derives corrupt-bit
    /// placement): per-party so two faulted links never share a
    /// corruption stream.
    pub fn fault_seed(&self, case_seed: u64) -> u64 {
        case_seed ^ ((self.party as u64) << 32)
    }

    /// Lower to a runnable `FaultPlan`.
    pub fn to_fault_plan(&self, case_seed: u64) -> FaultPlan {
        self.ops
            .iter()
            .fold(FaultPlan::new(self.fault_seed(case_seed)),
                  |p, op| op.apply(p))
    }

    /// Ready-to-paste builder chain reproducing this link's plan.
    pub fn builder_chain(&self, case_seed: u64) -> String {
        let mut s = format!("FaultPlan::new(0x{:X})",
                            self.fault_seed(case_seed));
        for op in &self.ops {
            s.push_str(&op.builder_call());
        }
        s
    }

    /// The round the link dies at, if any op kills it.
    pub fn kill_round(&self) -> Option<u64> {
        self.ops
            .iter()
            .filter_map(|op| match op {
                FaultOp::KillAtRound(r) => Some(*r),
                _ => None,
            })
            .min()
    }
}

/// Campaign scenario families — each stresses a different lifecycle
/// surface, and each maps to one executor mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// One fault on one lane of an in-proc K=3 mesh.
    Single,
    /// Two faulted lanes at once on a K=4 mesh, each carrying one or
    /// two composed ops (possibly two parties down simultaneously).
    Multi,
    /// Frame reordering, optionally composed with a duplicate.
    Reorder,
    /// Fault × codec cross-product: per-party codecs drawn from the
    /// full family, one fault on one lane.
    Codec,
    /// A `FaultPlan` kill over real TCP, healed by `rejoin_dial`.
    Kill,
    /// A kill whose *first rejoin attempt* is itself killed
    /// mid-handshake; the second attempt must heal the session.
    RejoinAbort,
    /// A `SessionServer` hosting the faulted session next to a clean
    /// neighbor session: the neighbor must stay byte-identical.
    Serve,
}

/// How the executor realizes a scenario's session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// In-proc star, label drives `LaneSet` directly.
    Mesh,
    /// Loopback TCP through `SessionListener` with a re-admission
    /// point (rejoin scenarios need real sockets).
    Tcp,
    /// Two sessions multiplexed behind one `SessionServer`.
    Serve,
}

impl Scenario {
    pub fn all() -> [Scenario; 7] {
        [Scenario::Single, Scenario::Multi, Scenario::Reorder,
         Scenario::Codec, Scenario::Kill, Scenario::RejoinAbort,
         Scenario::Serve]
    }

    pub fn label(&self) -> &'static str {
        match self {
            Scenario::Single => "single",
            Scenario::Multi => "multi",
            Scenario::Reorder => "reorder",
            Scenario::Codec => "codec",
            Scenario::Kill => "kill",
            Scenario::RejoinAbort => "rejoin-abort",
            Scenario::Serve => "serve",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Scenario> {
        Scenario::all()
            .into_iter()
            .find(|sc| sc.label() == s)
            .ok_or_else(|| anyhow::anyhow!(
                "unknown scenario '{s}' (expected one of: {})",
                Scenario::all()
                    .iter()
                    .map(|sc| sc.label())
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
    }

    pub fn mode(&self) -> ExecMode {
        match self {
            Scenario::Kill | Scenario::RejoinAbort => ExecMode::Tcp,
            Scenario::Serve => ExecMode::Serve,
            _ => ExecMode::Mesh,
        }
    }

    /// RNG stream salt (keeps same-index cases of different scenarios
    /// decorrelated).
    fn tag(&self) -> u64 {
        match self {
            Scenario::Single => 1,
            Scenario::Multi => 2,
            Scenario::Reorder => 3,
            Scenario::Codec => 4,
            Scenario::Kill => 5,
            Scenario::RejoinAbort => 6,
            Scenario::Serve => 7,
        }
    }
}

/// One fully-expanded chaos case: everything the executor needs, and
/// everything the shrinker mutates. Generation is the only place the
/// RNG is consulted — a mutated plan stays exactly as written.
#[derive(Debug, Clone, PartialEq)]
pub struct CasePlan {
    pub scenario: Scenario,
    pub root_seed: u64,
    pub index: u64,
    /// Session seed (drives epoch, synthetic tensors, corruption
    /// bits) — itself derived from the case RNG.
    pub case_seed: u64,
    pub parties: usize,
    pub rounds: u64,
    /// Per-party codec overrides (`[party.N] compress = ..`).
    pub codecs: Vec<(u16, CodecKind)>,
    /// Faulted links. Always leaves at least one feature lane clean,
    /// so the session's "≥1 contributing lane" invariant — and the
    /// clean-link byte-parity oracle — stay meaningful.
    pub faults: Vec<LinkFault>,
}

/// One non-kill op anchored at a frame index in `1..max_round`.
fn sample_non_kill(rng: &mut Pcg, max_round: u64) -> FaultOp {
    let span = max_round.max(2) - 1;
    let nth = 1 + rng.gen_range(span as u32) as u64;
    match rng.gen_range(6) {
        0 => FaultOp::DropFrame(nth),
        1 => FaultOp::DelayMs(nth, 50 + rng.gen_range(100) as u64),
        2 => FaultOp::DuplicateFrame(nth),
        3 => FaultOp::CorruptFrame(nth),
        4 => FaultOp::ReorderFrames(nth),
        _ => {
            let width = 1 + rng.gen_range(2) as u64;
            // A bidirectional window must end before the final round:
            // if it swallowed the label's last derivative, the feature
            // loop could only finish via shutdown and round parity
            // would (correctly, but uninterestingly) fail.
            let both_ways = rng.gen_range(2) == 0
                && nth + 1 < max_round;
            let cap = if both_ways { max_round - 1 } else { max_round };
            FaultOp::PartitionRounds {
                from: nth,
                to: (nth + width).min(cap),
                both_ways,
            }
        }
    }
}

fn sample_codec(rng: &mut Pcg) -> CodecKind {
    match rng.gen_range(4) {
        0 => CodecKind::Identity,
        1 => CodecKind::Fp16,
        2 => CodecKind::QuantInt8,
        _ => CodecKind::TopK(4),
    }
}

impl CasePlan {
    /// Expand `(scenario, root_seed, index)` into a full case. Every
    /// sampled placement is constrained to actually *trigger* within
    /// the case's rounds (a kill follows any other op on the same
    /// link), so each faulted link injects at least once.
    pub fn generate(scenario: Scenario, root_seed: u64, index: u64)
                    -> CasePlan {
        let mut rng = case_rng(root_seed, scenario, index);
        let case_seed = rng.next_u64();
        let mut plan = CasePlan {
            scenario,
            root_seed,
            index,
            case_seed,
            parties: 3,
            rounds: 4,
            codecs: Vec::new(),
            faults: Vec::new(),
        };
        match scenario {
            Scenario::Single => {
                plan.rounds = 4 + rng.gen_range(4) as u64;
                let party = 1 + rng.gen_range(2) as u16;
                let op = sample_non_kill(&mut rng, plan.rounds);
                plan.faults.push(LinkFault { party, ops: vec![op] });
            }
            Scenario::Multi => {
                plan.parties = 4;
                plan.rounds = 5 + rng.gen_range(3) as u64;
                // Two distinct faulted parties out of {1, 2, 3} — the
                // third stays clean for the parity oracle.
                let a = 1 + rng.gen_range(3) as u16;
                let b = 1 + ((a - 1 + 1 + rng.gen_range(2) as u16) % 3);
                for party in [a, b] {
                    // One non-kill op, optionally followed by a kill
                    // strictly after it (fault-then-die composition;
                    // two kills ⇒ two parties down at once).
                    let op = sample_non_kill(&mut rng, plan.rounds - 1);
                    let mut ops = vec![op];
                    if rng.gen_range(2) == 0 {
                        let lo = op.index() + 1;
                        let span = (plan.rounds - lo).max(1);
                        let k = lo + rng.gen_range(span as u32) as u64;
                        ops.push(FaultOp::KillAtRound(
                            k.min(plan.rounds - 1)));
                    }
                    plan.faults.push(LinkFault { party, ops });
                }
            }
            Scenario::Reorder => {
                plan.rounds = 5 + rng.gen_range(3) as u64;
                let party = 1 + rng.gen_range(2) as u16;
                let nth = 1 + rng.gen_range(plan.rounds as u32 - 1)
                    as u64;
                let mut ops = vec![FaultOp::ReorderFrames(nth)];
                if rng.gen_range(2) == 0 && nth + 1 < plan.rounds {
                    ops.push(FaultOp::DuplicateFrame(nth + 1));
                }
                plan.faults.push(LinkFault { party, ops });
            }
            Scenario::Codec => {
                plan.rounds = 4 + rng.gen_range(3) as u64;
                plan.codecs = vec![(1, sample_codec(&mut rng)),
                                   (2, sample_codec(&mut rng))];
                let party = 1 + rng.gen_range(2) as u16;
                let op = sample_non_kill(&mut rng, plan.rounds);
                plan.faults.push(LinkFault { party, ops: vec![op] });
            }
            Scenario::Kill => {
                plan.parties = 3 + rng.gen_range(2) as usize;
                plan.rounds = 6 + rng.gen_range(3) as u64;
                let party =
                    1 + rng.gen_range(plan.parties as u32 - 1) as u16;
                let k = 2 + rng.gen_range(plan.rounds as u32 - 4)
                    as u64;
                plan.faults.push(LinkFault {
                    party,
                    ops: vec![FaultOp::KillAtRound(k)],
                });
            }
            Scenario::RejoinAbort => {
                plan.rounds = 7 + rng.gen_range(2) as u64;
                let party = 1 + rng.gen_range(2) as u16;
                let k = 2 + rng.gen_range(2) as u64;
                plan.faults.push(LinkFault {
                    party,
                    ops: vec![FaultOp::KillAtRound(k)],
                });
            }
            Scenario::Serve => {
                plan.rounds = 4 + rng.gen_range(3) as u64;
                let party = 1 + rng.gen_range(2) as u16;
                let op = sample_non_kill(&mut rng, plan.rounds);
                plan.faults.push(LinkFault { party, ops: vec![op] });
            }
        }
        plan
    }

    /// The session config this case runs under (see
    /// [`RunConfig::protocol_probe`]).
    pub fn cfg(&self) -> anyhow::Result<RunConfig> {
        let mut cfg = RunConfig::protocol_probe(
            self.parties, self.case_seed, CAMPAIGN_STRAGGLER_MS);
        cfg.party_compress = self.codecs.clone();
        cfg.validate()?;
        Ok(cfg)
    }

    /// The fault schedule of `party`'s link, if faulted.
    pub fn fault_for(&self, party: u16) -> Option<&LinkFault> {
        self.faults.iter().find(|f| f.party == party)
    }

    /// Whether every op can actually trigger — and every party can
    /// still terminate — within this plan's round budget. Generated
    /// plans always are; the shrinker skips candidates that fall
    /// outside this envelope, so a shrink can never "reproduce" a
    /// failure by mutating a plan into one that starves the final
    /// round instead.
    pub fn executable(&self) -> bool {
        self.faults.iter().all(|f| {
            f.ops.iter().all(|op| match *op {
                FaultOp::PartitionRounds { from, to, both_ways } => {
                    from < to
                        && from < self.rounds
                        && (!both_ways || to < self.rounds)
                }
                _ => op.index() < self.rounds,
            })
        })
    }

    /// Human/report identity line: `scenario#index@root`.
    pub fn id(&self) -> String {
        format!("{}#{}@{}", self.scenario.label(), self.index,
                self.root_seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expansion_is_reproducible_from_the_triple_alone() {
        for sc in Scenario::all() {
            for index in 0..16 {
                let a = CasePlan::generate(sc, 42, index);
                let b = CasePlan::generate(sc, 42, index);
                assert_eq!(a, b, "{sc:?}#{index} not reproducible");
                let c = CasePlan::generate(sc, 43, index);
                assert!(a != c || a.faults.is_empty(),
                        "{sc:?}#{index} ignores the root seed");
            }
        }
    }

    #[test]
    fn every_generated_case_is_well_formed() {
        for sc in Scenario::all() {
            for index in 0..32 {
                let p = CasePlan::generate(sc, 7, index);
                p.cfg().unwrap();
                assert!(!p.faults.is_empty(), "{}: no faults", p.id());
                assert!(p.executable(), "{}: not executable: {:?}",
                        p.id(), p.faults);
                // At least one clean feature lane survives by
                // construction (the parity oracle and the session's
                // "some lane contributes" invariant both need it).
                assert!(p.faults.len() < p.parties - 1,
                        "{}: {} faulted of {} feature lanes",
                        p.id(), p.faults.len(), p.parties - 1);
                for f in &p.faults {
                    assert!(f.party >= 1
                            && (f.party as usize) < p.parties,
                            "{}: fault on party {}", p.id(), f.party);
                    for op in &f.ops {
                        assert!(op.index() >= 1
                                && op.index() < p.rounds,
                                "{}: op {:?} outside 1..{}",
                                p.id(), op, p.rounds);
                    }
                    if let Some(k) = f.kill_round() {
                        for op in &f.ops {
                            assert!(op.is_kill() || op.index() < k,
                                    "{}: op {:?} after kill at {k}",
                                    p.id(), op);
                        }
                    }
                }
                // Distinct faulted parties.
                let mut parties: Vec<u16> =
                    p.faults.iter().map(|f| f.party).collect();
                parties.sort_unstable();
                parties.dedup();
                assert_eq!(parties.len(), p.faults.len(),
                           "{}: duplicate faulted party", p.id());
            }
        }
    }

    #[test]
    fn lowering_and_builder_chain_agree() {
        let lf = LinkFault {
            party: 2,
            ops: vec![
                FaultOp::DropFrame(3),
                FaultOp::DelayMs(1, 75),
                FaultOp::PartitionRounds {
                    from: 4, to: 6, both_ways: true,
                },
                FaultOp::ReorderFrames(2),
                FaultOp::KillAtRound(7),
            ],
        };
        let chain = lf.builder_chain(0xAB);
        assert!(chain.starts_with("FaultPlan::new(0x"), "{chain}");
        for frag in [".drop_frame(3)", ".delay_ms(1, 75)",
                     ".partition_rounds_bidirectional(4, 6)",
                     ".reorder_frames(2)", ".kill_at_round(7)"] {
            assert!(chain.contains(frag), "{chain} missing {frag}");
        }
        // The lowered plan carries the kill (the one builder knob
        // observable from outside).
        let plan = lf.to_fault_plan(0xAB);
        assert_eq!(plan.kill_round(), Some(7));
        assert_eq!(lf.kill_round(), Some(7));
    }

    #[test]
    fn op_index_roundtrip_preserves_shape() {
        let ops = [
            FaultOp::KillAtRound(5),
            FaultOp::DropFrame(3),
            FaultOp::DelayMs(2, 99),
            FaultOp::DuplicateFrame(4),
            FaultOp::CorruptFrame(6),
            FaultOp::ReorderFrames(1),
            FaultOp::PartitionRounds { from: 3, to: 5,
                                       both_ways: false },
        ];
        for op in ops {
            let moved = op.with_index(9);
            assert_eq!(moved.index(), 9);
            assert_eq!(moved.with_index(op.index()), op,
                       "{op:?} did not round-trip");
            assert_eq!(op.is_kill(),
                       matches!(op, FaultOp::KillAtRound(_)));
        }
        // A partition keeps its width when re-anchored.
        let p = FaultOp::PartitionRounds { from: 3, to: 5,
                                           both_ways: true };
        assert_eq!(p.with_index(0),
                   FaultOp::PartitionRounds { from: 0, to: 2,
                                              both_ways: true });
    }

    #[test]
    fn scenario_labels_parse_back() {
        for sc in Scenario::all() {
            assert_eq!(Scenario::parse(sc.label()).unwrap(), sc);
        }
        assert!(Scenario::parse("bogus").is_err());
    }
}
