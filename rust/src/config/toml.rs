//! TOML-subset parser (the `toml` crate is unavailable offline).
//!
//! Supported grammar — everything the run configs need:
//!   - `[section]` and `[nested.section]` headers
//!   - `key = "string" | 123 | 4.5 | true | false | [1, 2, 3]`
//!   - `#` comments, blank lines
//!
//! Values land in a flat `section.key → Value` map; typed accessors give
//! loud errors with the offending line number.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

#[derive(Debug, Default)]
pub struct TomlDoc {
    map: BTreeMap<String, Value>,
}

impl TomlDoc {
    pub fn parse(src: &str) -> anyhow::Result<Self> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!(
                        "line {}: unterminated section header", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    anyhow::bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let (key, val) = line.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("line {}: expected 'key = value'", lineno + 1)
            })?;
            let key = key.trim();
            if key.is_empty() {
                anyhow::bail!("line {}: empty key", lineno + 1);
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let value = parse_value(val.trim()).map_err(|e| {
                anyhow::anyhow!("line {}: {e}", lineno + 1)
            })?;
            if map.insert(full.clone(), value).is_some() {
                anyhow::bail!("line {}: duplicate key '{full}'", lineno + 1);
            }
        }
        Ok(TomlDoc { map })
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> anyhow::Result<String> {
        match self.map.get(key) {
            None => Ok(default.to_string()),
            Some(Value::Str(s)) => Ok(s.clone()),
            Some(v) => anyhow::bail!("{key}: expected string, got {v:?}"),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.map.get(key) {
            None => Ok(default),
            Some(Value::Num(x)) => Ok(*x),
            Some(v) => anyhow::bail!("{key}: expected number, got {v:?}"),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        let x = self.f64_or(key, default as f64)?;
        if x < 0.0 || x.fract() != 0.0 {
            anyhow::bail!("{key}: expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> anyhow::Result<bool> {
        match self.map.get(key) {
            None => Ok(default),
            Some(Value::Bool(b)) => Ok(*b),
            Some(v) => anyhow::bail!("{key}: expected bool, got {v:?}"),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings is respected.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> anyhow::Result<Value> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        if inner.contains('"') {
            anyhow::bail!("embedded quotes unsupported");
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items = inner
            .split(',')
            .map(|x| parse_value(x.trim()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        return Ok(Value::Arr(items));
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| anyhow::anyhow!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            "# run config\n\
             model = \"wdl\"\n\
             rounds = 500\n\
             lr = 0.05  # learning rate\n\
             verbose = true\n\
             sweep = [1, 3, 5]\n\
             [wan]\n\
             bandwidth_mbps = 300\n",
        )
        .unwrap();
        assert_eq!(doc.str_or("model", "x").unwrap(), "wdl");
        assert_eq!(doc.usize_or("rounds", 0).unwrap(), 500);
        assert!((doc.f64_or("lr", 0.0).unwrap() - 0.05).abs() < 1e-12);
        assert!(doc.bool_or("verbose", false).unwrap());
        assert_eq!(doc.f64_or("wan.bandwidth_mbps", 0.0).unwrap(), 300.0);
        assert_eq!(
            doc.get("sweep").unwrap(),
            &Value::Arr(vec![Value::Num(1.0), Value::Num(3.0),
                             Value::Num(5.0)])
        );
    }

    #[test]
    fn defaults_apply_when_missing() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.usize_or("rounds", 7).unwrap(), 7);
        assert_eq!(doc.str_or("model", "dssm").unwrap(), "dssm");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["[sec", "novalue", "k = \"open", "k = [1, 2",
                    "k = nope", "k = 1\nk = 2"] {
            assert!(TomlDoc::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn type_errors_are_loud() {
        let doc = TomlDoc::parse("rounds = \"many\"").unwrap();
        assert!(doc.usize_or("rounds", 1).is_err());
        let doc = TomlDoc::parse("lr = 0.5").unwrap();
        assert!(doc.str_or("lr", "").is_err());
    }

    #[test]
    fn fractional_rejected_for_usize() {
        let doc = TomlDoc::parse("rounds = 1.5").unwrap();
        assert!(doc.usize_or("rounds", 1).is_err());
    }
}
