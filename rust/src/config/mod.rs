//! Run configuration: typed config struct, TOML loader, presets.
//!
//! A `RunConfig` fully determines one training run: model/dataset/size
//! (which select an artifact set), the algorithm (Vanilla / FedBCD /
//! CELU-VFL) with its hyper-parameters (R, W, ξ), the optimizer settings,
//! the synthetic-data parameters and the WAN simulation profile.

pub mod toml;

use self::toml::TomlDoc;

use crate::compress::CodecKind;

/// Valid `--algorithm` / `algorithm =` values, kept next to the parser
/// so error messages can never drift from what it accepts.
pub const VALID_ALGORITHMS: &str = "vanilla, fedbcd, celu (alias: celu-vfl)";

/// Training algorithm, per the paper's §5.3 competitors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// One exchange per update; no local steps (R effectively 1).
    Vanilla,
    /// FedBCD (Liu et al.): R consecutive local updates on the latest
    /// batch (≍ workset W=1), no instance weighting.
    FedBcd,
    /// CELU-VFL: workset of W batches, round-robin local sampling,
    /// staleness-aware instance weighting at threshold ξ.
    CeluVfl,
}

impl Algorithm {
    /// Parse a CLI/TOML algorithm name. The error lists every valid
    /// value, so a typo is self-correcting at the terminal.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "vanilla" => Ok(Algorithm::Vanilla),
            "fedbcd" => Ok(Algorithm::FedBcd),
            "celu" | "celu-vfl" => Ok(Algorithm::CeluVfl),
            _ => anyhow::bail!(
                "unknown algorithm '{s}' — valid values: {VALID_ALGORITHMS}"
            ),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Vanilla => "vanilla",
            Algorithm::FedBcd => "fedbcd",
            Algorithm::CeluVfl => "celu",
        }
    }
}

/// Valid `--data-format` / `data_format =` values.
pub const VALID_DATA_FORMATS: &str = "csv | libsvm | synthetic";

/// Where training rows come from (DESIGN.md §12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataFormat {
    /// `key,label,f0,…` rows streamed from `--data` in chunks.
    Csv,
    /// `label idx:val …` rows streamed from `--data` in chunks.
    Libsvm,
    /// The in-memory generator (historic default; no `--data`).
    Synthetic,
}

impl DataFormat {
    /// Parse a CLI/TOML format name; the error lists the menu.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "csv" => Ok(DataFormat::Csv),
            "libsvm" => Ok(DataFormat::Libsvm),
            "synthetic" => Ok(DataFormat::Synthetic),
            _ => anyhow::bail!(
                "unknown data format '{s}' — valid values: \
                 {VALID_DATA_FORMATS}"
            ),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DataFormat::Csv => "csv",
            DataFormat::Libsvm => "libsvm",
            DataFormat::Synthetic => "synthetic",
        }
    }

    /// Does this format stream from an on-disk file?
    pub fn is_streaming(self) -> bool {
        !matches!(self, DataFormat::Synthetic)
    }
}

/// Local-sampling strategy for the workset table (paper §3.2 / Figure 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sampling {
    /// Re-use the most recent batch for consecutive steps (FedBCD).
    Consecutive,
    /// Round-robin over the workset: a batch is not re-sampled within
    /// W−1 local steps (CELU-VFL).
    RoundRobin,
}

/// WAN simulation profile (paper §2.1: geo-distributed, ~300 Mbps,
/// gateway-proxied).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WanProfile {
    /// Link bandwidth in megabits/s. 0 disables the bandwidth charge.
    pub bandwidth_mbps: f64,
    /// Round-trip latency in ms (charged half per one-way message).
    pub rtt_ms: f64,
    /// Extra per-message gateway/proxy overhead in ms (paper: messages
    /// are proxied by gateway machines).
    pub gateway_ms: f64,
}

impl WanProfile {
    /// The paper's testbed: 300 Mbps, typical cross-DC RTT.
    pub fn paper() -> Self {
        WanProfile { bandwidth_mbps: 300.0, rtt_ms: 20.0, gateway_ms: 2.0 }
    }

    /// No simulated delay (unit tests, micro-benches).
    pub fn instant() -> Self {
        WanProfile { bandwidth_mbps: 0.0, rtt_ms: 0.0, gateway_ms: 0.0 }
    }

    /// One-way delay charged to a message of `bytes` payload.
    pub fn one_way_delay(&self, bytes: usize) -> std::time::Duration {
        let mut secs = self.rtt_ms / 2.0 / 1e3 + self.gateway_ms / 1e3;
        if self.bandwidth_mbps > 0.0 {
            secs += (bytes as f64 * 8.0) / (self.bandwidth_mbps * 1e6);
        }
        std::time::Duration::from_secs_f64(secs)
    }
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    // model / artifacts
    pub model: String,    // "wdl" | "dssm"
    pub dataset: String,  // "criteo" | "avazu" | "d3"
    pub size: String,     // "tiny" | "small" | "big" | "paper"
    pub artifacts_dir: String,

    // algorithm
    pub algorithm: Algorithm,
    /// Max local updates per cached batch (R). Vanilla ⇒ 0 local steps.
    pub r_local: usize,
    /// Workset capacity (W).
    pub w_workset: usize,
    /// Weighting threshold ξ in degrees; 180 disables weighting
    /// (cos 180° = −1 keeps every instance at its raw cosine weight...
    /// see `weighting_enabled`: 180 maps to the unweighted algorithm).
    pub xi_degrees: f64,
    /// Wire codec for the exchanged statistics (`compress::CodecKind`),
    /// negotiated down to identity when the peer can't decode it.
    pub compress: CodecKind,
    /// Total parties in the session, label party included (`--parties`).
    /// 2 is the paper's two-party protocol; K > 2 runs K−1 feature
    /// parties over a v2-framed star mesh (session module).
    pub parties: usize,
    /// Per-party codec overrides from `[party.<id>]` TOML sections:
    /// `(feature party id, codec)` — the codec requested on that
    /// party's link in both directions, still negotiated per-link.
    pub party_compress: Vec<(u16, CodecKind)>,

    // optimizer / training
    pub lr: f64,
    pub seed: u64,
    pub trials: usize,
    pub max_rounds: usize,
    /// Wall-clock budget in seconds; 0 disables (Fig. 6 runs use this).
    pub max_seconds: f64,
    pub target_auc: f64,
    pub eval_every: usize,
    pub eval_batches: usize,

    // synthetic data
    pub train_instances: usize,
    pub test_instances: usize,
    /// Label noise: probability a teacher label is flipped.
    pub label_noise: f64,

    // data plane (DESIGN.md §12)
    /// On-disk table for the streaming formats (`--data`). Empty with
    /// `data_format = synthetic` (the historic in-memory generator).
    pub data: String,
    /// Row source: csv | libsvm | synthetic (`--data-format`).
    pub data_format: DataFormat,
    /// Rows per streaming window (`--chunk-rows`) — the constant-memory
    /// bound: no party materializes more training rows than this.
    pub chunk_rows: usize,
    /// Expected aligned (PSI-intersection) fraction in (0, 1]
    /// (`--overlap`). 1.0 is the historic fully-aligned regime and is
    /// byte-identical to it on the wire.
    pub overlap: f64,
    /// Self-supervised local updates each feature party runs on
    /// unaligned rows per communication round (`--ssl-ratio`); only
    /// meaningful at overlap < 1. 0 disables SSL work.
    pub ssl_ratio: usize,

    // environment
    pub wan: WanProfile,
    /// Extra artificial compute slow-down per step (secs) — used by the
    /// WAN-regime benches to emulate the paper's compute:comm ratio.
    pub compute_delay_s: f64,

    // supervised lifecycle (DESIGN.md §8)
    /// Bounded straggler wait per activation lane, in milliseconds
    /// (`--straggler-wait-ms`). 0 (default) disables supervision:
    /// collection blocks indefinitely, exactly the historic behaviour.
    /// With a budget, a lane that misses it is stepped on its cached
    /// stale statistics and reconciled when it catches up.
    pub straggler_wait_ms: u64,
    /// Directory for label-party checkpoint snapshots
    /// (`--checkpoint-dir`). Empty (default) disables checkpointing.
    pub checkpoint_dir: String,
    /// Write a snapshot every this many communication rounds
    /// (`--checkpoint-every`; only meaningful with `checkpoint_dir`).
    pub checkpoint_every: usize,
    /// Shared token gating the observability endpoints (`/metrics`,
    /// `/watch`) on the session port: requests must carry
    /// `Authorization: Bearer <token>` or get a 401. Empty (default)
    /// leaves the plane open. Join/Rejoin are never gated — parties
    /// authenticate by session epoch, not by header.
    pub metrics_token: String,
}

impl RunConfig {
    /// The repo-default quick configuration (tiny artifacts, fast).
    pub fn quick() -> Self {
        RunConfig {
            model: "wdl".into(),
            dataset: "criteo".into(),
            size: "tiny".into(),
            artifacts_dir: "artifacts".into(),
            algorithm: Algorithm::CeluVfl,
            r_local: 3,
            w_workset: 3,
            xi_degrees: 60.0,
            compress: CodecKind::Identity,
            parties: 2,
            party_compress: Vec::new(),
            lr: 0.05,
            seed: 42,
            trials: 1,
            max_rounds: 400,
            max_seconds: 0.0,
            target_auc: 0.0,
            eval_every: 25,
            eval_batches: 8,
            train_instances: 40_000,
            test_instances: 8_000,
            label_noise: 0.05,
            data: String::new(),
            data_format: DataFormat::Synthetic,
            chunk_rows: 4096,
            overlap: 1.0,
            ssl_ratio: 1,
            wan: WanProfile::instant(),
            compute_delay_s: 0.0,
            straggler_wait_ms: 0,
            checkpoint_dir: String::new(),
            checkpoint_every: 100,
            metrics_token: String::new(),
        }
    }

    /// A protocol-level probe session: `quick()` with the mesh shape
    /// pinned and the WAN emulation off, as used by lifecycle tests
    /// and the chaos campaign executor (`campaign::exec`). No model
    /// runs over these configs — only activation/derivative framing —
    /// so the training knobs keep their `quick()` values. A non-zero
    /// `straggler_wait_ms` opts the label's lane fan into supervised
    /// degradation (see `session::supervisor`).
    pub fn protocol_probe(parties: usize, seed: u64,
                          straggler_wait_ms: u64) -> Self {
        let mut cfg = RunConfig::quick();
        cfg.parties = parties;
        cfg.seed = seed;
        cfg.wan = WanProfile::instant();
        cfg.compress = CodecKind::Identity;
        cfg.straggler_wait_ms = straggler_wait_ms;
        cfg
    }

    /// Artifact set tag: `<model>_<dataset>_<size>`.
    pub fn artifact_tag(&self) -> String {
        format!("{}_{}_{}", self.model, self.dataset, self.size)
    }

    /// cos ξ — the weight threshold fed to the kernels. At ξ=180° every
    /// cosine passes the threshold, but weighting is *disabled* entirely
    /// (weights pinned to 1) to match the paper's "No Weights" baseline.
    pub fn cos_xi(&self) -> f64 {
        (self.xi_degrees.to_radians()).cos()
    }

    pub fn weighting_enabled(&self) -> bool {
        self.algorithm == Algorithm::CeluVfl && self.xi_degrees < 180.0
    }

    /// Sampling strategy implied by the algorithm.
    pub fn sampling(&self) -> Sampling {
        match self.algorithm {
            Algorithm::FedBcd => Sampling::Consecutive,
            _ => Sampling::RoundRobin,
        }
    }

    /// Effective workset capacity: FedBCD pins W=1 (the paper treats it
    /// as the degenerate case of the workset abstraction).
    pub fn effective_w(&self) -> usize {
        match self.algorithm {
            Algorithm::FedBcd => 1,
            Algorithm::Vanilla => 1,
            Algorithm::CeluVfl => self.w_workset,
        }
    }

    /// Local updates per cached batch; Vanilla does none.
    pub fn effective_r(&self) -> usize {
        match self.algorithm {
            Algorithm::Vanilla => 0,
            _ => self.r_local,
        }
    }

    /// Number of feature parties in the session (everyone but the
    /// label party).
    pub fn feature_parties(&self) -> usize {
        self.parties - 1
    }

    /// The codec requested on feature party `id`'s link: the
    /// `[party.<id>]` override when present, the session-wide
    /// `compress` otherwise. Negotiation can still downgrade it
    /// per-link at handshake time.
    pub fn codec_for(&self, id: u16) -> CodecKind {
        self.party_compress
            .iter()
            .find(|(p, _)| *p == id)
            .map(|(_, c)| *c)
            .unwrap_or(self.compress)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if !matches!(self.model.as_str(), "wdl" | "dssm") {
            anyhow::bail!("model must be wdl|dssm, got '{}'", self.model);
        }
        if !matches!(self.dataset.as_str(), "criteo" | "avazu" | "d3") {
            anyhow::bail!("dataset must be criteo|avazu|d3, got '{}'",
                          self.dataset);
        }
        if self.r_local == 0 && self.algorithm != Algorithm::Vanilla {
            anyhow::bail!("r_local must be ≥1 for local-update algorithms");
        }
        if self.w_workset == 0 {
            anyhow::bail!("w_workset must be ≥1");
        }
        if !(0.0..=180.0).contains(&self.xi_degrees) {
            anyhow::bail!("xi_degrees must be in [0, 180]");
        }
        if self.lr <= 0.0 {
            anyhow::bail!("lr must be positive");
        }
        if self.max_rounds == 0 {
            anyhow::bail!("max_rounds must be ≥1");
        }
        if self.train_instances == 0 || self.test_instances == 0 {
            anyhow::bail!("train/test instances must be ≥1");
        }
        if !(0.0..=0.5).contains(&self.label_noise) {
            anyhow::bail!("label_noise must be in [0, 0.5]");
        }
        let max = crate::session::MAX_PARTIES as usize;
        if !(2..=max).contains(&self.parties) {
            anyhow::bail!("parties must be in [2, {max}], got {}",
                          self.parties);
        }
        for (id, _) in &self.party_compress {
            if *id == 0 || *id as usize >= self.parties {
                anyhow::bail!(
                    "[party.{id}] override targets no feature party \
                     (valid ids: 1..={})", self.parties - 1
                );
            }
        }
        if self.checkpoint_every == 0 {
            anyhow::bail!("checkpoint_every must be ≥1");
        }
        if !(0.0..=1.0).contains(&self.overlap) || self.overlap == 0.0 {
            anyhow::bail!("overlap must be in (0, 1], got {}",
                          self.overlap);
        }
        if self.chunk_rows == 0 {
            anyhow::bail!("chunk_rows must be ≥1");
        }
        if self.data_format.is_streaming() {
            if self.data.is_empty() {
                anyhow::bail!(
                    "data_format {} streams from disk — pass --data <path>",
                    self.data_format.name()
                );
            }
            if !self.checkpoint_dir.is_empty() {
                anyhow::bail!(
                    "checkpointing replays the batch cursor from round 0, \
                     which streaming windows cannot do — drop \
                     --checkpoint-dir or use --data-format synthetic"
                );
            }
        } else if !self.data.is_empty() {
            anyhow::bail!(
                "--data is set but data_format is synthetic (which \
                 generates rows in memory) — pass --data-format csv \
                 or libsvm"
            );
        }
        if self.straggler_wait_ms > 3_600_000 {
            anyhow::bail!(
                "straggler_wait_ms must be ≤ 3600000 (one hour), got {}",
                self.straggler_wait_ms
            );
        }
        Ok(())
    }

    /// Load from a TOML file, starting from `quick()` defaults.
    pub fn from_toml_file(path: &str) -> anyhow::Result<Self> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        Self::from_toml(&src)
    }

    pub fn from_toml(src: &str) -> anyhow::Result<Self> {
        let doc = TomlDoc::parse(src)?;
        let base = RunConfig::quick();
        let cfg = RunConfig {
            model: doc.str_or("model", &base.model)?,
            dataset: doc.str_or("dataset", &base.dataset)?,
            size: doc.str_or("size", &base.size)?,
            artifacts_dir: doc.str_or("artifacts_dir", &base.artifacts_dir)?,
            algorithm: Algorithm::parse(&doc.str_or(
                "algorithm", base.algorithm.name())?)?,
            r_local: doc.usize_or("r_local", base.r_local)?,
            w_workset: doc.usize_or("w_workset", base.w_workset)?,
            xi_degrees: doc.f64_or("xi_degrees", base.xi_degrees)?,
            compress: CodecKind::parse(&doc.str_or(
                "compress", &base.compress.label())?)?,
            parties: doc.usize_or("parties", base.parties)?,
            party_compress: parse_party_overrides(&doc)?,
            lr: doc.f64_or("lr", base.lr)?,
            seed: doc.f64_or("seed", base.seed as f64)? as u64,
            trials: doc.usize_or("trials", base.trials)?,
            max_rounds: doc.usize_or("max_rounds", base.max_rounds)?,
            max_seconds: doc.f64_or("max_seconds", base.max_seconds)?,
            target_auc: doc.f64_or("target_auc", base.target_auc)?,
            eval_every: doc.usize_or("eval_every", base.eval_every)?,
            eval_batches: doc.usize_or("eval_batches", base.eval_batches)?,
            train_instances: doc.usize_or("train_instances",
                                          base.train_instances)?,
            test_instances: doc.usize_or("test_instances",
                                         base.test_instances)?,
            label_noise: doc.f64_or("label_noise", base.label_noise)?,
            data: doc.str_or("data", &base.data)?,
            data_format: DataFormat::parse(&doc.str_or(
                "data_format", base.data_format.name())?)?,
            chunk_rows: doc.usize_or("chunk_rows", base.chunk_rows)?,
            overlap: doc.f64_or("overlap", base.overlap)?,
            ssl_ratio: doc.usize_or("ssl_ratio", base.ssl_ratio)?,
            wan: WanProfile {
                bandwidth_mbps: doc.f64_or("wan.bandwidth_mbps",
                                           base.wan.bandwidth_mbps)?,
                rtt_ms: doc.f64_or("wan.rtt_ms", base.wan.rtt_ms)?,
                gateway_ms: doc.f64_or("wan.gateway_ms",
                                       base.wan.gateway_ms)?,
            },
            compute_delay_s: doc.f64_or("compute_delay_s",
                                        base.compute_delay_s)?,
            straggler_wait_ms: doc.usize_or(
                "straggler_wait_ms", base.straggler_wait_ms as usize)?
                as u64,
            checkpoint_dir: doc.str_or("checkpoint_dir",
                                       &base.checkpoint_dir)?,
            checkpoint_every: doc.usize_or("checkpoint_every",
                                           base.checkpoint_every)?,
            metrics_token: doc.str_or("metrics_token",
                                      &base.metrics_token)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Collect `[party.<id>]` section overrides. Currently the per-party
/// knob is `compress` (the per-link codec request); unknown keys under
/// a party section are rejected loudly so typos can't silently
/// no-op.
fn parse_party_overrides(doc: &TomlDoc)
                         -> anyhow::Result<Vec<(u16, CodecKind)>> {
    let mut out: Vec<(u16, CodecKind)> = Vec::new();
    for key in doc.keys() {
        let Some(rest) = key.strip_prefix("party.") else {
            continue;
        };
        let (id, field) = rest.split_once('.').ok_or_else(|| {
            anyhow::anyhow!("malformed party section key '{key}'")
        })?;
        let id: u16 = id.parse().map_err(|_| {
            anyhow::anyhow!("invalid party id in section '[party.{id}]'")
        })?;
        match field {
            "compress" => {
                let spec = doc.str_or(key, "")?;
                out.push((id, CodecKind::parse(&spec)?));
            }
            other => anyhow::bail!(
                "unknown key '{other}' in [party.{id}] — supported: \
                 compress"
            ),
        }
    }
    out.sort_by_key(|(id, _)| *id);
    // The TOML layer already rejects duplicate keys (two `[party.N]`
    // sections both setting `compress` collide on `party.N.compress`),
    // but guard here too so a future multi-key section can't make two
    // sections for one party silently coexist.
    for w in out.windows(2) {
        if w[0].0 == w[1].0 {
            anyhow::bail!("duplicate [party.{}] section", w[0].0);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_config_validates() {
        RunConfig::quick().validate().unwrap();
    }

    #[test]
    fn toml_overrides_defaults() {
        let cfg = RunConfig::from_toml(
            "model = \"dssm\"\nalgorithm = \"fedbcd\"\nr_local = 5\n\
             [wan]\nbandwidth_mbps = 300\nrtt_ms = 40\n",
        )
        .unwrap();
        assert_eq!(cfg.model, "dssm");
        assert_eq!(cfg.algorithm, Algorithm::FedBcd);
        assert_eq!(cfg.r_local, 5);
        assert_eq!(cfg.wan.bandwidth_mbps, 300.0);
        assert_eq!(cfg.wan.rtt_ms, 40.0);
        // untouched default
        assert_eq!(cfg.dataset, "criteo");
    }

    #[test]
    fn algorithm_semantics() {
        let mut cfg = RunConfig::quick();
        cfg.algorithm = Algorithm::Vanilla;
        assert_eq!(cfg.effective_r(), 0);
        assert_eq!(cfg.effective_w(), 1);
        cfg.algorithm = Algorithm::FedBcd;
        cfg.r_local = 5;
        assert_eq!(cfg.effective_r(), 5);
        assert_eq!(cfg.effective_w(), 1);
        assert_eq!(cfg.sampling(), Sampling::Consecutive);
        assert!(!cfg.weighting_enabled());
        cfg.algorithm = Algorithm::CeluVfl;
        cfg.w_workset = 5;
        assert_eq!(cfg.effective_w(), 5);
        assert_eq!(cfg.sampling(), Sampling::RoundRobin);
        assert!(cfg.weighting_enabled());
        cfg.xi_degrees = 180.0;
        assert!(!cfg.weighting_enabled());
    }

    #[test]
    fn cos_xi_values() {
        let mut cfg = RunConfig::quick();
        cfg.xi_degrees = 90.0;
        assert!(cfg.cos_xi().abs() < 1e-12);
        cfg.xi_degrees = 60.0;
        assert!((cfg.cos_xi() - 0.5).abs() < 1e-12);
        cfg.xi_degrees = 0.0;
        assert!((cfg.cos_xi() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn parse_errors_list_every_valid_value() {
        // A typo'd algorithm must be answered with the full menu, not a
        // bare failure (same contract as CodecKind::parse).
        let e = Algorithm::parse("celu_vfl").unwrap_err().to_string();
        for valid in ["vanilla", "fedbcd", "celu", "celu-vfl"] {
            assert!(e.contains(valid), "error must list '{valid}': {e}");
        }
        assert_eq!(Algorithm::parse("celu-vfl").unwrap(),
                   Algorithm::CeluVfl);
    }

    #[test]
    fn compress_config_parses_and_defaults() {
        assert_eq!(RunConfig::quick().compress, CodecKind::Identity);
        let cfg =
            RunConfig::from_toml("compress = \"topk:48\"\n").unwrap();
        assert_eq!(cfg.compress, CodecKind::TopK(48));
        let cfg = RunConfig::from_toml("compress = \"int8\"\n").unwrap();
        assert_eq!(cfg.compress, CodecKind::QuantInt8);
        let e = RunConfig::from_toml("compress = \"zstd\"\n").unwrap_err();
        assert!(e.to_string().contains("topk:<k>"), "{e}");
    }

    #[test]
    fn parties_config_parses_and_validates() {
        assert_eq!(RunConfig::quick().parties, 2);
        assert_eq!(RunConfig::quick().feature_parties(), 1);
        let cfg = RunConfig::from_toml("parties = 4\n").unwrap();
        assert_eq!(cfg.parties, 4);
        assert_eq!(cfg.feature_parties(), 3);
        // Bounds: a session needs a label party and ≥ 1 feature party,
        // and ids must fit the protocol's MAX_PARTIES range check.
        let mut cfg = RunConfig::quick();
        cfg.parties = 1;
        assert!(cfg.validate().is_err());
        cfg.parties = crate::session::MAX_PARTIES as usize + 1;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn per_party_toml_sections_override_the_codec() {
        let cfg = RunConfig::from_toml(
            "parties = 3\ncompress = \"fp16\"\n\
             [party.2]\ncompress = \"int8\"\n",
        )
        .unwrap();
        // Party 1 inherits the session codec; party 2 is overridden.
        assert_eq!(cfg.codec_for(1), CodecKind::Fp16);
        assert_eq!(cfg.codec_for(2), CodecKind::QuantInt8);
        assert_eq!(cfg.party_compress, vec![(2, CodecKind::QuantInt8)]);
        // Overrides targeting the label party or absent parties fail.
        let e = RunConfig::from_toml(
            "parties = 3\n[party.0]\ncompress = \"int8\"\n");
        assert!(e.is_err());
        let e = RunConfig::from_toml(
            "parties = 3\n[party.7]\ncompress = \"int8\"\n");
        assert!(e.is_err());
        // Typo'd per-party keys are loud, not silent.
        let e = RunConfig::from_toml(
            "parties = 3\n[party.2]\ncompres = \"int8\"\n");
        assert!(e.unwrap_err().to_string().contains("unknown key"));
    }

    #[test]
    fn party_section_failures_name_the_offending_key() {
        // Every way a [party.N] section can be wrong must fail loudly
        // *and* point at the section/key that caused it — a K-party
        // launch is K shells reading the same file, so a silent no-op
        // here desynchronizes a whole fleet.

        // Duplicate section: caught at the TOML layer as a duplicate
        // flattened key, named in full.
        let e = RunConfig::from_toml(
            "parties = 3\n[party.2]\ncompress = \"int8\"\n\
             [party.2]\ncompress = \"fp16\"\n",
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("party.2.compress"), "duplicate unnamed: {e}");

        // id ≥ parties (and the label party's id 0): named section.
        let e = RunConfig::from_toml(
            "parties = 3\n[party.7]\ncompress = \"int8\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[party.7]"), "bad id unnamed: {e}");
        assert!(e.contains("1..=2"), "valid range missing: {e}");
        let e = RunConfig::from_toml(
            "parties = 3\n[party.0]\ncompress = \"int8\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("[party.0]"), "label id unnamed: {e}");

        // Unknown key inside a party section: both the key and the
        // section are named, with the supported menu.
        let e = RunConfig::from_toml(
            "parties = 3\n[party.2]\ncompres = \"int8\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("'compres'") && e.contains("[party.2]"),
                "typo'd key unnamed: {e}");
        assert!(e.contains("compress"), "supported menu missing: {e}");

        // Non-numeric party id: named section.
        let e = RunConfig::from_toml(
            "parties = 3\n[party.one]\ncompress = \"int8\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("party.one"), "bad section unnamed: {e}");
    }

    #[test]
    fn lifecycle_config_parses_and_validates() {
        let base = RunConfig::quick();
        assert_eq!(base.straggler_wait_ms, 0);
        assert_eq!(base.checkpoint_dir, "");
        assert_eq!(base.checkpoint_every, 100);
        let cfg = RunConfig::from_toml(
            "straggler_wait_ms = 250\ncheckpoint_dir = \"ckpts\"\n\
             checkpoint_every = 10\n",
        )
        .unwrap();
        assert_eq!(cfg.straggler_wait_ms, 250);
        assert_eq!(cfg.checkpoint_dir, "ckpts");
        assert_eq!(cfg.checkpoint_every, 10);
        let mut cfg = RunConfig::quick();
        cfg.checkpoint_every = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = RunConfig::quick();
        cfg.straggler_wait_ms = 3_600_001;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn protocol_probe_is_a_valid_supervised_shape() {
        let cfg = RunConfig::protocol_probe(3, 7, 250);
        cfg.validate().unwrap();
        assert_eq!((cfg.parties, cfg.seed, cfg.straggler_wait_ms),
                   (3, 7, 250));
        assert_eq!(cfg.compress, CodecKind::Identity);
        assert_eq!(cfg.wan.rtt_ms, 0.0);
    }

    #[test]
    fn data_plane_config_parses_and_validates() {
        let base = RunConfig::quick();
        assert_eq!(base.data_format, DataFormat::Synthetic);
        assert_eq!(base.data, "");
        assert_eq!(base.overlap, 1.0);
        assert_eq!(base.chunk_rows, 4096);
        let cfg = RunConfig::from_toml(
            "data = \"rows.csv\"\ndata_format = \"csv\"\n\
             chunk_rows = 512\noverlap = 0.3\nssl_ratio = 2\n",
        )
        .unwrap();
        assert_eq!(cfg.data, "rows.csv");
        assert_eq!(cfg.data_format, DataFormat::Csv);
        assert_eq!(cfg.chunk_rows, 512);
        assert_eq!(cfg.overlap, 0.3);
        assert_eq!(cfg.ssl_ratio, 2);
        // The format menu follows the CLI parse-error convention.
        let e = DataFormat::parse("parquet").unwrap_err().to_string();
        for valid in ["csv", "libsvm", "synthetic"] {
            assert!(e.contains(valid), "error must list '{valid}': {e}");
        }
        // Streaming needs a path; synthetic must not get one.
        let e = RunConfig::from_toml("data_format = \"csv\"\n")
            .unwrap_err().to_string();
        assert!(e.contains("--data"), "{e}");
        let e = RunConfig::from_toml("data = \"rows.csv\"\n")
            .unwrap_err().to_string();
        assert!(e.contains("synthetic"), "{e}");
        // Streaming is incompatible with checkpoint replay.
        let e = RunConfig::from_toml(
            "data = \"r.csv\"\ndata_format = \"libsvm\"\n\
             checkpoint_dir = \"ckpts\"\n",
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("checkpoint"), "{e}");
        // Overlap bounds are (0, 1].
        for bad in ["overlap = 0.0\n", "overlap = 1.5\n",
                    "overlap = -0.2\n"] {
            assert!(RunConfig::from_toml(bad).is_err(), "{bad}");
        }
        assert!(RunConfig::from_toml("chunk_rows = 0\n").is_err());
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut cfg = RunConfig::quick();
        cfg.model = "bert".into();
        assert!(cfg.validate().is_err());
        let mut cfg = RunConfig::quick();
        cfg.w_workset = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = RunConfig::quick();
        cfg.xi_degrees = 181.0;
        assert!(cfg.validate().is_err());
        let mut cfg = RunConfig::quick();
        cfg.lr = 0.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn wan_delay_model() {
        let wan = WanProfile { bandwidth_mbps: 300.0, rtt_ms: 20.0,
                               gateway_ms: 2.0 };
        // 4 MiB message at 300 Mbps ≈ 112 ms transfer + 12 ms fixed.
        let d = wan.one_way_delay(4 << 20);
        assert!((d.as_secs_f64() - (4.194304 * 8.0 / 300.0 + 0.012)).abs()
                < 2e-3, "d={d:?}");
        // paper's §2.1 example: 4 MB message, two transmissions ≈ 213 ms
        // at 300 Mbps (ignoring latency).
        let wan_bw = WanProfile { bandwidth_mbps: 300.0, rtt_ms: 0.0,
                                  gateway_ms: 0.0 };
        let two = wan_bw.one_way_delay(4_000_000).as_secs_f64() * 2.0;
        assert!((two - 0.2133).abs() < 2e-3, "two={two}");
        assert_eq!(WanProfile::instant().one_way_delay(1 << 20),
                   std::time::Duration::ZERO);
    }
}
