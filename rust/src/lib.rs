//! # CELU-VFL
//!
//! Reproduction of *"Towards Communication-efficient Vertical Federated
//! Learning Training via Cache-enabled Local Updates"* (Fu et al., PVLDB
//! 15(10), 2022) as a three-layer Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the VFL coordinator: a K-party session API
//!   (`session`: role-based parties over a per-peer transport mesh,
//!   DESIGN.md §6) with a listener-based bootstrap
//!   (`session::bootstrap`: the label party is a session server
//!   accepting `Join`-identified connections, feature parties dial in
//!   with backoff — DESIGN.md §7, so the mesh launches as K OS
//!   processes) and a supervised lifecycle (`session::supervisor`:
//!   validated state machine with typed lifecycle events, bounded
//!   straggler lanes stepping on cached stale statistics, `Rejoin`
//!   reconnect through a live re-admission point, and label-party
//!   checkpoint/restart via `session::checkpoint` — DESIGN.md §8),
//!   a chaos campaign subsystem (`campaign`: seeded fault-plan sweeps
//!   over real sessions — multi-fault overlaps, reorders, fault ×
//!   codec cross-products, kills during rejoin, faults beside a
//!   multiplexed neighbor — judged by round-parity / clean-link
//!   byte-identity / no-hang oracles, with delta-debug shrinking of
//!   failing seeds to minimal `FaultPlan` reproducers — DESIGN.md
//!   §13),
//!   a live observability plane (`metrics`: a lock-free recorder
//!   facade every transport bumps through pre-registered handles,
//!   observed by a Prometheus-text scrape and a tag-14 push stream
//!   served straight off the session port, plus the terminal
//!   `RunRecord` snapshot — DESIGN.md §10),
//!   running the paper's protocol with negotiated wire
//!   compression for the exchanged statistics (`compress`: fp16 / int8
//!   / top-k codecs, DESIGN.md §5), simulated-WAN / TCP transports with
//!   per-link raw-vs-wire byte accounting, per-peer workset lanes with
//!   round-robin local sampling, comm/local worker overlap, metrics and
//!   the experiment harnesses. The two-party entry points
//!   (`coordinator::run_party_a` / `run_party_b`, `--parties 2`) are
//!   thin wrappers over the session API and keep the historic wire
//!   format byte-for-byte. The data plane (`dataset`, DESIGN.md §12)
//!   streams CSV/libsvm tables in constant-memory chunks and splits
//!   partially-overlapping populations into the aligned rows the CELU
//!   cache path trains on and unaligned rows feature parties use for
//!   zero-traffic self-supervised updates.
//! - **L2 (python/compile)** — JAX step functions (WDL/DSSM bottoms +
//!   tops, AdaGrad), AOT-lowered once to HLO-text artifacts.
//! - **L1 (python/compile/kernels)** — Pallas kernels for the
//!   per-instance hot spots (InsWeight cosine, weighted backward).
//!
//! Python never runs on the training path: the coordinator loads the
//! artifacts through PJRT (`runtime`) and drives everything from Rust.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod campaign;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dataset;
pub mod experiments;
pub mod metrics;
pub mod protocol;
pub mod runtime;
pub mod session;
pub mod tensor;
pub mod testing;
pub mod transport;
pub mod util;
pub mod workset;
