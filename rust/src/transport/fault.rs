//! Deterministic fault injection for chaos testing (DESIGN.md §9).
//!
//! Crash-recovery code is exactly the code that never runs in a clean
//! test suite. The OS-process route (kill a real process, as the
//! `chaos_k3` example does) proves the end-to-end story but is
//! scheduler roulette: which round the victim dies in depends on
//! timing. [`FaultTransport`] makes the *failure itself* deterministic:
//! it wraps any [`Transport`] and injects failures at points fixed by a
//! seeded [`FaultPlan`], so "P1 dies at round 4" is a reproducible unit
//! test, and the supervisor's peer-lost / straggler / rejoin machinery
//! can be exercised against every injection point.
//!
//! Injection points (all decided from the plan, never from wall-clock
//! randomness):
//!
//! - **kill-at-round-N** — the first `send` carrying round ≥ N fails,
//!   and every later `send`/`recv`/`try_recv` fails too (a dead process
//!   does no I/O). [`FaultPlan::kill_within`] derives N from the plan
//!   seed for randomized-but-reproducible placement.
//! - **drop-next-frame** — the nth outbound `send` call (0-based)
//!   returns `Ok` but the frame never reaches the peer: a lost packet
//!   the sender doesn't notice. The inner transport's accounting never
//!   sees the frame.
//! - **delay-ms** — the nth outbound `send` call sleeps before
//!   forwarding: a straggler, not a failure.
//! - **duplicate-frame** — the nth outbound `send` call puts the frame
//!   on the wire twice, back to back: a retransmit-after-spurious-
//!   timeout, the failure mode that punishes receivers assuming
//!   exactly-once delivery. Both copies are charged (both crossed the
//!   wire), so accounting assertions see the duplicate too.
//! - **corrupt-frame** — the nth outbound `send` call is encoded to its
//!   wire bytes, one seeded bit is flipped, and the mangled buffer is
//!   pushed back through the frame decoder — bit-rot on the wire. If
//!   the hostile-input discipline rejects the buffer (the overwhelming
//!   case: tag, length and shape checks), the frame dies there, exactly
//!   as a real receiver would refuse it; if the flip survives decoding,
//!   the garbled-but-well-formed frame is delivered and the receiver's
//!   protocol checks deal with it.
//! - **one-way partition** — outbound frames whose round falls in
//!   `[from, to)` are silently discarded while the inbound direction
//!   keeps working: the asymmetric link failure that distinguishes a
//!   straggling peer from a dead one.
//! - **bidirectional partition** — the same round window applied to
//!   *both* directions: outbound frames in the window are discarded as
//!   above, and inbound frames in the window are filtered out of
//!   `recv`/`try_recv` before the caller sees them (the peer charged
//!   its send — the loss is on this side of the wire, exactly like a
//!   middlebox eating traffic both ways).
//! - **reorder-frames** — the nth outbound `send` call is held back and
//!   delivered right *after* the next forwarded/dropped/corrupted send
//!   (nth and nth+1 swap on the wire): the out-of-order delivery a
//!   multi-path route or a retransmission produces. The held frame is
//!   charged when it actually crosses, so accounting reflects delivery
//!   order. If no later send ever happens, the held frame is lost —
//!   deterministically, like a drop (an in-flight frame on a route that
//!   never carries traffic again).
//!
//! # Composition grammar
//!
//! A plan may schedule any number of injections, including several on
//! the same frame index or round. Application order is deterministic;
//! per outbound send call, exactly one *terminal* action is chosen by
//! this precedence:
//!
//! 1. **sticky kill** — a dead endpoint does nothing else, ever;
//! 2. **kill-at-round** — `msg.round() >= kill_at` kills now;
//! 3. **drop-next-frame** — `nth ∈ drops`;
//! 4. **corrupt-frame** — `nth ∈ corrupts`;
//! 5. **partition window** — `msg.round() ∈ [from, to)`;
//! 6. **reorder-frames** — `nth ∈ reorders`: hold the frame;
//! 7. **forward** — the default.
//!
//! The *modifiers* `delay_ms` and `duplicate_frame` compose with a
//! forwarded frame (a delayed duplicate sleeps once, then sends twice)
//! and are inert when a higher-precedence terminal action consumed the
//! frame. Frames held by a reorder are flushed FIFO immediately after
//! the next send call's own action completes (so consecutive holds
//! accumulate and drain together), except after a kill — a dead
//! endpoint delivers nothing. `kill_at_round` composes with every
//! frame-indexed injection: indices that fire before the kill round
//! behave normally, later ones never happen.
//!
//! The wrapper forwards [`stats`](Transport::stats) to the inner
//! transport untouched, so dropped and partitioned frames are never
//! charged — surviving-link byte parity against an undisturbed
//! reference run stays assertable to the byte. Every *applied*
//! injection (a kill transition, each dropped/corrupted/held/delayed/
//! duplicated/partition-discarded/inbound-filtered frame) bumps the
//! wrapped link's `faults_injected` cell (see
//! [`crate::metrics::facade::LinkHandles`]), so chaos runs are visible
//! on `/metrics` and in `RunRecord` without touching byte parity.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::metrics::facade::Counter;
use crate::protocol::Message;
use crate::util::rng::Pcg;

use super::{LinkStats, Transport};

/// Pcg stream used to derive a kill round from the plan seed (see
/// [`FaultPlan::kill_within`]); disjoint from every other stream
/// constant in the crate so fault placement never correlates with
/// batch order or session epochs.
const KILL_STREAM: u64 = 0xFA17;

/// Pcg stream for choosing which bit of an encoded frame a
/// corrupt-frame injection flips.
const CORRUPT_STREAM: u64 = 0xB17_F11B;

/// A seeded, declarative schedule of transport failures. Build one
/// with the chained setters, wrap a transport with
/// [`FaultTransport::new`], and the same plan reproduces the same
/// failure sequence on every run.
///
/// Frame indices (`nth`) count outbound `send` *calls* on the wrapped
/// endpoint, 0-based, including calls that end up dropped, delayed or
/// killed — the index is a property of the caller's send sequence, not
/// of what reached the wire.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    seed: u64,
    kill_at: Option<u64>,
    drops: Vec<u64>,
    delays: Vec<(u64, Duration)>,
    duplicates: Vec<u64>,
    corrupts: Vec<u64>,
    reorders: Vec<u64>,
    partition: Option<(u64, u64)>,
    partition_both_ways: bool,
}

impl FaultPlan {
    /// An empty plan (no injections) carrying `seed` for derived
    /// placements.
    pub fn new(seed: u64) -> Self {
        FaultPlan {
            seed,
            kill_at: None,
            drops: Vec::new(),
            delays: Vec::new(),
            duplicates: Vec::new(),
            corrupts: Vec::new(),
            reorders: Vec::new(),
            partition: None,
            partition_both_ways: false,
        }
    }

    /// Kill the endpoint at round `round`: the first `send` carrying
    /// that round (or later) fails, and the endpoint is dead — sticky —
    /// from then on.
    pub fn kill_at_round(mut self, round: u64) -> Self {
        self.kill_at = Some(round);
        self
    }

    /// Like [`kill_at_round`](Self::kill_at_round), with the round
    /// drawn deterministically from the plan seed in `[lo, hi)` — the
    /// "seeded chaos" mode: vary the seed to sweep kill placements,
    /// keep it to reproduce one.
    pub fn kill_within(self, lo: u64, hi: u64) -> Self {
        let span = hi.saturating_sub(lo).max(1);
        let round = lo + Pcg::new(self.seed, KILL_STREAM).next_u64() % span;
        self.kill_at_round(round)
    }

    /// Swallow the `nth` outbound send call: `Ok` to the caller, no
    /// frame to the peer.
    pub fn drop_frame(mut self, nth: u64) -> Self {
        self.drops.push(nth);
        self
    }

    /// Sleep `ms` milliseconds before forwarding the `nth` outbound
    /// send call (a straggler, not a loss).
    pub fn delay_ms(mut self, nth: u64, ms: u64) -> Self {
        self.delays.push((nth, Duration::from_millis(ms)));
        self
    }

    /// Put the `nth` outbound send call on the wire twice, back to
    /// back: a spurious retransmit. Both copies are forwarded (and
    /// charged) — the receiver must tolerate the duplicate.
    pub fn duplicate_frame(mut self, nth: u64) -> Self {
        self.duplicates.push(nth);
        self
    }

    /// Flip one seeded bit of the `nth` outbound frame's encoded bytes
    /// (bit-rot on the wire). The mangled buffer goes back through the
    /// frame decoder: a rejected buffer dies silently (the receiver
    /// refused it), a surviving one is delivered garbled.
    pub fn corrupt_frame(mut self, nth: u64) -> Self {
        self.corrupts.push(nth);
        self
    }

    /// Hold the `nth` outbound send call back and deliver it right
    /// after the next one: nth and nth+1 swap on the wire (out-of-order
    /// delivery). If no later send happens the held frame is lost,
    /// deterministically, like a drop. See the module's composition
    /// grammar for how holds interact with other injections.
    pub fn reorder_frames(mut self, nth: u64) -> Self {
        self.reorders.push(nth);
        self
    }

    /// One-way partition: outbound frames whose round is in
    /// `[from, to)` are silently discarded; inbound traffic is
    /// unaffected.
    pub fn partition_rounds(mut self, from: u64, to: u64) -> Self {
        self.partition = Some((from, to));
        self.partition_both_ways = false;
        self
    }

    /// Bidirectional partition: the `[from, to)` round window of
    /// [`partition_rounds`](Self::partition_rounds) applied to both
    /// directions — outbound frames in the window are discarded, and
    /// inbound frames in the window are filtered before `recv`/
    /// `try_recv` return.
    pub fn partition_rounds_bidirectional(mut self, from: u64, to: u64)
                                          -> Self {
        self.partition = Some((from, to));
        self.partition_both_ways = true;
        self
    }

    /// The round this plan kills at, if any (resolved — `kill_within`
    /// has already been drawn).
    pub fn kill_round(&self) -> Option<u64> {
        self.kill_at
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }
}

/// What the wrapper decided to do with one outbound frame.
enum SendAction {
    Forward { delay: Option<Duration>, duplicate: bool },
    Corrupt { nth: u64 },
    Drop,
    Hold,
    Kill(u64),
}

#[derive(Default)]
struct FaultState {
    /// Outbound send calls observed so far (the `nth` counter).
    sent: u64,
    killed: bool,
    /// Frames held back by reorder injections, flushed FIFO after the
    /// next send call's own action completes.
    held: Vec<Message>,
}

/// A [`Transport`] wrapper that injects the failures scheduled by a
/// [`FaultPlan`]. See the module docs for the injection semantics.
pub struct FaultTransport {
    inner: Arc<dyn Transport>,
    plan: FaultPlan,
    state: Mutex<FaultState>,
    /// Applied-injection counter. Shares the wrapped link's
    /// [`LinkHandles`](crate::metrics::facade::LinkHandles) cell when
    /// the inner transport exposes one, so a bound registry renders
    /// the count as `celu_link_faults_injected_total`; detached (still
    /// readable via [`Self::injected`]) otherwise.
    faults: Counter,
}

impl FaultTransport {
    pub fn new(inner: Arc<dyn Transport>, plan: FaultPlan) -> Self {
        let faults = inner
            .metrics()
            .map(|h| h.faults_injected.clone())
            .unwrap_or_default();
        FaultTransport {
            inner,
            plan,
            state: Mutex::new(FaultState::default()),
            faults,
        }
    }

    /// Injections applied so far (kill transition, dropped / corrupted
    /// / held / delayed / duplicated / partition-discarded / inbound-
    /// filtered frames — one bump each).
    pub fn injected(&self) -> u64 {
        self.faults.get()
    }

    /// The plan this wrapper executes.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Sticky-death check shared by the receive paths.
    fn ensure_alive(&self) -> anyhow::Result<()> {
        if self.state.lock().unwrap().killed {
            anyhow::bail!(
                "injected fault: endpoint killed (plan seed {:#x})",
                self.plan.seed
            );
        }
        Ok(())
    }

    /// Decide one send's fate under the state lock; the action itself
    /// (sleeping, forwarding) runs outside it.
    fn classify(&self, msg: &Message) -> SendAction {
        let mut st = self.state.lock().unwrap();
        let nth = st.sent;
        st.sent += 1;
        if st.killed {
            return SendAction::Kill(self.plan.kill_at.unwrap_or(0));
        }
        if let Some(k) = self.plan.kill_at {
            if msg.round() >= k {
                st.killed = true;
                self.faults.inc();
                return SendAction::Kill(k);
            }
        }
        if self.plan.drops.contains(&nth) {
            self.faults.inc();
            return SendAction::Drop;
        }
        if self.plan.corrupts.contains(&nth) {
            self.faults.inc();
            return SendAction::Corrupt { nth };
        }
        if let Some((from, to)) = self.plan.partition {
            let r = msg.round();
            if r >= from && r < to {
                self.faults.inc();
                return SendAction::Drop;
            }
        }
        if self.plan.reorders.contains(&nth) {
            self.faults.inc();
            return SendAction::Hold;
        }
        let delay = self
            .plan
            .delays
            .iter()
            .find(|(n, _)| *n == nth)
            .map(|(_, d)| *d);
        let duplicate = self.plan.duplicates.contains(&nth);
        if delay.is_some() {
            self.faults.inc();
        }
        if duplicate {
            self.faults.inc();
        }
        SendAction::Forward { delay, duplicate }
    }

    /// Deliver every held (reordered) frame, FIFO. Runs after the
    /// current send call's own action, so the held frame lands right
    /// behind its successor — the swap the injection promises.
    fn flush_held(&self) -> anyhow::Result<()> {
        let held = std::mem::take(&mut self.state.lock().unwrap().held);
        for m in held {
            self.inner.send(m)?;
        }
        Ok(())
    }

    /// Whether an inbound frame is eaten by a bidirectional partition.
    fn inbound_partitioned(&self, msg: &Message) -> bool {
        match self.plan.partition {
            Some((from, to)) if self.plan.partition_both_ways => {
                let r = msg.round();
                if r >= from && r < to {
                    self.faults.inc();
                    return true;
                }
                false
            }
            _ => false,
        }
    }
}

impl Transport for FaultTransport {
    fn send(&self, msg: Message) -> anyhow::Result<()> {
        let result = match self.classify(&msg) {
            SendAction::Forward { delay, duplicate } => {
                if let Some(d) = delay {
                    std::thread::sleep(d);
                }
                if duplicate {
                    self.inner.send(msg.clone())?;
                }
                self.inner.send(msg)
            }
            SendAction::Corrupt { nth } => {
                // Post-encode bit flip: the injection operates on the
                // actual wire representation, so whether the damage is
                // survivable is decided by the same decoder discipline
                // a TCP receiver applies — not by this wrapper.
                let mut bytes = crate::protocol::encode_frame(None, &msg);
                let mut rng = Pcg::new(
                    self.plan.seed.wrapping_add(nth), CORRUPT_STREAM);
                let pos = rng.gen_range(bytes.len() as u32) as usize;
                bytes[pos] ^= 1u8 << rng.gen_range(8);
                match crate::protocol::decode_frame(&bytes) {
                    // The flip survived the tag/length/shape checks:
                    // deliver the garbled frame for the receiver's
                    // protocol checks to judge.
                    Ok((_, garbled)) => self.inner.send(garbled),
                    // The receiver's hostile-input discipline refused
                    // the buffer — the frame dies on the wire, uncharged
                    // (like a drop, the sender never learns).
                    Err(_) => Ok(()),
                }
            }
            SendAction::Drop => Ok(()),
            SendAction::Hold => {
                self.state.lock().unwrap().held.push(msg);
                return Ok(()); // flushes on the *next* send call
            }
            SendAction::Kill(round) => anyhow::bail!(
                "injected fault: killed at round {round} (plan seed \
                 {:#x})",
                self.plan.seed
            ),
        };
        result?;
        self.flush_held()
    }

    fn recv(&self) -> anyhow::Result<Message> {
        loop {
            self.ensure_alive()?;
            let msg = self.inner.recv()?;
            if !self.inbound_partitioned(&msg) {
                return Ok(msg);
            }
        }
    }

    fn try_recv(&self) -> anyhow::Result<Option<Message>> {
        loop {
            self.ensure_alive()?;
            match self.inner.try_recv()? {
                Some(msg) if self.inbound_partitioned(&msg) => continue,
                other => return Ok(other),
            }
        }
    }

    fn stats(&self) -> LinkStats {
        self.inner.stats()
    }

    fn metrics(&self) -> Option<crate::metrics::facade::LinkHandles> {
        // Same delegation as stats(): dropped/partitioned frames are
        // never charged, so a bound registry sees exactly what the
        // inner transport put on the wire.
        self.inner.metrics()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WanProfile;
    use crate::tensor::Tensor;
    use crate::transport::inproc_pair;
    use std::time::Instant;

    fn act(round: u64) -> Message {
        Message::Activation { round, tensor: Tensor::zeros_f32(vec![4]) }
    }

    fn wrapped(plan: FaultPlan) -> (FaultTransport, impl Transport) {
        let (a, b) = inproc_pair(WanProfile::instant());
        (FaultTransport::new(Arc::new(a), plan), b)
    }

    #[test]
    fn kill_at_round_is_sticky_across_all_io() {
        let (f, peer) = wrapped(FaultPlan::new(1).kill_at_round(2));
        f.send(act(0)).unwrap();
        f.send(act(1)).unwrap();
        let e = f.send(act(2)).unwrap_err().to_string();
        assert!(e.contains("injected fault") && e.contains("round 2"),
                "{e}");
        // Dead is dead: every path fails, including frames whose round
        // predates the kill and both receive directions.
        assert!(f.send(act(0)).is_err());
        peer.send(act(9)).unwrap();
        assert!(f.recv().is_err());
        assert!(f.try_recv().is_err());
        // The peer got exactly the two pre-kill frames.
        assert_eq!(peer.recv().unwrap().round(), 0);
        assert_eq!(peer.recv().unwrap().round(), 1);
    }

    #[test]
    fn kill_within_is_seed_deterministic_and_in_range() {
        for seed in [0u64, 7, 0xdead_beef] {
            let a = FaultPlan::new(seed).kill_within(3, 9);
            let b = FaultPlan::new(seed).kill_within(3, 9);
            assert_eq!(a.kill_round(), b.kill_round());
            let r = a.kill_round().unwrap();
            assert!((3..9).contains(&r), "seed {seed}: round {r}");
        }
        // Degenerate range resolves to its lower bound, not a panic.
        assert_eq!(FaultPlan::new(5).kill_within(4, 4).kill_round(),
                   Some(4));
    }

    #[test]
    fn drop_frame_swallows_exactly_the_nth_send() {
        let (f, peer) = wrapped(FaultPlan::new(2).drop_frame(1));
        for r in 0..3 {
            f.send(act(r)).unwrap(); // all Ok — the loss is silent
        }
        assert_eq!(peer.recv().unwrap().round(), 0);
        assert_eq!(peer.recv().unwrap().round(), 2);
        // The inner accounting never saw the dropped frame.
        assert_eq!(f.stats().messages, 2);
    }

    #[test]
    fn delay_ms_holds_the_nth_send() {
        let (f, peer) = wrapped(FaultPlan::new(3).delay_ms(1, 150));
        let start = Instant::now();
        f.send(act(0)).unwrap();
        assert!(start.elapsed() < Duration::from_millis(100));
        let start = Instant::now();
        f.send(act(1)).unwrap();
        assert!(start.elapsed() >= Duration::from_millis(150));
        assert_eq!(peer.recv().unwrap().round(), 0);
        assert_eq!(peer.recv().unwrap().round(), 1);
    }

    #[test]
    fn one_way_partition_discards_outbound_rounds_only() {
        let (f, peer) = wrapped(FaultPlan::new(4).partition_rounds(2, 4));
        for r in 0..5 {
            f.send(act(r)).unwrap();
        }
        // Rounds 2 and 3 vanished; 0, 1 and 4 crossed.
        assert_eq!(peer.recv().unwrap().round(), 0);
        assert_eq!(peer.recv().unwrap().round(), 1);
        assert_eq!(peer.recv().unwrap().round(), 4);
        assert_eq!(f.stats().messages, 3);
        // Inbound keeps flowing: the partition is one-way.
        peer.send(act(2)).unwrap();
        assert_eq!(f.recv().unwrap().round(), 2);
    }

    #[test]
    fn duplicate_frame_doubles_exactly_the_nth_send() {
        let (f, peer) = wrapped(FaultPlan::new(6).duplicate_frame(1));
        for r in 0..3 {
            f.send(act(r)).unwrap();
        }
        // The nth=1 frame (round 1) arrives twice, back to back.
        assert_eq!(peer.recv().unwrap().round(), 0);
        assert_eq!(peer.recv().unwrap().round(), 1);
        assert_eq!(peer.recv().unwrap().round(), 1);
        assert_eq!(peer.recv().unwrap().round(), 2);
        // Both copies crossed the wire, so both are charged.
        assert_eq!(f.stats().messages, 4);
    }

    #[test]
    fn duplicate_composes_with_delay_on_the_same_nth() {
        let (f, peer) =
            wrapped(FaultPlan::new(7).duplicate_frame(0).delay_ms(0, 120));
        let start = Instant::now();
        f.send(act(5)).unwrap();
        assert!(start.elapsed() >= Duration::from_millis(120));
        assert_eq!(peer.recv().unwrap().round(), 5);
        assert_eq!(peer.recv().unwrap().round(), 5);
    }

    #[test]
    fn bidirectional_partition_eats_both_directions() {
        let (f, peer) =
            wrapped(FaultPlan::new(8).partition_rounds_bidirectional(2, 4));
        // Outbound: rounds 2 and 3 vanish, exactly like the one-way
        // case.
        for r in 0..5 {
            f.send(act(r)).unwrap();
        }
        assert_eq!(f.stats().messages, 3);
        assert_eq!(peer.recv().unwrap().round(), 0);
        assert_eq!(peer.recv().unwrap().round(), 1);
        assert_eq!(peer.recv().unwrap().round(), 4);
        // Inbound: in-window frames are filtered before recv returns;
        // the first out-of-window frame comes through.
        peer.send(act(2)).unwrap();
        peer.send(act(3)).unwrap();
        peer.send(act(7)).unwrap();
        assert_eq!(f.recv().unwrap().round(), 7);
        // try_recv filters too: an in-window frame alone in the queue
        // reads as "nothing pending".
        peer.send(act(2)).unwrap();
        assert!(f.try_recv().unwrap().is_none());
        peer.send(act(9)).unwrap();
        assert_eq!(f.try_recv().unwrap().unwrap().round(), 9);
    }

    #[test]
    fn one_way_partition_still_lets_inbound_window_rounds_through() {
        // Regression guard on the historic semantics: without the
        // bidirectional flag, inbound frames inside the window pass.
        let (f, peer) = wrapped(FaultPlan::new(10).partition_rounds(2, 4));
        peer.send(act(2)).unwrap();
        assert_eq!(f.recv().unwrap().round(), 2);
        peer.send(act(3)).unwrap();
        assert_eq!(f.try_recv().unwrap().unwrap().round(), 3);
    }

    #[test]
    fn corrupt_frame_mangles_exactly_the_nth_send_without_panicking() {
        // Sweep seeds so the flipped bit lands all over the frame —
        // tag byte, length words, payload. Whatever it hits, the send
        // path must stay Ok: the damage is the receiver's problem, and
        // the receiver's answer is reject-or-tolerate, never panic.
        for seed in 0..32u64 {
            let (f, peer) = wrapped(FaultPlan::new(seed).corrupt_frame(1));
            for r in 0..3 {
                f.send(act(r)).unwrap();
            }
            let mut rounds = Vec::new();
            while let Some(m) = peer.try_recv().unwrap() {
                rounds.push(m.round());
            }
            // Frames 0 and 2 always arrive intact. The corrupted frame
            // either died at the decoder or arrived garbled (possibly
            // with a different round — the flip may have hit the round
            // field itself).
            assert!(rounds.len() == 2 || rounds.len() == 3,
                    "seed {seed}: rounds {rounds:?}");
            assert_eq!(rounds[0], 0, "seed {seed}");
            assert_eq!(*rounds.last().unwrap(), 2, "seed {seed}");
        }
    }

    #[test]
    fn every_single_bit_flip_is_rejected_or_decoded_never_panics() {
        // The hostile-input discipline behind corrupt-frame: exhaustive
        // single-bit damage over a real frame must never panic the
        // decoder (v1 body and v2 party-addressed header alike).
        use crate::protocol::{decode_frame, encode_frame, FrameHeader};
        use crate::session::{LABEL_PARTY, PartyId};
        let headers = [
            None,
            Some(FrameHeader { src: PartyId(2), dst: LABEL_PARTY }),
        ];
        for header in headers {
            let clean = encode_frame(header, &act(3));
            let mut survived = 0u32;
            for pos in 0..clean.len() {
                for bit in 0..8 {
                    let mut bytes = clean.clone();
                    bytes[pos] ^= 1u8 << bit;
                    if decode_frame(&bytes).is_ok() {
                        survived += 1;
                    }
                }
            }
            // Some flips necessarily survive (payload bits carry no
            // redundancy), but the structural checks must catch a
            // non-trivial share — an all-survive decoder has no
            // discipline at all.
            let total = (clean.len() * 8) as u32;
            assert!(survived < total,
                    "every one of {total} bit flips decoded cleanly");
        }
    }

    #[test]
    fn an_empty_plan_is_transparent() {
        let (f, peer) = wrapped(FaultPlan::new(9));
        f.send(act(0)).unwrap();
        assert_eq!(peer.recv().unwrap().round(), 0);
        peer.send(act(1)).unwrap();
        assert_eq!(f.try_recv().unwrap().unwrap().round(), 1);
        assert_eq!(f.stats().messages, 1);
        assert_eq!(f.injected(), 0, "clean run counted an injection");
        assert_eq!(FaultPlan::new(9).kill_round(), None);
    }

    #[test]
    fn reorder_frames_swaps_nth_and_next_on_the_wire() {
        let (f, peer) = wrapped(FaultPlan::new(12).reorder_frames(1));
        for r in 0..4 {
            f.send(act(r)).unwrap();
        }
        // Frame 1 was held and delivered right after frame 2.
        for expect in [0, 2, 1, 3] {
            assert_eq!(peer.recv().unwrap().round(), expect);
        }
        // All four frames crossed eventually — charged in delivery
        // order, total count intact.
        assert_eq!(f.stats().messages, 4);
        assert_eq!(f.injected(), 1);
    }

    #[test]
    fn reorder_at_the_tail_loses_the_held_frame_deterministically() {
        let (f, peer) = wrapped(FaultPlan::new(13).reorder_frames(2));
        for r in 0..3 {
            f.send(act(r)).unwrap();
        }
        assert_eq!(peer.recv().unwrap().round(), 0);
        assert_eq!(peer.recv().unwrap().round(), 1);
        // No later send ever flushed the hold: the frame is gone and
        // was never charged, exactly like a drop.
        assert!(peer.try_recv().unwrap().is_none());
        assert_eq!(f.stats().messages, 2);
    }

    #[test]
    fn consecutive_reorders_accumulate_and_flush_fifo() {
        let (f, peer) = wrapped(
            FaultPlan::new(14).reorder_frames(0).reorder_frames(1));
        for r in 0..3 {
            f.send(act(r)).unwrap();
        }
        for expect in [2, 0, 1] {
            assert_eq!(peer.recv().unwrap().round(), expect);
        }
        assert_eq!(f.stats().messages, 3);
    }

    #[test]
    fn reorder_flushes_even_when_the_next_send_is_dropped() {
        let (f, peer) = wrapped(
            FaultPlan::new(15).reorder_frames(0).drop_frame(1));
        f.send(act(0)).unwrap(); // held
        f.send(act(1)).unwrap(); // dropped — but the hold flushes
        f.send(act(2)).unwrap();
        for expect in [0, 2] {
            assert_eq!(peer.recv().unwrap().round(), expect);
        }
        assert_eq!(f.stats().messages, 2);
        assert_eq!(f.injected(), 2, "one hold + one drop");
    }

    #[test]
    fn reorder_composes_with_duplicate_on_the_successor() {
        let (f, peer) = wrapped(
            FaultPlan::new(16).reorder_frames(0).duplicate_frame(1));
        f.send(act(0)).unwrap();
        f.send(act(1)).unwrap();
        for expect in [1, 1, 0] {
            assert_eq!(peer.recv().unwrap().round(), expect);
        }
        assert_eq!(f.stats().messages, 3);
    }

    #[test]
    fn kill_and_drop_compose_on_one_plan_in_documented_order() {
        // Grammar check: kill_at_round + drop_frame on the same link.
        // The drop fires before the kill round; the kill wins from its
        // round on, and frame indices past the death never happen.
        let (f, peer) = wrapped(
            FaultPlan::new(17).kill_at_round(2).drop_frame(0));
        f.send(act(0)).unwrap(); // dropped
        f.send(act(1)).unwrap(); // forwarded
        assert!(f.send(act(2)).is_err()); // killed
        assert_eq!(peer.recv().unwrap().round(), 1);
        assert!(peer.try_recv().unwrap().is_none());
        assert_eq!(f.stats().messages, 1);
        // One drop + one kill transition; sticky-kill re-sends don't
        // recount.
        assert!(f.send(act(3)).is_err());
        assert_eq!(f.injected(), 2);
    }

    #[test]
    fn drop_beats_corrupt_beats_reorder_on_the_same_index() {
        // Precedence is documented, not incidental: a frame index named
        // by several terminal injections takes the highest-precedence
        // one and the rest are inert.
        let (f, peer) = wrapped(
            FaultPlan::new(18)
                .drop_frame(0)
                .corrupt_frame(0)
                .reorder_frames(0));
        f.send(act(0)).unwrap();
        f.send(act(1)).unwrap();
        assert_eq!(peer.recv().unwrap().round(), 1);
        assert!(peer.try_recv().unwrap().is_none());
        assert_eq!(f.stats().messages, 1);
        assert_eq!(f.injected(), 1, "only the drop applied");
    }

    #[test]
    fn faults_injected_counts_every_applied_injection() {
        let (f, peer) = wrapped(
            FaultPlan::new(19)
                .delay_ms(0, 1)
                .duplicate_frame(0)
                .drop_frame(1)
                .partition_rounds_bidirectional(5, 6));
        f.send(act(0)).unwrap(); // delay + duplicate: 2 injections
        f.send(act(1)).unwrap(); // drop: 1
        f.send(act(5)).unwrap(); // partition discard: 1
        peer.send(act(5)).unwrap(); // inbound-filtered: 1
        peer.send(act(9)).unwrap();
        assert_eq!(f.recv().unwrap().round(), 9);
        assert_eq!(f.injected(), 5);
        // The counter shares the link's metrics cell when one exists.
        if let Some(h) = f.metrics() {
            assert_eq!(h.faults_injected.get(), 5);
        }
    }
}
