//! Real TCP transport: length-prefixed frames over std::net sockets.
//!
//! Used by examples/tcp_two_party.rs to run the two parties as separate
//! OS processes — the deployment shape of a real VFL job (each enterprise
//! runs its own binary). The codec is protocol::Message's frame format;
//! an optional `WanProfile` adds simulated WAN delay on top of the real
//! socket for single-host demos.
//!
//! Send path (DESIGN.md §4): each send encodes the length word + frame
//! body into one reusable scratch buffer (`protocol::encode_frame_into`)
//! and hands the kernel a single `write_all` — one syscall per message
//! in the common case, and zero steady-state allocation. The receive
//! path reuses a frame buffer the same way.
//!
//! K-party links (DESIGN.md §6): [`TcpTransport::with_identity`] stamps
//! every outgoing frame with the v2 `[src][dst]` envelope and verifies
//! the peer's envelope on receive — a miswired mesh fails at the first
//! frame with a party-id mismatch instead of silently corrupting the
//! round clock. Headerless peers (pre-session builds) still decode via
//! the v1 compat path. A peer that vanishes mid-round surfaces as an
//! error naming the link and the dead party id, not a bare io error.
//!
//! Mesh deployments don't construct transports directly: the session
//! bootstrap (DESIGN.md §7) runs the `Join`/`JoinAck` handshake on the
//! raw socket and then wraps it via [`TcpTransport::from_stream`], so
//! `LinkStats` counts training traffic only — byte-identical to an
//! in-proc link of the same session.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::WanProfile;
use crate::metrics::facade::LinkHandles;
use crate::protocol::{decode_frame, encode_frame_into, FrameHeader,
                      Message, FRAME_V2_OVERHEAD};
use crate::session::PartyId;

use super::{LinkStats, Transport};

/// Writer half: socket + reusable frame scratch, locked together so
/// concurrent senders interleave at frame granularity.
struct FramedWriter {
    stream: TcpStream,
    scratch: Vec<u8>,
}

/// Reader half: socket + reusable frame buffer.
struct FramedReader {
    stream: TcpStream,
    buf: Vec<u8>,
}

pub struct TcpTransport {
    reader: Mutex<FramedReader>,
    writer: Mutex<FramedWriter>,
    wan: WanProfile,
    /// `Some` on a v2 mesh link: stamped on every outgoing frame;
    /// incoming v2 frames must carry exactly its mirror image.
    header: Option<FrameHeader>,
    /// Pre-registered (initially detached) metric cells — what four
    /// private atomics used to be (DESIGN.md §10).
    handles: LinkHandles,
}

impl TcpTransport {
    /// Wrap an already-connected stream. This is the constructor the
    /// session bootstrap uses *after* the `Join`/`JoinAck` handshake on
    /// the raw socket: byte accounting starts at zero here, so
    /// `LinkStats` covers exactly the training traffic — identical to
    /// what an in-proc link of the same session charges.
    pub fn from_stream(stream: TcpStream, wan: WanProfile)
                       -> anyhow::Result<Self> {
        stream.set_nodelay(true)?;
        let reader = stream.try_clone()?;
        Ok(TcpTransport {
            reader: Mutex::new(FramedReader { stream: reader,
                                              buf: Vec::new() }),
            writer: Mutex::new(FramedWriter { stream,
                                              scratch: Vec::new() }),
            wan,
            header: None,
            handles: LinkHandles::detached(),
        })
    }

    /// Promote this link to v2 framing: every outgoing frame carries
    /// `self_id → peer`, and incoming v2 frames are verified to carry
    /// `peer → self_id` (v1 frames still pass — the compat path).
    pub fn with_identity(mut self, self_id: PartyId, peer: PartyId)
                         -> Self {
        self.header = Some(FrameHeader { src: self_id, dst: peer });
        self
    }

    /// Bind `addr` and accept one peer connection (Party B side).
    pub fn listen(addr: &str, wan: WanProfile) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let (stream, peer) = listener.accept()?;
        log::info!("tcp transport: accepted {peer}");
        Self::from_stream(stream, wan)
    }

    /// Connect to a listening peer, retrying with backoff (Party A side).
    pub fn connect(addr: &str, wan: WanProfile) -> anyhow::Result<Self> {
        let deadline = Instant::now() + Duration::from_secs(15);
        let stream = connect_with_backoff(addr, deadline)?;
        log::info!("tcp transport: connected {addr}");
        Self::from_stream(stream, wan)
    }

    /// Blocking read of one frame body into the reader's reusable buffer;
    /// decodes (and identity-checks v2 envelopes) before releasing the
    /// lock. `expect` is the envelope the peer must stamp — the mirror
    /// image of this endpoint's own header.
    fn recv_locked(r: &mut FramedReader, expect: Option<FrameHeader>)
                   -> anyhow::Result<Message> {
        let mut len_buf = [0u8; 4];
        r.stream
            .read_exact(&mut len_buf)
            .map_err(|e| eof_context(e, expect))?;
        let len = u32::from_le_bytes(len_buf) as usize;
        if len > 1 << 30 {
            anyhow::bail!("frame too large: {len} bytes");
        }
        r.buf.resize(len, 0);
        r.stream
            .read_exact(&mut r.buf)
            .map_err(|e| eof_context(e, expect))?;
        let (header, msg) = decode_frame(&r.buf)?;
        if let (Some(want), Some(got)) = (expect, header) {
            anyhow::ensure!(
                got == want,
                "frame from wrong endpoint: got {}→{}, expected {}→{}",
                got.src, got.dst, want.src, want.dst
            );
        }
        Ok(msg)
    }

    fn expected_header(&self) -> Option<FrameHeader> {
        self.header.map(FrameHeader::reply)
    }
}

/// Dial `addr` until it answers or `deadline` passes, sleeping with
/// exponential backoff (25 ms doubling to 1 s) between attempts. Shared
/// by [`TcpTransport::connect`] and the session bootstrap's dialer: the
/// label party may bind seconds (or a human shell-switch) after the
/// feature parties launch.
pub(crate) fn connect_with_backoff(addr: &str, deadline: Instant)
                                   -> anyhow::Result<TcpStream> {
    connect_with_backoff_jittered(addr, deadline, None)
}

/// Deterministic jitter factor for one backoff step: scales the sleep
/// into [0.5, 1.0) of the nominal step, derived purely from the jitter
/// stream (the dialing party's id) and the attempt counter. After a
/// label-party blip every feature party reconnects at once; without
/// jitter their exponential schedules are phase-locked (identical
/// constants, near-identical failure times), so each retry wave hits
/// the listener as a thundering herd of K−1 simultaneous dials. The
/// per-party stream de-phases the waves while staying reproducible —
/// no wall-clock entropy, so a retry schedule can be replayed in tests.
pub(crate) fn backoff_jitter(stream: u64, attempt: u32) -> f64 {
    let mut rng = crate::util::rng::Pcg::new(attempt as u64,
                                            0xB0FF ^ stream);
    0.5 + 0.5 * rng.next_f64()
}

/// [`connect_with_backoff`] with deterministic per-dialer jitter.
/// `jitter_stream` is typically the party id; `None` keeps the exact
/// historic schedule (the two-party `connect` path).
pub(crate) fn connect_with_backoff_jittered(
    addr: &str, deadline: Instant, jitter_stream: Option<u64>)
    -> anyhow::Result<TcpStream> {
    let mut backoff = Duration::from_millis(25);
    let mut attempt: u32 = 0;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                // Clamp the sleep to the time remaining so the last
                // attempt lands at the deadline, not up to a whole
                // backoff step before it; give up only once the
                // deadline has actually passed.
                let remaining =
                    deadline.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    return Err(anyhow::anyhow!(
                        "dialing {addr}: {e} (gave up at deadline)"
                    ));
                }
                let step = match jitter_stream {
                    Some(stream) => backoff
                        .mul_f64(backoff_jitter(stream, attempt)),
                    None => backoff,
                };
                let sleep = step.min(remaining);
                log::debug!("connect retry to {addr} in {sleep:?}: {e}");
                std::thread::sleep(sleep);
                backoff = (backoff * 2).min(Duration::from_secs(1));
                attempt += 1;
            }
        }
    }
}

/// Map a mid-frame EOF to an error naming the link and the peer party
/// (when the link carries a v2 identity) instead of surfacing a bare
/// `io::Error`: a K-party operator needs to know *which* of the K−1
/// links died, and that it died inside a round rather than at an
/// orderly shutdown boundary. `expect` is the envelope the peer stamps,
/// so `expect.src` is the peer and `expect.dst` this endpoint.
fn eof_context(e: std::io::Error, expect: Option<FrameHeader>)
               -> anyhow::Error {
    if e.kind() != std::io::ErrorKind::UnexpectedEof {
        return e.into();
    }
    match expect {
        Some(h) => anyhow::anyhow!(
            "link {}→{}: peer party {} disconnected mid-round \
             (unexpected EOF)", h.src, h.dst, h.src
        ),
        None => anyhow::anyhow!(
            "tcp link: peer disconnected mid-round (unexpected EOF)"
        ),
    }
}

impl Transport for TcpTransport {
    fn send(&self, msg: Message) -> anyhow::Result<()> {
        let start = Instant::now();
        let extra = if self.header.is_some() { FRAME_V2_OVERHEAD } else { 0 };
        let delay = self.wan.one_way_delay(msg.wire_bytes() + extra);
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        let frame_len;
        {
            let mut w = self.writer.lock().unwrap();
            let FramedWriter { stream, scratch } = &mut *w;
            // Length word + optional envelope + body in one reusable
            // buffer, one write_all.
            encode_frame_into(self.header, &msg, scratch);
            frame_len = scratch.len();
            stream.write_all(scratch)?;
            stream.flush()?;
        }
        self.handles
            .record(frame_len, msg.raw_bytes() + extra, start.elapsed());
        Ok(())
    }

    fn recv(&self) -> anyhow::Result<Message> {
        let mut r = self.reader.lock().unwrap();
        Self::recv_locked(&mut r, self.expected_header())
    }

    fn try_recv(&self) -> anyhow::Result<Option<Message>> {
        // Peek via nonblocking read of the length prefix. A peek of 0
        // bytes on a readable nonblocking socket means EOF — the peer
        // hung up — and must surface as an error, not as "no message
        // pending": the supervised label loop relies on try_recv to
        // distinguish a straggler (WouldBlock → keep waiting) from a
        // dead peer (EOF → mark the lane lost and go degraded).
        let mut r = self.reader.lock().unwrap();
        r.stream.set_nonblocking(true)?;
        let mut len_buf = [0u8; 4];
        let peeked = r.stream.peek(&mut len_buf);
        r.stream.set_nonblocking(false)?;
        match peeked {
            Ok(4) => {}
            Ok(0) => {
                return Err(eof_context(
                    std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "peer closed the connection",
                    ),
                    self.expected_header(),
                ))
            }
            Ok(_) => return Ok(None),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                return Ok(None)
            }
            Err(e) => return Err(e.into()),
        }
        Self::recv_locked(&mut r, self.expected_header()).map(Some)
    }

    fn stats(&self) -> LinkStats {
        self.handles.snapshot()
    }

    fn metrics(&self) -> Option<LinkHandles> {
        Some(self.handles.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn loopback_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener); // free the port for listen() below (racy but fine)
        let addr2 = addr.clone();
        let server = std::thread::spawn(move || {
            let t = TcpTransport::listen(&addr2, WanProfile::instant())
                .unwrap();
            let m = t.recv().unwrap();
            t.send(Message::EvalAck { round: m.round() }).unwrap();
            t.recv().unwrap()
        });
        let client =
            TcpTransport::connect(&addr, WanProfile::instant()).unwrap();
        client
            .send(Message::Activation {
                round: 11,
                tensor: Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
            })
            .unwrap();
        assert_eq!(client.recv().unwrap(), Message::EvalAck { round: 11 });
        client.send(Message::Shutdown).unwrap();
        assert_eq!(server.join().unwrap(), Message::Shutdown);
        assert_eq!(client.stats().messages, 2);
    }

    #[test]
    fn try_recv_over_tcp() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let addr2 = addr.clone();
        let server = std::thread::spawn(move || {
            let t = TcpTransport::listen(&addr2, WanProfile::instant())
                .unwrap();
            std::thread::sleep(Duration::from_millis(120));
            t.send(Message::EvalAck { round: 1 }).unwrap();
            // Hold the socket open until the client is done reading.
            t.recv().unwrap()
        });
        let client =
            TcpTransport::connect(&addr, WanProfile::instant()).unwrap();
        assert!(client.try_recv().unwrap().is_none());
        std::thread::sleep(Duration::from_millis(250));
        assert_eq!(client.try_recv().unwrap(),
                   Some(Message::EvalAck { round: 1 }));
        client.send(Message::Shutdown).unwrap();
        server.join().unwrap();
    }

    #[test]
    fn identity_links_roundtrip_and_charge_envelope() {
        // A v2 mesh link over real sockets: frames carry ids, the byte
        // accounting includes the 6-byte envelope, and both directions
        // verify the peer's identity.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let addr2 = addr.clone();
        let server = std::thread::spawn(move || {
            let t = TcpTransport::listen(&addr2, WanProfile::instant())
                .unwrap()
                .with_identity(PartyId(0), PartyId(2));
            let m = t.recv().unwrap();
            t.send(Message::EvalAck { round: m.round() }).unwrap();
            (m, t.stats())
        });
        let client =
            TcpTransport::connect(&addr, WanProfile::instant())
                .unwrap()
                .with_identity(PartyId(2), PartyId(0));
        let m = Message::Activation {
            round: 4,
            tensor: Tensor::f32(vec![2], vec![1.0, -1.0]),
        };
        client.send(m.clone()).unwrap();
        assert_eq!(client.recv().unwrap(), Message::EvalAck { round: 4 });
        let (got, server_stats) = server.join().unwrap();
        assert_eq!(got, m);
        assert_eq!(client.stats().bytes,
                   (m.wire_bytes() + FRAME_V2_OVERHEAD) as u64);
        assert_eq!(server_stats.bytes,
                   (Message::EvalAck { round: 4 }.wire_bytes()
                    + FRAME_V2_OVERHEAD) as u64);
    }

    #[test]
    fn wrong_identity_is_rejected_at_first_frame() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let addr2 = addr.clone();
        let server = std::thread::spawn(move || {
            // Expects frames from P1, but the client claims to be P2.
            let t = TcpTransport::listen(&addr2, WanProfile::instant())
                .unwrap()
                .with_identity(PartyId(0), PartyId(1));
            t.recv()
        });
        let client =
            TcpTransport::connect(&addr, WanProfile::instant())
                .unwrap()
                .with_identity(PartyId(2), PartyId(0));
        client.send(Message::Shutdown).unwrap();
        let got = server.join().unwrap();
        assert!(got.is_err(), "mis-identified peer was accepted");
        let e = got.unwrap_err().to_string();
        assert!(e.contains("wrong endpoint"), "unexpected error: {e}");
    }

    #[test]
    fn v1_peer_still_decodes_on_an_identity_link() {
        // Compat: a headerless (pre-session) frame arriving on an
        // identity-checking link passes — only *mismatched* v2
        // envelopes are rejected.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let addr2 = addr.clone();
        let server = std::thread::spawn(move || {
            let t = TcpTransport::listen(&addr2, WanProfile::instant())
                .unwrap()
                .with_identity(PartyId(0), PartyId(1));
            t.recv()
        });
        let client =
            TcpTransport::connect(&addr, WanProfile::instant()).unwrap();
        client.send(Message::EvalAck { round: 3 }).unwrap();
        assert_eq!(server.join().unwrap().unwrap(),
                   Message::EvalAck { round: 3 });
    }

    #[test]
    fn mid_round_eof_names_the_link_and_party() {
        // A peer that vanishes mid-round must surface as an error
        // naming the link endpoints and the dead party, not a bare io
        // error — on a K-party mesh the operator needs to know which
        // of the K−1 links died.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let addr2 = addr.clone();
        let server = std::thread::spawn(move || {
            let t = TcpTransport::listen(&addr2, WanProfile::instant())
                .unwrap()
                .with_identity(PartyId(0), PartyId(2));
            t.recv()
        });
        // Connect and hang up without sending a frame.
        let client =
            TcpTransport::connect(&addr, WanProfile::instant()).unwrap();
        drop(client);
        let e = server.join().unwrap().unwrap_err().to_string();
        assert!(e.contains("P2"), "missing peer id: {e}");
        assert!(e.contains("P2→P0"), "missing link name: {e}");
        assert!(e.contains("mid-round"), "missing context: {e}");
    }

    #[test]
    fn mid_round_eof_without_identity_still_says_disconnected() {
        // v1 (two-party) links have no ids to name, but the error must
        // still say what happened instead of "failed to fill whole
        // buffer".
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let addr2 = addr.clone();
        let server = std::thread::spawn(move || {
            let t = TcpTransport::listen(&addr2, WanProfile::instant())
                .unwrap();
            t.recv()
        });
        let client =
            TcpTransport::connect(&addr, WanProfile::instant()).unwrap();
        drop(client);
        let e = server.join().unwrap().unwrap_err().to_string();
        assert!(e.contains("disconnected mid-round"), "{e}");
    }

    #[test]
    fn try_recv_surfaces_peer_eof_as_an_error() {
        // A dead peer must not masquerade as "no message pending":
        // the supervised label loop polls try_recv during straggler
        // waits and needs EOF to mark the lane lost.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let addr2 = addr.clone();
        let server = std::thread::spawn(move || {
            let t = TcpTransport::listen(&addr2, WanProfile::instant())
                .unwrap()
                .with_identity(PartyId(0), PartyId(1));
            // Poll until the client's hangup becomes visible.
            let deadline = Instant::now() + Duration::from_secs(5);
            loop {
                match t.try_recv() {
                    Ok(Some(_)) => panic!("unexpected message"),
                    Ok(None) => {
                        assert!(Instant::now() < deadline,
                                "EOF never surfaced");
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => return e.to_string(),
                }
            }
        });
        let client =
            TcpTransport::connect(&addr, WanProfile::instant()).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        drop(client);
        let e = server.join().unwrap();
        assert!(e.contains("P1") && e.contains("disconnected"),
                "EOF error lacks context: {e}");
    }

    #[test]
    fn backoff_jitter_is_deterministic_bounded_and_de_phased() {
        // Deterministic: the same (stream, attempt) always yields the
        // same factor, so a retry schedule is replayable.
        for attempt in 0..6 {
            assert_eq!(backoff_jitter(1, attempt),
                       backoff_jitter(1, attempt));
        }
        // Bounded: every factor sits in [0.5, 1.0) — jitter shortens a
        // step (never extends it past the nominal exponential bound).
        for stream in 0..8u64 {
            for attempt in 0..8 {
                let f = backoff_jitter(stream, attempt);
                assert!((0.5..1.0).contains(&f),
                        "factor {f} out of range (stream {stream}, \
                         attempt {attempt})");
            }
        }
        // De-phased: across a K-party reconnect wave the parties'
        // factors differ on (nearly) every attempt — the schedules are
        // not phase-locked. Require strict difference on attempt 0 for
        // every pair in a K=8 mesh.
        for a in 1..8u64 {
            for b in (a + 1)..8 {
                assert_ne!(backoff_jitter(a, 0), backoff_jitter(b, 0),
                           "parties {a} and {b} share a jitter phase");
            }
        }
    }

    #[test]
    fn byte_accounting_matches_wire_bytes() {
        // The single-buffer send path must charge exactly the framed size.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let addr2 = addr.clone();
        let server = std::thread::spawn(move || {
            let t = TcpTransport::listen(&addr2, WanProfile::instant())
                .unwrap();
            (t.recv().unwrap(), t.recv().unwrap())
        });
        let client =
            TcpTransport::connect(&addr, WanProfile::instant()).unwrap();
        let m1 = Message::Activation {
            round: 1,
            tensor: Tensor::zeros_f32(vec![8, 4]),
        };
        let m2 = Message::Shutdown;
        let expect = (m1.wire_bytes() + m2.wire_bytes()) as u64;
        client.send(m1.clone()).unwrap();
        client.send(m2.clone()).unwrap();
        let (r1, r2) = server.join().unwrap();
        assert_eq!(r1, m1);
        assert_eq!(r2, m2);
        assert_eq!(client.stats().bytes, expect);
        assert_eq!(client.stats().messages, 2);
    }
}
