//! Cross-party transports: simulated-WAN in-process duplex + real TCP.
//!
//! The paper's testbed is two geo-distributed servers on a ~300 Mbps WAN
//! with gateway proxies. `InProcTransport` reproduces that environment on
//! one machine: every message is charged `WanProfile::one_way_delay`
//! (bandwidth + half-RTT + gateway overhead) by *sleeping in the sender*,
//! which models the sender-side link occupancy that makes the paper's
//! comm/compute overlap worth building. The two directions are
//! independent (full duplex), matching two TCP connections over a WAN.
//!
//! `TcpTransport` is the same interface over real sockets for genuine
//! two-process runs (examples/tcp_two_party.rs).
//!
//! In-proc delivery is zero-copy (DESIGN.md §4): messages move through
//! the channel as `Arc`-backed tensor handles, so the byte accounting
//! charges the full wire size while the process never copies the
//! payload. TCP pays exactly one serialize + one deserialize, each a
//! single bulk copy through a reused scratch buffer.
//!
//! K-party sessions (DESIGN.md §6) give each link an optional
//! [`FrameHeader`]: the endpoint then speaks v2 (party-addressed)
//! frames and charges the 6-byte envelope per message. In-proc links
//! never materialize the envelope — messages still cross as shared
//! handles — but the accounting (and therefore the simulated-WAN
//! occupancy) matches what TCP puts on the wire. Headerless endpoints
//! ([`inproc_pair`], the plain TCP constructors) stay byte-identical to
//! the two-party protocol.

pub mod fault;
pub mod tcp;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::WanProfile;
use crate::metrics::facade::LinkHandles;
use crate::protocol::{FrameHeader, Message, FRAME_V2_OVERHEAD};
use crate::session::PartyId;

/// Blocking duplex endpoint. `send` blocks for the (simulated or real)
/// link occupancy; `recv` blocks until a message is available.
pub trait Transport: Send + Sync {
    fn send(&self, msg: Message) -> anyhow::Result<()>;
    fn recv(&self) -> anyhow::Result<Message>;
    /// Non-blocking receive; Ok(None) when no message is pending.
    fn try_recv(&self) -> anyhow::Result<Option<Message>>;
    /// Cumulative traffic stats for this endpoint (sent direction).
    fn stats(&self) -> LinkStats;
    /// The pre-registered handle bundle this endpoint bumps on every
    /// send (DESIGN.md §10). Every transport in this crate starts
    /// *detached* — the cells exist but no registry sees them — and a
    /// session that wants live observability calls
    /// `Registry::bind_link` with the clone returned here, so enabling
    /// an exporter never changes a transport constructor or the wire.
    /// `None` (the default, for exotic impls) means the endpoint keeps
    /// private accounting that only `stats()` can read.
    fn metrics(&self) -> Option<LinkHandles> {
        None
    }
}

/// Sender-side accounting: bytes, messages, busy time on the link.
/// `bytes` is what actually crossed the wire; `raw_bytes` is what the
/// same messages would have occupied uncompressed (identical when no
/// compression is negotiated), so `raw_bytes / bytes` is the link's
/// achieved compression ratio.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LinkStats {
    pub messages: u64,
    pub bytes: u64,
    pub raw_bytes: u64,
    pub busy: Duration,
}

impl LinkStats {
    /// Achieved compression ratio (≥ 1.0 in practice; 1.0 when idle or
    /// uncompressed).
    pub fn compression_ratio(&self) -> f64 {
        if self.bytes == 0 {
            return 1.0;
        }
        self.raw_bytes as f64 / self.bytes as f64
    }

    /// Field-wise sum with `other` — totals across a link's transport
    /// incarnations (a `Rejoin` swaps the socket but the lane's
    /// accounting must keep counting).
    pub fn merged(self, other: LinkStats) -> LinkStats {
        LinkStats {
            messages: self.messages + other.messages,
            bytes: self.bytes + other.bytes,
            raw_bytes: self.raw_bytes + other.raw_bytes,
            busy: self.busy + other.busy,
        }
    }
}

/// One endpoint of the in-process simulated-WAN duplex.
pub struct InProcTransport {
    tx: Mutex<Sender<Message>>,
    rx: Mutex<Receiver<Message>>,
    wan: WanProfile,
    /// Pre-registered (initially detached) metric cells — what the
    /// private per-transport counter struct used to be (DESIGN.md §10).
    handles: LinkHandles,
    /// `Some` on v2 (party-addressed) links: the envelope is charged to
    /// the byte accounting, though in-proc it never materializes.
    header: Option<FrameHeader>,
}

/// Create a connected (party A, party B) endpoint pair over `wan`,
/// speaking headerless v1 frames (the two-party wire format).
pub fn inproc_pair(wan: WanProfile) -> (InProcTransport, InProcTransport) {
    duplex(wan, None, None)
}

/// Create one mesh link between parties `a` and `b` over `wan`. With
/// `v2` the endpoints frame with their ids (6 extra bytes per message
/// in the accounting); without, the link is identical to
/// [`inproc_pair`]. Returns (a's endpoint, b's endpoint).
pub fn inproc_link(wan: WanProfile, a: PartyId, b: PartyId, v2: bool)
                   -> (InProcTransport, InProcTransport) {
    let (ha, hb) = if v2 {
        (Some(FrameHeader { src: a, dst: b }),
         Some(FrameHeader { src: b, dst: a }))
    } else {
        (None, None)
    };
    duplex(wan, ha, hb)
}

fn duplex(wan: WanProfile, ha: Option<FrameHeader>,
          hb: Option<FrameHeader>)
          -> (InProcTransport, InProcTransport) {
    let (tx_ab, rx_ab) = channel();
    let (tx_ba, rx_ba) = channel();
    let a = InProcTransport {
        tx: Mutex::new(tx_ab),
        rx: Mutex::new(rx_ba),
        wan,
        handles: LinkHandles::detached(),
        header: ha,
    };
    let b = InProcTransport {
        tx: Mutex::new(tx_ba),
        rx: Mutex::new(rx_ab),
        wan,
        handles: LinkHandles::detached(),
        header: hb,
    };
    (a, b)
}

impl Transport for InProcTransport {
    fn send(&self, msg: Message) -> anyhow::Result<()> {
        let extra = if self.header.is_some() { FRAME_V2_OVERHEAD } else { 0 };
        let bytes = msg.wire_bytes() + extra;
        // Compressed frames occupy the link for their *wire* size — the
        // whole point of the codec layer — while raw_bytes keeps the
        // uncompressed volume for ratio reporting. The v2 envelope is
        // part of both: it rides every frame regardless of codec.
        let delay = self.wan.one_way_delay(bytes);
        let start = Instant::now();
        if !delay.is_zero() {
            // Sender occupies the link for the full transfer: this is the
            // behaviour the local-update technique amortises.
            std::thread::sleep(delay);
        }
        self.handles
            .record(bytes, msg.raw_bytes() + extra, start.elapsed());
        self.tx
            .lock()
            .unwrap()
            .send(msg)
            .map_err(|_| anyhow::anyhow!("peer disconnected"))
    }

    fn recv(&self) -> anyhow::Result<Message> {
        self.rx
            .lock()
            .unwrap()
            .recv()
            .map_err(|_| anyhow::anyhow!("peer disconnected"))
    }

    fn try_recv(&self) -> anyhow::Result<Option<Message>> {
        use std::sync::mpsc::TryRecvError;
        match self.rx.lock().unwrap().try_recv() {
            Ok(m) => Ok(Some(m)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                anyhow::bail!("peer disconnected")
            }
        }
    }

    fn stats(&self) -> LinkStats {
        self.handles.snapshot()
    }

    fn metrics(&self) -> Option<LinkHandles> {
        Some(self.handles.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn act(round: u64, n: usize) -> Message {
        Message::Activation { round, tensor: Tensor::zeros_f32(vec![n]) }
    }

    #[test]
    fn duplex_delivery_in_order() {
        let (a, b) = inproc_pair(WanProfile::instant());
        a.send(act(1, 4)).unwrap();
        a.send(act(2, 4)).unwrap();
        b.send(Message::Shutdown).unwrap();
        assert_eq!(b.recv().unwrap().round(), 1);
        assert_eq!(b.recv().unwrap().round(), 2);
        assert_eq!(a.recv().unwrap(), Message::Shutdown);
    }

    #[test]
    fn try_recv_nonblocking() {
        let (a, b) = inproc_pair(WanProfile::instant());
        assert!(b.try_recv().unwrap().is_none());
        a.send(act(9, 1)).unwrap();
        assert_eq!(b.try_recv().unwrap().unwrap().round(), 9);
        assert!(b.try_recv().unwrap().is_none());
    }

    #[test]
    fn wan_charges_bandwidth() {
        // 1 MiB at 80 Mbps ≈ 105 ms; assert the sender actually blocked.
        let wan = WanProfile { bandwidth_mbps: 80.0, rtt_ms: 0.0,
                               gateway_ms: 0.0 };
        let (a, b) = inproc_pair(wan);
        let start = Instant::now();
        a.send(act(1, 262_144)).unwrap();
        let elapsed = start.elapsed();
        assert!(elapsed >= Duration::from_millis(95), "elapsed={elapsed:?}");
        assert_eq!(b.recv().unwrap().round(), 1);
        let stats = a.stats();
        assert_eq!(stats.messages, 1);
        assert!(stats.bytes > 1_000_000);
        assert!(stats.busy >= Duration::from_millis(95));
    }

    #[test]
    fn directions_are_independent() {
        // A large A→B transfer must not delay B→A.
        let wan = WanProfile { bandwidth_mbps: 40.0, rtt_ms: 0.0,
                               gateway_ms: 0.0 };
        let (a, b) = inproc_pair(wan);
        let handle = std::thread::spawn(move || {
            a.send(act(1, 1 << 20)).unwrap(); // ~0.8 s
            a.recv().unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        let start = Instant::now();
        b.send(Message::EvalAck { round: 5 }).unwrap();
        assert!(start.elapsed() < Duration::from_millis(200));
        assert_eq!(handle.join().unwrap().round(), 5);
    }

    #[test]
    fn raw_vs_wire_byte_accounting() {
        use crate::compress::CodecKind;
        use crate::protocol::{outbound_stats, Lane};
        let (a, b) = inproc_pair(WanProfile::instant());
        let t = Tensor::zeros_f32(vec![64, 16]);
        let plain = Message::Activation { round: 0, tensor: t.clone() };
        a.send(plain.clone()).unwrap();
        let (comp, _) =
            outbound_stats(CodecKind::QuantInt8, Lane::Activation, 1,
                           t.clone())
                .unwrap();
        a.send(comp.clone()).unwrap();
        let _ = b.recv().unwrap();
        let _ = b.recv().unwrap();
        let stats = a.stats();
        assert_eq!(stats.bytes,
                   (plain.wire_bytes() + comp.wire_bytes()) as u64);
        assert_eq!(stats.raw_bytes, 2 * plain.wire_bytes() as u64);
        assert!(stats.raw_bytes > stats.bytes);
        assert!(stats.compression_ratio() > 1.0);
        assert_eq!(LinkStats::default().compression_ratio(), 1.0);
    }

    #[test]
    fn metrics_handles_alias_stats() {
        // The facade contract: the handle bundle a transport exposes is
        // the same cells stats() snapshots, so a registry that binds
        // the handles observes every send with no extra bookkeeping.
        let (a, b) = inproc_pair(WanProfile::instant());
        let handles = a.metrics().expect("in-proc exposes handles");
        a.send(act(1, 8)).unwrap();
        let _ = b.recv().unwrap();
        assert_eq!(handles.snapshot(), a.stats());
        assert_eq!(handles.snapshot().messages, 1);
        // Charging the handles shows up in stats() too (the rejoin
        // carry-over path).
        handles.charge(LinkStats {
            messages: 2,
            bytes: 10,
            raw_bytes: 10,
            busy: Duration::ZERO,
        });
        assert_eq!(a.stats().messages, 3);
    }

    #[test]
    fn disconnected_peer_errors() {
        let (a, b) = inproc_pair(WanProfile::instant());
        drop(b);
        assert!(a.send(Message::Shutdown).is_err());
        assert!(a.recv().is_err());
    }

    #[test]
    fn v2_link_charges_the_envelope() {
        use crate::protocol::FRAME_V2_OVERHEAD;
        let (f, l) = inproc_link(WanProfile::instant(), PartyId(1),
                                 PartyId(0), true);
        let m = act(0, 16);
        f.send(m.clone()).unwrap();
        assert_eq!(l.recv().unwrap(), m);
        let stats = f.stats();
        assert_eq!(stats.bytes,
                   (m.wire_bytes() + FRAME_V2_OVERHEAD) as u64);
        assert_eq!(stats.raw_bytes, stats.bytes);
        // A v1 link (v2 = false) stays byte-identical to inproc_pair.
        let (f1, l1) = inproc_link(WanProfile::instant(), PartyId(1),
                                   PartyId(0), false);
        f1.send(m.clone()).unwrap();
        assert_eq!(l1.recv().unwrap(), m);
        assert_eq!(f1.stats().bytes, m.wire_bytes() as u64);
    }
}
