//! API-identical stub for the `xla` PJRT bindings (default build).
//!
//! The real `xla` crate needs the native `libxla_extension` toolchain,
//! which CI and dependency-free checkouts don't have. This module mirrors
//! the exact slice of its API the runtime layer uses so that every
//! XLA-free layer (tensor, protocol, compress, transport, workset,
//! coordinator plumbing, experiment harnesses) builds and tests without
//! it. Behaviour:
//!
//! - `Literal` is a real host-side implementation (`vec1`, `scalar`,
//!   `reshape`, `to_vec`, `array_shape`): the conversion layer and its
//!   unit tests work unchanged.
//! - Client/executable entry points (`PjRtClient::cpu`,
//!   `HloModuleProto::from_text_file`) fail with an instructive error, so
//!   anything needing actual artifact execution reports "rebuild with
//!   `--features pjrt`" instead of crashing. `PjRtLoadedExecutable` and
//!   `PjRtBuffer` are uninhabited — the execute path is provably
//!   unreachable in stub builds.
//!
//! Building with `--features pjrt` swaps this module out for the real
//! crate (see Cargo.toml); call sites are identical.

use std::fmt;

/// Element types mirrored from the PJRT ABI (only F32/S32 are ever
/// produced by this repo's artifacts; the rest exist so downstream
/// `match` arms with a catch-all stay reachable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F16,
    F32,
    F64,
}

/// Dense array shape: dims + element type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Self-contained host literal (the stub's only fully-functional type).
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32 { dims: Vec<i64>, data: Vec<f32> },
    I32 { dims: Vec<i64>, data: Vec<i32> },
}

/// Element types storable in a [`Literal`].
pub trait NativeType: Copy {
    fn build(dims: Vec<i64>, data: Vec<Self>) -> Literal;
    fn extract(lit: &Literal) -> anyhow::Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn build(dims: Vec<i64>, data: Vec<f32>) -> Literal {
        Literal::F32 { dims, data }
    }

    fn extract(lit: &Literal) -> anyhow::Result<Vec<f32>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            Literal::I32 { .. } => anyhow::bail!("literal is S32, not F32"),
        }
    }
}

impl NativeType for i32 {
    fn build(dims: Vec<i64>, data: Vec<i32>) -> Literal {
        Literal::I32 { dims, data }
    }

    fn extract(lit: &Literal) -> anyhow::Result<Vec<i32>> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            Literal::F32 { .. } => anyhow::bail!("literal is F32, not S32"),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::build(vec![v.len() as i64], v.to_vec())
    }

    /// Rank-0 f32 literal.
    pub fn scalar(x: f32) -> Literal {
        Literal::F32 { dims: vec![], data: vec![x] }
    }

    fn len(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
        }
    }

    /// Same data, new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> anyhow::Result<Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(
            n as usize == self.len(),
            "reshape to {dims:?} ({n} elements) from {} elements",
            self.len()
        );
        let mut out = self.clone();
        match &mut out {
            Literal::F32 { dims: d, .. } | Literal::I32 { dims: d, .. } => {
                *d = dims.to_vec();
            }
        }
        Ok(out)
    }

    pub fn array_shape(&self) -> anyhow::Result<ArrayShape> {
        Ok(match self {
            Literal::F32 { dims, .. } => {
                ArrayShape { dims: dims.clone(), ty: ElementType::F32 }
            }
            Literal::I32 { dims, .. } => {
                ArrayShape { dims: dims.clone(), ty: ElementType::S32 }
            }
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> anyhow::Result<Vec<T>> {
        T::extract(self)
    }

    /// Tuple decomposition exists only on real PJRT execution outputs,
    /// which stub builds can never produce.
    pub fn to_tuple(self) -> anyhow::Result<Vec<Literal>> {
        anyhow::bail!("stub literals are never tuples (rebuild with \
                       --features pjrt)")
    }
}

fn unavailable(what: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "{what} requires the XLA/PJRT backend, which this binary was built \
         without — rebuild with `--features pjrt` (see rust/Cargo.toml)"
    )
}

/// Stub PJRT client: construction always fails.
#[derive(Debug)]
pub struct PjRtClient {
    _private: Uninhabited,
}

#[derive(Debug, Clone, Copy)]
enum Uninhabited {}

impl PjRtClient {
    pub fn cpu() -> anyhow::Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> anyhow::Result<PjRtLoadedExecutable> {
        match self._private {}
    }
}

/// Stub HLO module: loading always fails.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: Uninhabited,
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> anyhow::Result<HloModuleProto> {
        Err(unavailable("loading HLO artifacts"))
    }
}

/// Stub computation: only constructible from an (unconstructible) proto.
#[derive(Debug)]
pub struct XlaComputation {
    _private: Uninhabited,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match proto._private {}
    }
}

/// Uninhabited: stub builds can never hold a loaded executable.
#[derive(Debug)]
pub enum PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<A>(&self, _args: &[A])
                      -> anyhow::Result<Vec<Vec<PjRtBuffer>>> {
        match *self {}
    }
}

/// Uninhabited: no buffers without an executable.
#[derive(Debug)]
pub enum PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> anyhow::Result<Literal> {
        match *self {}
    }
}

impl fmt::Display for ElementType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_shape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        let s = r.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn scalar_is_rank_zero() {
        let s = Literal::scalar(0.5);
        assert!(s.array_shape().unwrap().dims().is_empty());
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![0.5]);
    }

    #[test]
    fn backend_entry_points_error_with_guidance() {
        let e = PjRtClient::cpu().unwrap_err().to_string();
        assert!(e.contains("--features pjrt"), "{e}");
        let e = HloModuleProto::from_text_file("x.hlo").unwrap_err()
            .to_string();
        assert!(e.contains("--features pjrt"), "{e}");
    }
}
