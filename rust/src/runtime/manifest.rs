//! Artifact manifest: the wire ABI between python/compile/aot.py and the
//! Rust runtime, parsed from `artifacts/<tag>/manifest.json`.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Parameter initialisation policy (python `_init_kind`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitKind {
    /// N(0, 0.01) — embedding tables.
    Normal001,
    /// Glorot/Xavier uniform — dense matrices.
    Glorot,
    /// Zeros — biases, wide paths.
    Zeros,
    /// Ones — DSSM scale.
    Ones,
}

impl InitKind {
    fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "normal_0.01" => Ok(InitKind::Normal001),
            "glorot" => Ok(InitKind::Glorot),
            "zeros" => Ok(InitKind::Zeros),
            "ones" => Ok(InitKind::Ones),
            _ => anyhow::bail!("unknown init kind '{s}'"),
        }
    }
}

/// One parameter in the flat positional ABI.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitKind,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed manifest for one artifact set.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: String,
    pub dataset: String,
    pub size: String,
    pub batch: usize,
    pub z_dim: usize,
    pub fields_a: usize,
    pub fields_b: usize,
    pub vocab: usize,
    pub wstats_len: usize,
    pub params_a: Vec<ParamSpec>,
    pub params_b: Vec<ParamSpec>,
    /// step name → HLO file name.
    pub files: Vec<(String, String)>,
}

const REQUIRED_STEPS: &[&str] = &[
    "a_fwd", "a_upd", "a_local", "a_grad_cos", "b_step", "b_local", "b_eval",
];

fn parse_params(j: &Json) -> anyhow::Result<Vec<ParamSpec>> {
    j.as_arr()?
        .iter()
        .map(|e| {
            let shape = e
                .expect("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<anyhow::Result<Vec<_>>>()?;
            Ok(ParamSpec {
                name: e.expect("name")?.as_str()?.to_string(),
                shape,
                init: InitKind::parse(e.expect("init")?.as_str()?)?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))?;
        let j = Json::parse(&src)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        let abi = j.expect("abi_version")?.as_usize()?;
        if abi != 1 {
            anyhow::bail!("unsupported manifest ABI {abi} (want 1)");
        }
        let files_obj = j.expect("files")?.as_obj()?;
        let mut files = Vec::new();
        for step in REQUIRED_STEPS {
            let f = files_obj
                .get(*step)
                .ok_or_else(|| anyhow::anyhow!("manifest missing step \
                                                '{step}'"))?
                .as_str()?;
            files.push((step.to_string(), f.to_string()));
        }
        let m = Manifest {
            dir: dir.to_path_buf(),
            model: j.expect("model")?.as_str()?.to_string(),
            dataset: j.expect("dataset")?.as_str()?.to_string(),
            size: j.expect("size")?.as_str()?.to_string(),
            batch: j.expect("batch")?.as_usize()?,
            z_dim: j.expect("z_dim")?.as_usize()?,
            fields_a: j.expect("fields_a")?.as_usize()?,
            fields_b: j.expect("fields_b")?.as_usize()?,
            vocab: j.expect("vocab")?.as_usize()?,
            wstats_len: j.expect("wstats_len")?.as_usize()?,
            params_a: parse_params(j.expect("params_a")?)?,
            params_b: parse_params(j.expect("params_b")?)?,
            files,
        };
        if m.wstats_len != 8 {
            anyhow::bail!("wstats_len {} unsupported (runtime expects 8)",
                          m.wstats_len);
        }
        Ok(m)
    }

    pub fn hlo_path(&self, step: &str) -> anyhow::Result<PathBuf> {
        self.files
            .iter()
            .find(|(s, _)| s == step)
            .map(|(_, f)| self.dir.join(f))
            .ok_or_else(|| anyhow::anyhow!("no artifact for step '{step}'"))
    }

    /// Total parameter count (both parties) — reporting only.
    pub fn total_params(&self) -> usize {
        self.params_a.iter().map(|p| p.numel()).sum::<usize>()
            + self.params_b.iter().map(|p| p.numel()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/wdl_criteo_tiny");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model, "wdl");
        assert_eq!(m.fields_a, 26);
        assert_eq!(m.fields_b, 13);
        assert_eq!(m.batch, 64);
        assert_eq!(m.params_a[0].name, "emb");
        assert_eq!(m.params_a[0].init, InitKind::Normal001);
        assert!(m.total_params() > 10_000);
        assert!(m.hlo_path("a_fwd").unwrap().exists());
        assert!(m.hlo_path("nonsense").is_err());
    }

    #[test]
    fn rejects_bad_abi() {
        let dir = std::env::temp_dir().join("celu_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"),
                       r#"{"abi_version": 99}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn init_kind_parse() {
        assert!(InitKind::parse("glorot").is_ok());
        assert!(InitKind::parse("he").is_err());
    }
}
