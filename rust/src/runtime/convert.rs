//! Host `Tensor` ↔ `xla::Literal` conversions at the PJRT boundary.
//!
//! Copy discipline (DESIGN.md §4): the literal ABI owns its own C++-side
//! buffer, so one host copy per direction is inherent — `vec1` copies the
//! shared buffer into the literal, and `to_vec` copies the literal out.
//! What we avoid is any copy beyond that one: the host side passes the
//! `Arc`-backed payload as a borrowed slice (no staging `Vec`), and the
//! literal→tensor direction moves the single `to_vec` result into the
//! shared buffer without re-staging it.

use crate::tensor::{Data, Tensor};

#[cfg(not(feature = "pjrt"))]
use super::pjrt_stub as xla;

/// Convert a host tensor to an XLA literal (the one inherent copy).
pub fn tensor_to_literal(t: &Tensor) -> anyhow::Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    let lit = match &t.data {
        Data::F32(v) => xla::Literal::vec1(v.as_ref()),
        Data::I32(v) => xla::Literal::vec1(v.as_ref()),
    };
    if t.shape.is_empty() {
        // vec1 gives shape [1]; scalars must be rank-0.
        return Ok(lit.reshape(&[])?);
    }
    Ok(lit.reshape(&dims)?)
}

/// Convert an XLA literal back to a host tensor (the one inherent copy,
/// plus the move into the shared buffer).
pub fn literal_to_tensor(lit: &xla::Literal) -> anyhow::Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(Tensor::f32(dims, lit.to_vec::<f32>()?)),
        xla::ElementType::S32 => Ok(Tensor::i32(dims, lit.to_vec::<i32>()?)),
        other => anyhow::bail!("unsupported element type {other:?}"),
    }
}

/// Scalar f32 literal (lr, cos ξ, use_weights gates).
pub fn scalar_literal(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let t = Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, -4.0, 0.5, 9.0]);
        let lit = tensor_to_literal(&t).unwrap();
        assert_eq!(literal_to_tensor(&lit).unwrap(), t);
    }

    #[test]
    fn i32_roundtrip() {
        let t = Tensor::i32(vec![4], vec![5, -6, 7, i32::MAX]);
        let lit = tensor_to_literal(&t).unwrap();
        assert_eq!(literal_to_tensor(&lit).unwrap(), t);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar_f32(0.25);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert!(back.shape.is_empty());
        assert_eq!(back.as_f32().unwrap(), &[0.25]);
    }

    #[test]
    fn shared_handles_convert_like_owners() {
        // A cloned handle (refcount 2) converts identically — conversion
        // never needs exclusive ownership of the shared buffer.
        let t = Tensor::f32(vec![2], vec![1.0, -1.0]);
        let h = t.clone();
        let a = tensor_to_literal(&t).unwrap();
        let b = tensor_to_literal(&h).unwrap();
        assert_eq!(literal_to_tensor(&a).unwrap(),
                   literal_to_tensor(&b).unwrap());
    }
}
