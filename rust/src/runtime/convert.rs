//! Host `Tensor` ↔ `xla::Literal` conversions at the PJRT boundary.

use crate::tensor::{Data, Tensor};

/// Convert a host tensor to an XLA literal (copies once).
pub fn tensor_to_literal(t: &Tensor) -> anyhow::Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    let lit = match &t.data {
        Data::F32(v) => xla::Literal::vec1(v),
        Data::I32(v) => xla::Literal::vec1(v),
    };
    if t.shape.is_empty() {
        // vec1 gives shape [1]; scalars must be rank-0.
        return Ok(lit.reshape(&[])?);
    }
    Ok(lit.reshape(&dims)?)
}

/// Convert an XLA literal back to a host tensor.
pub fn literal_to_tensor(lit: &xla::Literal) -> anyhow::Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(Tensor::f32(dims, lit.to_vec::<f32>()?)),
        xla::ElementType::S32 => Ok(Tensor::i32(dims, lit.to_vec::<i32>()?)),
        other => anyhow::bail!("unsupported element type {other:?}"),
    }
}

/// Scalar f32 literal (lr, cos ξ, use_weights gates).
pub fn scalar_literal(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let t = Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, -4.0, 0.5, 9.0]);
        let lit = tensor_to_literal(&t).unwrap();
        assert_eq!(literal_to_tensor(&lit).unwrap(), t);
    }

    #[test]
    fn i32_roundtrip() {
        let t = Tensor::i32(vec![4], vec![5, -6, 7, i32::MAX]);
        let lit = tensor_to_literal(&t).unwrap();
        assert_eq!(literal_to_tensor(&lit).unwrap(), t);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar_f32(0.25);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert!(back.shape.is_empty());
        assert_eq!(back.as_f32().unwrap(), &[0.25]);
    }
}
