//! Parameter store: init + AdaGrad accumulator state held as XLA literals.
//!
//! Initialisation executes the manifest's per-parameter policy (glorot for
//! dense matrices, N(0, 0.01) for embeddings, zeros/ones elsewhere) with
//! the repo PRNG — Python exports shapes only, never weights, so the two
//! parties' init never crosses the wire.

use crate::tensor::Tensor;
use crate::util::rng::Pcg;

use super::manifest::{InitKind, ParamSpec};

#[cfg(not(feature = "pjrt"))]
use super::pjrt_stub as xla;

/// AdaGrad initial accumulator (python optimizer.ADAGRAD_INIT_ACC).
pub const ADAGRAD_INIT_ACC: f32 = 0.1;

/// Initialise one parameter tensor from its spec.
pub fn init_param(spec: &ParamSpec, rng: &mut Pcg) -> Tensor {
    let n = spec.numel();
    let data = match spec.init {
        InitKind::Zeros => vec![0.0f32; n],
        InitKind::Ones => vec![1.0f32; n],
        InitKind::Normal001 => {
            (0..n).map(|_| rng.next_normal() * 0.01).collect()
        }
        InitKind::Glorot => {
            let (fan_in, fan_out) = match spec.shape.len() {
                0 | 1 => (n, n),
                _ => (spec.shape[0], spec.shape[spec.shape.len() - 1]),
            };
            let lim = (6.0 / (fan_in + fan_out) as f64).sqrt() as f32;
            (0..n).map(|_| rng.uniform(-lim, lim)).collect()
        }
    };
    Tensor::f32(spec.shape.clone(), data)
}

/// One party's trainable state: flat params + AdaGrad accumulators, kept
/// as XLA literals so the hot loop feeds them straight back into execute.
pub struct ParamState {
    pub params: Vec<xla::Literal>,
    pub accs: Vec<xla::Literal>,
    pub n: usize,
}

impl ParamState {
    /// Build from manifest specs. `stream` separates the two parties'
    /// init randomness.
    pub fn init(specs: &[ParamSpec], seed: u64, stream: u64)
                -> anyhow::Result<Self> {
        let mut rng = Pcg::new(seed, stream);
        let mut params = Vec::with_capacity(specs.len());
        let mut accs = Vec::with_capacity(specs.len());
        for spec in specs {
            let t = init_param(spec, &mut rng);
            params.push(super::convert::tensor_to_literal(&t)?);
            let acc = Tensor::f32(spec.shape.clone(),
                                  vec![ADAGRAD_INIT_ACC; spec.numel()]);
            accs.push(super::convert::tensor_to_literal(&acc)?);
        }
        let n = specs.len();
        Ok(ParamState { params, accs, n })
    }

    /// Export params + accumulators as host tensors, in spec order —
    /// the trainable half of a label-party checkpoint (DESIGN.md §8).
    pub fn export(&self) -> anyhow::Result<(Vec<Tensor>, Vec<Tensor>)> {
        let params = self
            .params
            .iter()
            .map(super::convert::literal_to_tensor)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let accs = self
            .accs
            .iter()
            .map(super::convert::literal_to_tensor)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok((params, accs))
    }

    /// Restore params + accumulators from host tensors (checkpoint
    /// resume). Counts and per-parameter shapes must match the
    /// initialized state — a snapshot from a different model fails
    /// here, not deep inside an execute call.
    pub fn import(&mut self, params: &[Tensor], accs: &[Tensor])
                  -> anyhow::Result<()> {
        anyhow::ensure!(
            params.len() == self.n && accs.len() == self.n,
            "checkpoint carries {} params / {} accs, model has {}",
            params.len(), accs.len(), self.n
        );
        for (i, (t, lit)) in params.iter().zip(&self.params).enumerate() {
            let dims: Vec<usize> = lit
                .array_shape()?
                .dims()
                .iter()
                .map(|&d| d as usize)
                .collect();
            anyhow::ensure!(
                t.shape == dims,
                "checkpoint param {i} has shape {:?}, model wants {dims:?}",
                t.shape
            );
        }
        for (i, (t, lit)) in accs.iter().zip(&self.accs).enumerate() {
            let dims: Vec<usize> = lit
                .array_shape()?
                .dims()
                .iter()
                .map(|&d| d as usize)
                .collect();
            anyhow::ensure!(
                t.shape == dims,
                "checkpoint accumulator {i} has shape {:?}, model wants \
                 {dims:?}",
                t.shape
            );
        }
        self.params = params
            .iter()
            .map(super::convert::tensor_to_literal)
            .collect::<anyhow::Result<Vec<_>>>()?;
        self.accs = accs
            .iter()
            .map(super::convert::tensor_to_literal)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(())
    }

    /// Replace params+accs from the first 2n outputs of a step artifact.
    pub fn absorb(&mut self, outputs: &mut Vec<xla::Literal>) {
        debug_assert!(outputs.len() >= 2 * self.n);
        // Drain the trailing extras first so we can split off params/accs.
        let rest = outputs.split_off(2 * self.n);
        let accs = outputs.split_off(self.n);
        self.params = std::mem::take(outputs);
        self.accs = accs;
        *outputs = rest;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{InitKind, ParamSpec};

    fn spec(name: &str, shape: Vec<usize>, init: InitKind) -> ParamSpec {
        ParamSpec { name: name.into(), shape, init }
    }

    #[test]
    fn init_policies() {
        let mut rng = Pcg::seeded(1);
        let z = init_param(&spec("b", vec![8], InitKind::Zeros), &mut rng);
        assert!(z.as_f32().unwrap().iter().all(|&x| x == 0.0));
        let o = init_param(&spec("s", vec![1], InitKind::Ones), &mut rng);
        assert_eq!(o.as_f32().unwrap(), &[1.0]);
        let e = init_param(&spec("emb", vec![100, 8], InitKind::Normal001),
                           &mut rng);
        let vals = e.as_f32().unwrap();
        let max = vals.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(max < 0.08, "emb init too large: {max}");
        assert!(vals.iter().any(|&x| x != 0.0));
        let g = init_param(&spec("w1", vec![64, 32], InitKind::Glorot),
                           &mut rng);
        let lim = (6.0f64 / 96.0).sqrt() as f32;
        assert!(g.as_f32().unwrap().iter().all(|&x| x.abs() <= lim));
    }

    #[test]
    fn init_is_deterministic_per_stream() {
        let specs = vec![spec("w1", vec![4, 4], InitKind::Glorot)];
        let a = ParamState::init(&specs, 7, 1).unwrap();
        let b = ParamState::init(&specs, 7, 1).unwrap();
        let c = ParamState::init(&specs, 7, 2).unwrap();
        let va = a.params[0].to_vec::<f32>().unwrap();
        let vb = b.params[0].to_vec::<f32>().unwrap();
        let vc = c.params[0].to_vec::<f32>().unwrap();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn export_import_roundtrip_and_shape_checks() {
        let specs = vec![
            spec("w", vec![2, 2], InitKind::Glorot),
            spec("b", vec![3], InitKind::Zeros),
        ];
        let a = ParamState::init(&specs, 5, 1).unwrap();
        let (params, accs) = a.export().unwrap();
        assert_eq!(params.len(), 2);
        assert_eq!(params[0].shape, vec![2, 2]);
        assert_eq!(accs[1].as_f32().unwrap(), &[ADAGRAD_INIT_ACC; 3]);
        // Import into a differently-seeded state restores a's values.
        let mut b = ParamState::init(&specs, 9, 1).unwrap();
        assert_ne!(b.params[0].to_vec::<f32>().unwrap(),
                   a.params[0].to_vec::<f32>().unwrap());
        b.import(&params, &accs).unwrap();
        assert_eq!(b.params[0].to_vec::<f32>().unwrap(),
                   a.params[0].to_vec::<f32>().unwrap());
        assert_eq!(b.accs[1].to_vec::<f32>().unwrap(),
                   a.accs[1].to_vec::<f32>().unwrap());
        // Wrong count and wrong shape are refused loudly — for the
        // accumulators too, not just the params.
        assert!(b.import(&params[..1], &accs[..1]).is_err());
        let bad = vec![
            Tensor::zeros_f32(vec![2, 3]),
            Tensor::zeros_f32(vec![3]),
        ];
        let e = b.import(&bad, &accs).unwrap_err().to_string();
        assert!(e.contains("shape"), "{e}");
        let bad_accs = vec![
            Tensor::zeros_f32(vec![2, 2]),
            Tensor::zeros_f32(vec![4]),
        ];
        let e = b.import(&params, &bad_accs).unwrap_err().to_string();
        assert!(e.contains("accumulator"), "{e}");
    }

    #[test]
    fn absorb_splits_outputs() {
        let specs = vec![
            spec("a", vec![2], InitKind::Zeros),
            spec("b", vec![3], InitKind::Zeros),
        ];
        let mut st = ParamState::init(&specs, 0, 0).unwrap();
        let mk = |v: &[f32]| xla::Literal::vec1(v);
        let mut outputs = vec![
            mk(&[1.0, 1.0]),          // param a'
            mk(&[2.0, 2.0, 2.0]),     // param b'
            mk(&[3.0, 3.0]),          // acc a'
            mk(&[4.0, 4.0, 4.0]),     // acc b'
            mk(&[9.0]),               // extra (loss)
        ];
        st.absorb(&mut outputs);
        assert_eq!(outputs.len(), 1);
        assert_eq!(outputs[0].to_vec::<f32>().unwrap(), vec![9.0]);
        assert_eq!(st.params[0].to_vec::<f32>().unwrap(), vec![1.0, 1.0]);
        assert_eq!(st.accs[1].to_vec::<f32>().unwrap(), vec![4.0; 3]);
    }
}

// SAFETY: `Literal`s are self-contained heap objects with no client
// back-reference; moving a ParamState between threads is sound (see the
// thread-safety strategy block in runtime/mod.rs).
unsafe impl Send for ParamState {}
