//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on
//! the CPU PJRT client from the Rust hot path (Python is never invoked).
//!
//! Pipeline per artifact (see /opt/xla-example/README.md for the gotchas):
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` once at startup; `execute` per step. Artifacts
//! return one tuple literal (return_tuple=True is part of the ABI); the
//! runtime decomposes it and threads the carried params/optimizer state
//! back into the next call.

pub mod convert;
pub mod manifest;
pub mod params;
pub mod party;
#[cfg(not(feature = "pjrt"))]
pub mod pjrt_stub;

// Default builds target the API-identical stub backend; `--features
// pjrt` resolves the same `xla::` paths against the real bindings
// instead (see Cargo.toml and pjrt_stub.rs).
#[cfg(not(feature = "pjrt"))]
use self::pjrt_stub as xla;

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

pub use manifest::Manifest;
pub use params::ParamState;
pub use party::{PartyARuntime, PartyBRuntime};

// ---------------------------------------------------------------------------
// Thread-safety strategy.
//
// The `xla` crate's client/executable types are !Send/!Sync because the
// client handle is an `Rc`, and `PjRtBuffer`s clone that Rc on creation.
// The underlying TfrtCpuClient is thread-safe, but the Rust-side refcount
// is not. We therefore funnel EVERY operation that can touch the client
// Rc (compilation, execution, buffer creation/drop) through one global
// ENGINE mutex, and assert Send/Sync on the wrappers below. Invariants:
//
//   1. `PjRtClient` clones/drops only happen inside `engine_lock()`
//      (Artifact::load, Artifact::run's output processing).
//   2. `PjRtBuffer`s never escape `Artifact::run` — outputs are converted
//      to `Literal`s (plain heap objects with no client back-reference)
//      before the lock is released.
//   3. `Literal`s are self-contained C++ objects; distinct literals are
//      safe to use from distinct threads (Send), and our types only share
//      them behind `&self` for reads issued by one thread at a time
//      (coordinator wraps each party runtime in a Mutex).
//
// Serialising PJRT dispatch process-wide costs nothing on this 1-core
// testbed (the computations themselves are the bottleneck) and keeps the
// unsafe surface auditable: it is exactly this block + the two
// `unsafe impl`s below and in party.rs.
// ---------------------------------------------------------------------------

fn engine_lock() -> MutexGuard<'static, ()> {
    use once_cell::sync::OnceCell;
    static ENGINE: OnceCell<Mutex<()>> = OnceCell::new();
    ENGINE.get_or_init(|| Mutex::new(())).lock().unwrap()
}

struct ClientCell(xla::PjRtClient);
// SAFETY: see the strategy block above — all Rc traffic is under ENGINE.
unsafe impl Send for ClientCell {}
unsafe impl Sync for ClientCell {}

/// Process-wide PJRT CPU client. Call sites must hold `engine_lock()` for
/// any operation that clones buffers/executables out of the client.
pub fn global_client() -> anyhow::Result<&'static xla::PjRtClient> {
    use once_cell::sync::OnceCell;
    static CLIENT: OnceCell<ClientCell> = OnceCell::new();
    let c = CLIENT.get_or_try_init(|| xla::PjRtClient::cpu().map(ClientCell))?;
    Ok(&c.0)
}

/// Cumulative compute-time accounting shared by a party's artifacts.
#[derive(Debug, Default)]
pub struct ComputeClock {
    nanos: AtomicU64,
    calls: AtomicU64,
}

impl ComputeClock {
    pub fn record(&self, d: Duration) {
        self.nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.calls.fetch_add(1, Ordering::Relaxed);
    }

    pub fn busy(&self) -> Duration {
        Duration::from_nanos(self.nanos.load(Ordering::Relaxed))
    }

    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }
}

/// One compiled step function.
pub struct Artifact {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    clock: Arc<ComputeClock>,
}

// SAFETY: see the thread-safety strategy block — the executable (and the
// client Rc it holds) is only touched inside `engine_lock()`.
unsafe impl Send for Artifact {}
unsafe impl Sync for Artifact {}

impl Artifact {
    pub fn load(client: &xla::PjRtClient, name: &str, path: &Path,
                clock: Arc<ComputeClock>) -> anyhow::Result<Self> {
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let _g = engine_lock();
        let exe = client.compile(&comp)?;
        drop(_g);
        log::debug!("compiled artifact {name} from {path:?}");
        Ok(Artifact { name: name.to_string(), exe, clock })
    }

    /// Execute with positional literal args; returns the decomposed tuple
    /// outputs in ABI order.
    pub fn run(&self, args: &[&xla::Literal])
               -> anyhow::Result<Vec<xla::Literal>> {
        let start = Instant::now();
        // Holds ENGINE across execute + output-buffer processing + buffer
        // drop: all client-Rc traffic of this call (invariants 1 and 2).
        let parts = {
            let _g = engine_lock();
            let out = self.exe.execute::<&xla::Literal>(args)?;
            let tuple = out
                .first()
                .and_then(|r| r.first())
                .ok_or_else(|| anyhow::anyhow!("{}: empty execution result",
                                               self.name))?
                .to_literal_sync()?;
            tuple.to_tuple()?
        };
        self.clock.record(start.elapsed());
        Ok(parts)
    }
}

/// All compiled artifacts of one (model, dataset, size) set.
pub struct ArtifactSet {
    pub manifest: Manifest,
    pub a_fwd: Artifact,
    pub a_upd: Artifact,
    pub a_local: Artifact,
    pub a_grad_cos: Artifact,
    pub b_step: Artifact,
    pub b_local: Artifact,
    pub b_eval: Artifact,
    pub clock_a: Arc<ComputeClock>,
    pub clock_b: Arc<ComputeClock>,
}

impl ArtifactSet {
    /// Load + compile every step of the set under `dir`.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let client: &xla::PjRtClient = global_client()?;
        let manifest = Manifest::load(dir)?;
        let clock_a = Arc::new(ComputeClock::default());
        let clock_b = Arc::new(ComputeClock::default());
        let load = |step: &str, clock: &Arc<ComputeClock>| {
            Artifact::load(client, step, &manifest.hlo_path(step)?,
                           clock.clone())
        };
        let start = Instant::now();
        let set = ArtifactSet {
            a_fwd: load("a_fwd", &clock_a)?,
            a_upd: load("a_upd", &clock_a)?,
            a_local: load("a_local", &clock_a)?,
            a_grad_cos: load("a_grad_cos", &clock_a)?,
            b_step: load("b_step", &clock_b)?,
            b_local: load("b_local", &clock_b)?,
            b_eval: load("b_eval", &clock_b)?,
            manifest,
            clock_a,
            clock_b,
        };
        log::info!(
            "loaded artifact set {} ({} params) in {:.2}s",
            set.manifest.dir.display(),
            set.manifest.total_params(),
            start.elapsed().as_secs_f64()
        );
        Ok(set)
    }

    /// Resolve `<artifacts_dir>/<model>_<dataset>_<size>` and load.
    pub fn load_tagged(artifacts_dir: &str, tag: &str)
                       -> anyhow::Result<Self> {
        let dir = Path::new(artifacts_dir).join(tag);
        if !dir.join("manifest.json").exists() {
            anyhow::bail!(
                "artifact set '{tag}' not found under {artifacts_dir} — \
                 run `make artifacts` first"
            );
        }
        Self::load(&dir)
    }
}
