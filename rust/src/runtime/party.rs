//! Per-party compute runtimes: typed wrappers over the step artifacts.
//!
//! These are the only call sites of PJRT in the training loop. Each party
//! owns its parameter state; the wrappers assemble the positional ABI
//! (params… accs… data… scalars…), execute, absorb the carried state and
//! return the host-visible extras (Z_A, ∇Z_A, loss, wstats).

use std::sync::Arc;

use crate::tensor::Tensor;

use super::convert::{literal_to_tensor, scalar_literal, tensor_to_literal};
use super::params::ParamState;
use super::{Artifact, ArtifactSet};

#[cfg(not(feature = "pjrt"))]
use super::pjrt_stub as xla;

/// Staleness telemetry vector [min,q10,q25,q50,q75,q90,mean,frac_kept].
pub type WStats = [f32; 8];

fn wstats_from(lit: &xla::Literal) -> anyhow::Result<WStats> {
    let v = lit.to_vec::<f32>()?;
    if v.len() != 8 {
        anyhow::bail!("wstats length {} != 8", v.len());
    }
    Ok([v[0], v[1], v[2], v[3], v[4], v[5], v[6], v[7]])
}

fn scalar_from(lit: &xla::Literal) -> anyhow::Result<f32> {
    let v = lit.to_vec::<f32>()?;
    v.first()
        .copied()
        .ok_or_else(|| anyhow::anyhow!("empty scalar output"))
}

/// Assemble `params… accs… extras…` argument vector.
fn args<'a>(state: &'a ParamState, extras: &[&'a xla::Literal])
            -> Vec<&'a xla::Literal> {
    let mut v = Vec::with_capacity(2 * state.n + extras.len());
    v.extend(state.params.iter());
    v.extend(state.accs.iter());
    v.extend(extras.iter().copied());
    v
}

/// Party A: bottom model only (features, no labels).
pub struct PartyARuntime {
    set: Arc<ArtifactSet>,
    pub state: ParamState,
    lr: xla::Literal,
    cos_xi: xla::Literal,
    use_weights: xla::Literal,
    pub local_updates: u64,
    pub exact_updates: u64,
    /// Self-supervised (unaligned-row) updates — separate from the
    /// exact counter so wire-round accounting stays untouched.
    pub ssl_updates: u64,
}

impl PartyARuntime {
    pub fn new(set: Arc<ArtifactSet>, seed: u64, lr: f32, cos_xi: f32,
               use_weights: bool) -> anyhow::Result<Self> {
        let state = ParamState::init(&set.manifest.params_a, seed, 0xA)?;
        Ok(PartyARuntime {
            set,
            state,
            lr: scalar_literal(lr),
            cos_xi: scalar_literal(cos_xi),
            use_weights: scalar_literal(if use_weights { 1.0 } else { 0.0 }),
            local_updates: 0,
            exact_updates: 0,
            ssl_updates: 0,
        })
    }

    fn artifact(&self, name: &str) -> &Artifact {
        match name {
            "a_fwd" => &self.set.a_fwd,
            "a_upd" => &self.set.a_upd,
            "a_local" => &self.set.a_local,
            _ => &self.set.a_grad_cos,
        }
    }

    /// Z_A = Bottom_A(X_A): the forward half of a communication round.
    pub fn forward(&self, xa: &Tensor) -> anyhow::Result<Tensor> {
        let xa_l = tensor_to_literal(xa)?;
        let mut v: Vec<&xla::Literal> =
            self.state.params.iter().collect();
        v.push(&xa_l);
        let out = self.artifact("a_fwd").run(&v)?;
        literal_to_tensor(&out[0])
    }

    /// Exact update with the fresh ∇Z_A received from Party B.
    pub fn exact_update(&mut self, xa: &Tensor, dza: &Tensor)
                        -> anyhow::Result<()> {
        let xa_l = tensor_to_literal(xa)?;
        let dza_l = tensor_to_literal(dza)?;
        let v = args(&self.state, &[&xa_l, &dza_l, &self.lr]);
        let mut out = self.artifact("a_upd").run(&v)?;
        self.state.absorb(&mut out);
        self.exact_updates += 1;
        Ok(())
    }

    /// Local update from cached statistics (Algorithm 2, Party A).
    pub fn local_update(&mut self, xa: &Tensor, za_stale: &Tensor,
                        dza_stale: &Tensor) -> anyhow::Result<WStats> {
        let xa_l = tensor_to_literal(xa)?;
        let za_l = tensor_to_literal(za_stale)?;
        let dza_l = tensor_to_literal(dza_stale)?;
        let v = args(&self.state,
                     &[&xa_l, &za_l, &dza_l, &self.lr, &self.cos_xi,
                       &self.use_weights]);
        let mut out = self.artifact("a_local").run(&v)?;
        self.state.absorb(&mut out);
        self.local_updates += 1;
        wstats_from(&out[0])
    }

    /// Self-supervised denoising update on unaligned rows (DESIGN.md
    /// §12): pull the bottom model's representation of a corrupted
    /// batch toward its clean representation. The cotangent is the
    /// gradient of ½‖Z̃ − Z‖² w.r.t. Z̃ with the clean Z treated as a
    /// stop-gradient target, normalized per row — so the step reuses
    /// the compiled `a_fwd`/`a_upd` artifacts unchanged and never
    /// touches the wire. Returns the mean per-element consistency loss.
    pub fn ssl_update(&mut self, xa_clean: &Tensor, xa_noisy: &Tensor)
                      -> anyhow::Result<f32> {
        let z_clean = self.forward(xa_clean)?;
        let z_noisy = self.forward(xa_noisy)?;
        let clean = z_clean.as_f32()?;
        let noisy = z_noisy.as_f32()?;
        anyhow::ensure!(clean.len() == noisy.len(),
                        "ssl forward shape mismatch");
        let scale = 1.0 / xa_clean.rows().max(1) as f32;
        let mut loss = 0.0f32;
        let dz: Vec<f32> = noisy
            .iter()
            .zip(clean)
            .map(|(&nz, &cz)| {
                let d = nz - cz;
                loss += 0.5 * d * d;
                d * scale
            })
            .collect();
        let dza = Tensor::f32(z_noisy.shape.clone(), dz);
        let xa_l = tensor_to_literal(xa_noisy)?;
        let dza_l = tensor_to_literal(&dza)?;
        let v = args(&self.state, &[&xa_l, &dza_l, &self.lr]);
        let mut out = self.artifact("a_upd").run(&v)?;
        self.state.absorb(&mut out);
        self.ssl_updates += 1;
        Ok(loss / clean.len().max(1) as f32)
    }

    /// ρ probe: cosine between bottom-model gradients under two
    /// cotangents. Returns (cos, ‖g1‖, ‖g2‖).
    pub fn grad_cos(&self, xa: &Tensor, dza1: &Tensor, dza2: &Tensor)
                    -> anyhow::Result<(f32, f32, f32)> {
        let xa_l = tensor_to_literal(xa)?;
        let d1 = tensor_to_literal(dza1)?;
        let d2 = tensor_to_literal(dza2)?;
        let mut v: Vec<&xla::Literal> = self.state.params.iter().collect();
        v.extend([&xa_l, &d1, &d2]);
        let out = self.artifact("a_grad_cos").run(&v)?;
        let probe = out[0].to_vec::<f32>()?;
        Ok((probe[0], probe[1], probe[2]))
    }
}

/// Party B: bottom + top models, labels, loss.
pub struct PartyBRuntime {
    set: Arc<ArtifactSet>,
    pub state: ParamState,
    lr: xla::Literal,
    cos_xi: xla::Literal,
    use_weights: xla::Literal,
    pub local_updates: u64,
    pub exact_updates: u64,
}

impl PartyBRuntime {
    pub fn new(set: Arc<ArtifactSet>, seed: u64, lr: f32, cos_xi: f32,
               use_weights: bool) -> anyhow::Result<Self> {
        let state = ParamState::init(&set.manifest.params_b, seed, 0xB)?;
        Ok(PartyBRuntime {
            set,
            state,
            lr: scalar_literal(lr),
            cos_xi: scalar_literal(cos_xi),
            use_weights: scalar_literal(if use_weights { 1.0 } else { 0.0 }),
            local_updates: 0,
            exact_updates: 0,
        })
    }

    /// Exact step with fresh Z_A: full fwd/bwd + AdaGrad; returns the
    /// derivatives ∇Z_A to send back and the batch loss.
    pub fn exact_step(&mut self, xb: &Tensor, y: &Tensor, za: &Tensor)
                      -> anyhow::Result<(Tensor, f32)> {
        let xb_l = tensor_to_literal(xb)?;
        let y_l = tensor_to_literal(y)?;
        let za_l = tensor_to_literal(za)?;
        let v = args(&self.state, &[&xb_l, &y_l, &za_l, &self.lr]);
        let mut out = self.set.b_step.run(&v)?;
        self.state.absorb(&mut out);
        self.exact_updates += 1;
        let dza = literal_to_tensor(&out[0])?;
        let loss = scalar_from(&out[1])?;
        Ok((dza, loss))
    }

    /// Local step from cached statistics (Algorithm 2, Party B).
    pub fn local_step(&mut self, xb: &Tensor, y: &Tensor, za_stale: &Tensor,
                      dza_stale: &Tensor) -> anyhow::Result<(f32, WStats)> {
        let xb_l = tensor_to_literal(xb)?;
        let y_l = tensor_to_literal(y)?;
        let za_l = tensor_to_literal(za_stale)?;
        let dza_l = tensor_to_literal(dza_stale)?;
        let v = args(&self.state,
                     &[&xb_l, &y_l, &za_l, &dza_l, &self.lr, &self.cos_xi,
                       &self.use_weights]);
        let mut out = self.set.b_local.run(&v)?;
        self.state.absorb(&mut out);
        self.local_updates += 1;
        Ok((scalar_from(&out[0])?, wstats_from(&out[1])?))
    }

    /// Side-effect-free ∇Z_A probe: runs the exact-step artifact but
    /// discards the updated parameters — used by the Theorem-1 ρ probe to
    /// obtain fresh derivatives for a pinned batch under the *current*
    /// params without advancing them.
    pub fn dza_probe(&self, xb: &Tensor, y: &Tensor, za: &Tensor)
                     -> anyhow::Result<Tensor> {
        let xb_l = tensor_to_literal(xb)?;
        let y_l = tensor_to_literal(y)?;
        let za_l = tensor_to_literal(za)?;
        let v = args(&self.state, &[&xb_l, &y_l, &za_l, &self.lr]);
        let out = self.set.b_step.run(&v)?;
        literal_to_tensor(&out[2 * self.state.n])
    }

    /// Validation forward: ŷ probabilities for a held-out batch.
    pub fn eval(&self, xb: &Tensor, za: &Tensor) -> anyhow::Result<Vec<f32>> {
        let xb_l = tensor_to_literal(xb)?;
        let za_l = tensor_to_literal(za)?;
        let mut v: Vec<&xla::Literal> = self.state.params.iter().collect();
        v.extend([&xb_l, &za_l]);
        let out = self.set.b_eval.run(&v)?;
        Ok(out[0].to_vec::<f32>()?)
    }
}

// SAFETY: both runtimes hold Literals (Send per the strategy block in
// runtime/mod.rs) and Arc<ArtifactSet> (Sync via Artifact's unsafe impl);
// the coordinator serialises all access behind a Mutex.
unsafe impl Send for PartyARuntime {}
unsafe impl Send for PartyBRuntime {}
