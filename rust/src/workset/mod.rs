//! The workset table — CELU-VFL's central abstraction (paper §3.1).
//!
//! Caches the last `W` exchanged mini-batch statistics ⟨i, Z_A^(i),
//! ∇Z_A^(i)⟩ with **two clocks** per entry:
//!   1. the communication-round timestamp `i` at insertion, and
//!   2. the number of local updates performed with the entry (`uses`).
//!
//! Eviction (paper §3.1): at insertion time `i`, entries inserted before
//! `i − W + 1` are discarded (bounds the maximum staleness at W·R); an
//! entry reaching `R` uses is dropped as well.
//!
//! Sampling (paper §3.2):
//!   - `Consecutive` (FedBCD): always the newest entry — the degenerate
//!     W=1 pattern.
//!   - `RoundRobin` (CELU-VFL): an entry becomes ineligible for the next
//!     W−1 local steps after being sampled. With a full table this cycles
//!     the entries fairly; with a near-empty table it creates the §3.2
//!     "bubbles" where the local worker must wait for communication —
//!     `sample` returns `None` and the caller blocks on the comm lane.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::Sampling;
use crate::tensor::Tensor;

/// One cached mini-batch: the paper's ⟨i, Z_A^(i), ∇Z_A^(i), j⟩ tuple
/// plus the feature rows needed to recompute ad-hoc statistics locally.
///
/// Every payload field is a shared handle (`Arc`-backed), so `Clone` is
/// O(ndim) — a few refcount bumps — regardless of batch × dim. `sample()`
/// hands out such a clone: the local worker reads the statistics through
/// the same allocation the comm worker inserted (DESIGN.md §4).
#[derive(Debug, Clone)]
pub struct WorksetEntry {
    /// Communication-round timestamp (clock #1).
    pub round: u64,
    /// Instance indices of this batch (for re-gathering features).
    pub indices: Arc<[u32]>,
    /// Cached forward activations Z_A^(i).
    pub za: Tensor,
    /// Cached backward derivatives ∇Z_A^(i).
    pub dza: Tensor,
    /// Local updates done with this entry (clock #2).
    pub uses: usize,
    /// Local-step counter value when last sampled (round-robin spacing).
    last_sampled: Option<u64>,
}

/// Lifetime statistics for the table (telemetry + invariant tests).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorksetStats {
    pub inserted: u64,
    pub evicted_stale: u64,
    pub retired_exhausted: u64,
    pub sampled: u64,
    pub bubbles: u64,
    /// Entries evicted to honour a cross-session [`CacheBudget`] (only
    /// nonzero for worksets attached to one via
    /// [`MeshWorkset::with_budget`]).
    pub evicted_budget: u64,
}

#[derive(Debug)]
pub struct WorksetTable {
    capacity: usize,
    max_uses: usize,
    policy: Sampling,
    entries: VecDeque<WorksetEntry>,
    /// Monotone local-step counter (increments per successful sample).
    local_step: u64,
    stats: WorksetStats,
}

impl WorksetTable {
    /// `capacity` = W, `max_uses` = R.
    pub fn new(capacity: usize, max_uses: usize, policy: Sampling) -> Self {
        assert!(capacity >= 1, "W must be ≥ 1");
        WorksetTable {
            capacity,
            max_uses,
            policy,
            entries: VecDeque::new(),
            local_step: 0,
            stats: WorksetStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn stats(&self) -> WorksetStats {
        self.stats
    }

    /// The configured capacity W.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn iter(&self) -> impl Iterator<Item = &WorksetEntry> {
        self.entries.iter()
    }

    /// Drop the oldest resident entry (budget-pressure eviction:
    /// cross-*session* memory bounds, as opposed to the per-table W
    /// window `insert` enforces). Returns whether anything was evicted.
    pub fn evict_oldest(&mut self) -> bool {
        if self.entries.pop_front().is_some() {
            self.stats.evicted_budget += 1;
            true
        } else {
            false
        }
    }

    /// Drop every entry inserted before round `floor` (streaming data
    /// plane, DESIGN.md §12: when a party's feed advances to the next
    /// window the feature rows backing older rounds are gone, so their
    /// cached statistics can no longer be re-gathered against). Counted
    /// as staleness evictions — the window moved, just not by the W
    /// clock. Returns how many entries were dropped.
    pub fn retire_below(&mut self, floor: u64) -> usize {
        let before = self.entries.len();
        self.entries.retain(|e| e.round >= floor);
        let dropped = before - self.entries.len();
        self.stats.evicted_stale += dropped as u64;
        dropped
    }

    /// Insert a freshly-exchanged batch at communication round `round`.
    /// Applies both eviction rules. `indices` accepts anything that
    /// converts into the shared index buffer — a `Vec<u32>` (moved into
    /// a fresh `Arc`) or an existing `Arc<[u32]>` handle (refcount
    /// bump, no reallocation), so callers that already hold shared
    /// indices (a decoded message, a sibling mesh lane) insert for
    /// free.
    pub fn insert(&mut self, round: u64,
                  indices: impl Into<Arc<[u32]>>, za: Tensor,
                  dza: Tensor) {
        // Staleness window: discard entries inserted before round−W+1.
        let min_round = round.saturating_sub(self.capacity as u64 - 1);
        let before = self.entries.len();
        self.entries.retain(|e| e.round >= min_round);
        self.stats.evicted_stale += (before - self.entries.len()) as u64;
        // Capacity bound (guards non-monotone round counters).
        while self.entries.len() >= self.capacity {
            self.entries.pop_front();
            self.stats.evicted_stale += 1;
        }
        self.entries.push_back(WorksetEntry {
            round,
            indices: indices.into(),
            za,
            dza,
            uses: 0,
            last_sampled: None,
        });
        self.stats.inserted += 1;
    }

    /// Pick one cached batch for a local update, or `None` when the policy
    /// has no eligible entry (a §3.2 bubble). The returned entry is a
    /// shared handle onto the cached buffers (refcount bumps, no tensor
    /// data copy); its use-count was already incremented (and the entry
    /// retired if it hit R).
    pub fn sample(&mut self) -> Option<WorksetEntry> {
        let pos = match self.policy {
            Sampling::Consecutive => {
                // Newest entry, FedBCD-style.
                if self.entries.is_empty() {
                    None
                } else {
                    Some(self.entries.len() - 1)
                }
            }
            Sampling::RoundRobin => {
                // Eligible: never sampled, or last sampled ≥ W local steps
                // before the *candidate* step (i.e. not within the last
                // W−1 steps). Among eligible, pick the least-recently-
                // sampled (FIFO for the never-sampled) — the rotation
                // order of Figure 4.
                let w = self.capacity as u64;
                let candidate_step = self.local_step + 1;
                self.entries
                    .iter()
                    .enumerate()
                    .filter(|(_, e)| match e.last_sampled {
                        None => true,
                        Some(s) => candidate_step - s >= w,
                    })
                    .min_by_key(|(i, e)| (e.last_sampled, e.round, *i))
                    .map(|(i, _)| i)
            }
        };
        let Some(pos) = pos else {
            self.stats.bubbles += 1;
            return None;
        };
        self.local_step += 1;
        self.stats.sampled += 1;
        let entry = &mut self.entries[pos];
        entry.uses += 1;
        entry.last_sampled = Some(self.local_step);
        let out = entry.clone();
        if entry.uses >= self.max_uses {
            self.entries.remove(pos);
            self.stats.retired_exhausted += 1;
        }
        Some(out)
    }

    /// Sample the entry cached at communication round `round`,
    /// bypassing the policy's choice — the bookkeeping (local-step
    /// clock, use count, retirement at R) is exactly [`Self::sample`]'s.
    ///
    /// This is how a secondary [`MeshWorkset`] lane mirrors the primary
    /// lane's sampling decision: lanes that see identical
    /// insert/sample histories (rounds are unique — the comm round
    /// counter is monotone) remain identical state machines, so
    /// per-link eviction and use accounting stay exact without each
    /// lane re-running the policy.
    pub fn sample_round(&mut self, round: u64) -> Option<WorksetEntry> {
        let pos = self.entries.iter().position(|e| e.round == round)?;
        self.local_step += 1;
        self.stats.sampled += 1;
        let entry = &mut self.entries[pos];
        entry.uses += 1;
        entry.last_sampled = Some(self.local_step);
        let out = entry.clone();
        if entry.uses >= self.max_uses {
            self.entries.remove(pos);
            self.stats.retired_exhausted += 1;
        }
        Some(out)
    }
}

// -- shared (condvar-parked) mesh workset ------------------------------------

/// One sampled aggregate from a [`MeshWorkset`]: the batch identity
/// plus the summed activations Σ_k Z_k^(round) and the cached
/// derivative view — exactly what the label party's local step
/// (Algorithm 2, LocalUpdatePartyB) consumes.
#[derive(Debug, Clone)]
pub struct MeshEntry {
    pub round: u64,
    pub indices: Arc<[u32]>,
    /// Σ over lanes of the cached Z_k. With a single lane this is the
    /// lane's own handle (refcount bump, no copy) — the two-party
    /// zero-copy path unchanged.
    pub za: Tensor,
    /// The primary lane's cached ∇Z view. All lanes cache the same
    /// derivative modulo per-link codec round-trips, so the primary
    /// lane's view is exact whenever the links share a codec (always,
    /// unless per-party overrides diverge).
    pub dza: Tensor,
}

#[derive(Debug)]
struct MeshInner {
    lanes: Vec<WorksetTable>,
    wake_epoch: u64,
    /// Entries currently charged against the attached [`CacheBudget`]
    /// (0, and never touched, without one).
    charged: usize,
}

/// A global cache-entry budget shared by every [`MeshWorkset`] a
/// multi-session server hosts: total resident entries (summed over all
/// sessions and all lanes) stay bounded no matter how many meshes are
/// live. Enforcement is *self-serving*: the workset whose `insert`
/// pushes the global total over the budget evicts its **own** oldest
/// rounds (lock-step across its lanes, so per-link exactness is
/// untouched) until the total fits or it has nothing left to give —
/// one session cannot evict another session's cache, it can only be
/// asked to live within what its own inserts claim. A session that is
/// merely *holding* entries while another session inserts keeps them
/// until its own next insert. The instantaneous bound is therefore
/// `max_entries` plus one round's lanes of transient overshoot per
/// concurrently-inserting session.
#[derive(Debug)]
pub struct CacheBudget {
    max_entries: usize,
    used: AtomicUsize,
}

impl CacheBudget {
    /// A budget of `max_entries` total resident entries.
    pub fn new(max_entries: usize) -> Arc<Self> {
        assert!(max_entries >= 1, "a cache budget must admit ≥ 1 entry");
        Arc::new(CacheBudget {
            max_entries,
            used: AtomicUsize::new(0),
        })
    }

    pub fn max_entries(&self) -> usize {
        self.max_entries
    }

    /// Entries currently charged (all attached worksets summed).
    pub fn used(&self) -> usize {
        self.used.load(Ordering::SeqCst)
    }
}

/// A sampling decision made under the mesh lock. The single-lane case
/// is fully resolved in place (the aggregate is the lane's handle);
/// the multi-lane case carries the per-lane handles out of the
/// critical section so the Σ_k sum never runs while holding the mutex
/// the comm worker's `insert` needs.
enum Picked {
    Ready(MeshEntry),
    Pending {
        round: u64,
        indices: Arc<[u32]>,
        zas: Vec<Tensor>,
        dza: Tensor,
    },
}

/// The thread-safe workset every party trains from: one
/// [`WorksetTable`] lane per peer, kept in **lock-step** behind a
/// single mutex and paired with a condvar so a local worker hitting a
/// §3.2 bubble parks until the comm worker's next `insert` instead of
/// burning CPU in a poll loop. Feature parties (and the two-party
/// label) run it with a single lane — the historic `SharedWorkset`
/// behaviour, zero-copy handles included; the K-party label party
/// gives it one lane per feature peer.
///
/// Every round the comm worker inserts one ⟨Z_k, ∇Z⟩ pair into every
/// lane atomically; sampling runs the policy on the primary lane and
/// mirrors its choice into the others via
/// [`WorksetTable::sample_round`], so uniform sampling, use counting
/// and eviction stay *per-link exact* — each lane is bit-for-bit the
/// table a two-party run against that peer alone would have kept.
///
/// Eligibility under both sampling policies can only change when an
/// entry is inserted (each party has a single local worker, and a
/// failed sample does not advance the local-step clock), so waking on
/// insert is exact — the wait timeout is belt-and-braces for shutdown
/// and spurious wakeups, not part of the protocol. `wake_all` bumps an
/// epoch under the same mutex, so a parked sampler can never miss it
/// and can distinguish a deliberate shutdown poke from a spurious
/// condvar wakeup.
#[derive(Debug)]
pub struct MeshWorkset {
    inner: Mutex<MeshInner>,
    on_insert: Condvar,
    budget: Option<Arc<CacheBudget>>,
}

impl MeshWorkset {
    /// `lanes` tables of `capacity` = W, `max_uses` = R each.
    pub fn new(lanes: usize, capacity: usize, max_uses: usize,
               policy: Sampling) -> Self {
        assert!(lanes >= 1, "a mesh workset needs at least one lane");
        MeshWorkset {
            inner: Mutex::new(MeshInner {
                lanes: (0..lanes)
                    .map(|_| WorksetTable::new(capacity, max_uses, policy))
                    .collect(),
                wake_epoch: 0,
                charged: 0,
            }),
            on_insert: Condvar::new(),
            budget: None,
        }
    }

    /// Attach this workset to a cross-session [`CacheBudget`]. Without
    /// one (the default, and every single-session run) nothing changes
    /// — no counter is even touched.
    pub fn with_budget(mut self, budget: Arc<CacheBudget>) -> Self {
        self.budget = Some(budget);
        self
    }

    pub fn lanes(&self) -> usize {
        self.inner.lock().unwrap().lanes.len()
    }

    /// Reconcile the attached budget's global counter with this
    /// workset's current residency. Must run under the mesh lock after
    /// anything that changed a lane's length (insert, retirement at R,
    /// eviction).
    fn settle(&self, inner: &mut MeshInner) {
        if let Some(b) = &self.budget {
            let now: usize = inner.lanes.iter().map(|l| l.len()).sum();
            if now >= inner.charged {
                b.used.fetch_add(now - inner.charged, Ordering::SeqCst);
            } else {
                b.used.fetch_sub(inner.charged - now, Ordering::SeqCst);
            }
            inner.charged = now;
        }
    }

    /// Insert round `round` into every lane atomically: `stats[k]` is
    /// peer k's ⟨Z_k, ∇Z_k⟩ pair. The indices are shared across lanes
    /// through one `Arc` (no per-lane reallocation). Wakes any parked
    /// local worker.
    pub fn insert(&self, round: u64, indices: impl Into<Arc<[u32]>>,
                  stats: Vec<(Tensor, Tensor)>) {
        let indices: Arc<[u32]> = indices.into();
        let mut inner = self.inner.lock().unwrap();
        assert_eq!(stats.len(), inner.lanes.len(),
                   "one (za, dza) pair per lane");
        for (lane, (za, dza)) in inner.lanes.iter_mut().zip(stats) {
            lane.insert(round, indices.clone(), za, dza);
        }
        self.settle(&mut inner);
        // Budget pressure: the inserting workset pays with its own
        // oldest rounds, popped lock-step across its lanes so the
        // mirrored sampling state machines stay identical. The entry
        // just inserted is never evicted (a session always keeps at
        // least its freshest round — otherwise a tight budget would
        // starve local updates entirely instead of merely shortening
        // the staleness window).
        if let Some(b) = &self.budget {
            while b.used() > b.max_entries
                && inner.lanes[0].len() > 1
            {
                for lane in inner.lanes.iter_mut() {
                    lane.evict_oldest();
                }
                self.settle(&mut inner);
            }
        }
        drop(inner);
        self.on_insert.notify_all();
    }

    /// Pick this step's entry under the lock, deferring any Σ_k
    /// aggregation until the lock is released (see [`Picked`]).
    fn sample_locked(inner: &mut MeshInner)
                     -> anyhow::Result<Option<Picked>> {
        let (first, rest) = inner
            .lanes
            .split_first_mut()
            .expect("mesh workset has ≥ 1 lane");
        let Some(e0) = first.sample() else {
            return Ok(None);
        };
        if rest.is_empty() {
            // Two-party fast path: the aggregate IS the lane's handle —
            // no allocation, no sum, nothing left to do outside the
            // lock.
            return Ok(Some(Picked::Ready(MeshEntry {
                round: e0.round,
                indices: e0.indices,
                za: e0.za,
                dza: e0.dza,
            })));
        }
        // Multi-lane: collect per-lane handles (refcount bumps) only;
        // the O(K·batch·z_dim) sum happens in `finalize`, outside the
        // mutex, so the comm worker's insert never stalls behind it.
        let mut zas = Vec::with_capacity(1 + rest.len());
        zas.push(e0.za);
        for lane in rest {
            let ek = lane.sample_round(e0.round).ok_or_else(|| {
                anyhow::anyhow!(
                    "mesh workset lanes out of lock-step at round {}",
                    e0.round
                )
            })?;
            zas.push(ek.za);
        }
        Ok(Some(Picked::Pending {
            round: e0.round,
            indices: e0.indices,
            zas,
            dza: e0.dza,
        }))
    }

    /// Resolve a [`Picked`] into the aggregate entry. Runs lock-free:
    /// the handles collected under the lock keep the tensors alive
    /// even if the lanes evict or retire the entries meanwhile. The
    /// sum is recomputed per sample (up to R−1 redundant sums per
    /// round) rather than cached per round — trading a [batch, z_dim]
    /// allocation per local step, off the comm path, for not holding
    /// an extra aggregate tensor alive per resident entry.
    fn finalize(picked: Picked) -> anyhow::Result<MeshEntry> {
        match picked {
            Picked::Ready(e) => Ok(e),
            Picked::Pending { round, indices, zas, dza } => {
                Ok(MeshEntry {
                    round,
                    indices,
                    za: Tensor::sum_f32(&zas)?,
                    dza,
                })
            }
        }
    }

    /// Non-blocking aggregate sample; `Ok(None)` on a §3.2 bubble.
    pub fn sample(&self) -> anyhow::Result<Option<MeshEntry>> {
        let mut inner = self.inner.lock().unwrap();
        let picked = Self::sample_locked(&mut inner)?;
        self.settle(&mut inner); // retirement at R shrinks residency
        drop(inner);
        picked.map(Self::finalize).transpose()
    }

    /// Sample, parking for up to `timeout` on a bubble: an `insert`
    /// ends the park with an entry, `wake_all` ends it empty-handed,
    /// and spurious condvar wakeups re-arm the wait against the
    /// original deadline, so the park genuinely honours `timeout`.
    /// Returns `Ok(None)` when the bubble persists (caller loops,
    /// re-checking its stop flag).
    pub fn sample_or_wait(&self, timeout: Duration)
                          -> anyhow::Result<Option<MeshEntry>> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(p) = Self::sample_locked(&mut inner)? {
            self.settle(&mut inner);
            drop(inner); // aggregate outside the lock
            return Self::finalize(p).map(Some);
        }
        let start_epoch = inner.wake_epoch;
        let deadline = Instant::now() + timeout;
        loop {
            let remaining =
                deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                let picked = Self::sample_locked(&mut inner)?;
                self.settle(&mut inner);
                drop(inner);
                return picked.map(Self::finalize).transpose();
            }
            let (guard, _timed_out) =
                self.on_insert.wait_timeout(inner, remaining).unwrap();
            inner = guard;
            if let Some(p) = Self::sample_locked(&mut inner)? {
                self.settle(&mut inner);
                drop(inner);
                return Self::finalize(p).map(Some);
            }
            if inner.wake_epoch != start_epoch {
                return Ok(None); // deliberate wake (shutdown)
            }
        }
    }

    /// Drop rounds below `floor` from every lane lock-step (see
    /// [`WorksetTable::retire_below`]): the streaming feed published a
    /// new window, so entries whose feature rows left memory must not
    /// be sampled again. Returns entries dropped from the primary lane.
    pub fn retire_below(&self, floor: u64) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let mut dropped = 0;
        for (i, lane) in inner.lanes.iter_mut().enumerate() {
            let d = lane.retire_below(floor);
            if i == 0 {
                dropped = d;
            }
        }
        self.settle(&mut inner);
        dropped
    }

    /// Wake all parked workers without inserting (shutdown path).
    pub fn wake_all(&self) {
        self.inner.lock().unwrap().wake_epoch += 1;
        self.on_insert.notify_all();
    }

    /// Primary-lane statistics. Lanes are lock-step, so every lane
    /// reports the same counters; the primary stands for all.
    pub fn stats(&self) -> WorksetStats {
        self.inner.lock().unwrap().lanes[0].stats()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().lanes[0].len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().lanes[0].is_empty()
    }

    /// Fill fraction of the primary lane: resident entries over W, in
    /// [0, 1] — the `celu_workset_fill` trainer gauge. Lanes are
    /// lock-step, so the primary stands for all.
    pub fn fill(&self) -> f64 {
        let inner = self.inner.lock().unwrap();
        inner.lanes[0].len() as f64 / inner.lanes[0].capacity() as f64
    }
}

impl Drop for MeshWorkset {
    fn drop(&mut self) {
        // Return this workset's residency to the shared budget: a
        // session ending must free its share for the meshes still live.
        if let Some(b) = &self.budget {
            let inner = self.inner.get_mut().unwrap();
            b.used.fetch_sub(inner.charged, Ordering::SeqCst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::{prop_assert, prop_assert_eq};

    fn t() -> Tensor {
        Tensor::zeros_f32(vec![1])
    }

    fn table(w: usize, r: usize, policy: Sampling) -> WorksetTable {
        WorksetTable::new(w, r, policy)
    }

    #[test]
    fn capacity_and_staleness_eviction() {
        let mut ws = table(3, 10, Sampling::RoundRobin);
        for round in 0..5 {
            ws.insert(round, vec![], t(), t());
        }
        assert_eq!(ws.len(), 3);
        let rounds: Vec<u64> = ws.iter().map(|e| e.round).collect();
        assert_eq!(rounds, vec![2, 3, 4]);
        assert_eq!(ws.stats().evicted_stale, 2);
    }

    #[test]
    fn staleness_window_evicts_on_round_jump() {
        let mut ws = table(3, 10, Sampling::RoundRobin);
        ws.insert(0, vec![], t(), t());
        ws.insert(1, vec![], t(), t());
        ws.insert(10, vec![], t(), t()); // window [8, 10] — drops 0 and 1
        assert_eq!(ws.len(), 1);
        assert_eq!(ws.iter().next().unwrap().round, 10);
    }

    #[test]
    fn entries_retire_after_r_uses() {
        let mut ws = table(1, 3, Sampling::Consecutive);
        ws.insert(0, vec![], t(), t());
        for expect_uses in 1..=3u64 {
            let e = ws.sample().expect("entry available");
            assert_eq!(e.uses as u64, expect_uses);
        }
        assert!(ws.is_empty());
        assert!(ws.sample().is_none());
        assert_eq!(ws.stats().retired_exhausted, 1);
        assert_eq!(ws.stats().bubbles, 1);
    }

    #[test]
    fn consecutive_always_newest() {
        let mut ws = table(3, 100, Sampling::Consecutive);
        ws.insert(0, vec![], t(), t());
        ws.insert(1, vec![], t(), t());
        assert_eq!(ws.sample().unwrap().round, 1);
        assert_eq!(ws.sample().unwrap().round, 1);
        ws.insert(2, vec![], t(), t());
        assert_eq!(ws.sample().unwrap().round, 2);
    }

    #[test]
    fn round_robin_cycles_fairly() {
        let mut ws = table(3, 100, Sampling::RoundRobin);
        for round in 0..3 {
            ws.insert(round, vec![], t(), t());
        }
        let seq: Vec<u64> =
            (0..6).map(|_| ws.sample().unwrap().round).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_bubbles_with_single_entry() {
        // W=3: after sampling the only entry, it is ineligible for the
        // next W−1 = 2 local steps → bubble (Figure 4, bottom row).
        let mut ws = table(3, 100, Sampling::RoundRobin);
        ws.insert(0, vec![], t(), t());
        assert!(ws.sample().is_some());
        assert!(ws.sample().is_none());
        assert_eq!(ws.stats().bubbles, 1);
        // A new batch arrives: it is sampled instead.
        ws.insert(1, vec![], t(), t());
        assert_eq!(ws.sample().unwrap().round, 1);
    }

    // -- property tests ----------------------------------------------------

    #[test]
    fn prop_len_never_exceeds_w() {
        prop::check("len ≤ W", |rng| {
            let w = 1 + rng.gen_range(8) as usize;
            let r = 1 + rng.gen_range(8) as usize;
            let policy = if rng.next_f32() < 0.5 {
                Sampling::RoundRobin
            } else {
                Sampling::Consecutive
            };
            let mut ws = table(w, r, policy);
            let mut round = 0u64;
            for _ in 0..200 {
                if rng.next_f32() < 0.4 {
                    round += 1 + rng.gen_range(3) as u64;
                    ws.insert(round, vec![], t(), t());
                } else {
                    let _ = ws.sample();
                }
                prop_assert!(ws.len() <= w, "len {} > W {}", ws.len(), w);
            }
            Ok(())
        });
    }

    #[test]
    fn prop_uses_never_exceed_r() {
        prop::check("uses ≤ R", |rng| {
            let w = 1 + rng.gen_range(5) as usize;
            let r = 1 + rng.gen_range(5) as usize;
            let mut ws = table(w, r, Sampling::RoundRobin);
            let mut round = 0u64;
            for _ in 0..300 {
                if rng.next_f32() < 0.3 {
                    round += 1;
                    ws.insert(round, vec![], t(), t());
                }
                if let Some(e) = ws.sample() {
                    prop_assert!(e.uses <= r, "uses {} > R {}", e.uses, r);
                }
                for e in ws.iter() {
                    prop_assert!(e.uses < r,
                                 "resident entry has uses {} ≥ R {}",
                                 e.uses, r);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_round_robin_spacing() {
        // No batch is sampled twice within W−1 intervening local steps.
        prop::check("round-robin spacing ≥ W", |rng| {
            let w = 2 + rng.gen_range(6) as usize;
            let mut ws = table(w, 1000, Sampling::RoundRobin);
            let mut round = 0u64;
            let mut history: Vec<u64> = Vec::new(); // round per local step
            for _ in 0..400 {
                if rng.next_f32() < 0.5 {
                    round += 1;
                    ws.insert(round, vec![], t(), t());
                }
                if let Some(e) = ws.sample() {
                    history.push(e.round);
                }
            }
            for (i, r1) in history.iter().enumerate() {
                for (j, r2) in history.iter().enumerate().skip(i + 1) {
                    if r1 == r2 {
                        prop_assert!(
                            j - i >= w,
                            "batch {} resampled after {} steps (< W={})",
                            r1, j - i, w
                        );
                        break;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_staleness_bounded_by_window() {
        prop::check("resident staleness < W", |rng| {
            let w = 1 + rng.gen_range(6) as usize;
            let mut ws = table(w, 10, Sampling::RoundRobin);
            let mut round = 0u64;
            for _ in 0..200 {
                round += 1 + rng.gen_range(2) as u64;
                ws.insert(round, vec![], t(), t());
                for e in ws.iter() {
                    prop_assert!(
                        round - e.round < w as u64,
                        "entry round {} too stale at {} (W={})",
                        e.round, round, w
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_conservation_of_entries() {
        prop::check("inserted = resident + evicted + retired", |rng| {
            let w = 1 + rng.gen_range(5) as usize;
            let r = 1 + rng.gen_range(4) as usize;
            let mut ws = table(w, r, Sampling::RoundRobin);
            let mut round = 0u64;
            for _ in 0..250 {
                if rng.next_f32() < 0.4 {
                    round += 1;
                    ws.insert(round, vec![], t(), t());
                } else {
                    let _ = ws.sample();
                }
            }
            let s = ws.stats();
            prop_assert_eq!(
                s.inserted,
                ws.len() as u64 + s.evicted_stale + s.retired_exhausted
            );
            Ok(())
        });
    }
}

#[cfg(test)]
mod extra_tests {
    use super::*;
    use crate::config::Sampling;

    fn t() -> Tensor {
        Tensor::zeros_f32(vec![1])
    }

    #[test]
    fn consecutive_starves_after_exhausting_newest() {
        // FedBCD semantics: only the newest entry is ever used; once it
        // hits R uses the worker stalls until the next exchange, even if
        // older entries remain.
        let mut ws = WorksetTable::new(3, 2, Sampling::Consecutive);
        ws.insert(0, vec![], t(), t());
        ws.insert(1, vec![], t(), t());
        assert_eq!(ws.sample().unwrap().round, 1);
        assert_eq!(ws.sample().unwrap().round, 1); // retires entry 1
        // Entry 0 is still resident but FedBCD goes back to it (newest
        // remaining), matching "latest batch" semantics.
        assert_eq!(ws.sample().unwrap().round, 0);
        assert_eq!(ws.sample().unwrap().round, 0);
        assert!(ws.sample().is_none());
    }

    #[test]
    fn round_robin_prefers_never_sampled_entries() {
        let mut ws = WorksetTable::new(4, 100, Sampling::RoundRobin);
        ws.insert(0, vec![], t(), t());
        assert_eq!(ws.sample().unwrap().round, 0);
        ws.insert(1, vec![], t(), t());
        ws.insert(2, vec![], t(), t());
        // Fresh entries outrank the recently-sampled one.
        assert_eq!(ws.sample().unwrap().round, 1);
        assert_eq!(ws.sample().unwrap().round, 2);
    }

    #[test]
    fn indices_travel_with_entries() {
        let mut ws = WorksetTable::new(2, 5, Sampling::RoundRobin);
        ws.insert(9, vec![4, 5, 6], t(), t());
        let e = ws.sample().unwrap();
        assert_eq!(e.round, 9);
        assert_eq!(e.indices.as_ref(), &[4, 5, 6]);
    }

    #[test]
    fn sample_returns_shared_handles_not_copies() {
        // The zero-copy contract: the sampled entry's tensors alias the
        // inserted allocations, and repeated samples alias each other.
        let mut ws = WorksetTable::new(2, 10, Sampling::Consecutive);
        let za = Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let dza = Tensor::f32(vec![2, 2], vec![5.0, 6.0, 7.0, 8.0]);
        ws.insert(0, vec![0, 1], za.clone(), dza.clone());
        let e1 = ws.sample().unwrap();
        let e2 = ws.sample().unwrap();
        assert!(e1.za.shares_data(&za), "sampled Z_A was deep-copied");
        assert!(e1.dza.shares_data(&dza), "sampled ∇Z_A was deep-copied");
        assert!(e1.za.shares_data(&e2.za));
        assert!(std::sync::Arc::ptr_eq(&e1.indices, &e2.indices));
    }

    #[test]
    fn stats_count_bubbles() {
        let mut ws = WorksetTable::new(3, 5, Sampling::RoundRobin);
        assert!(ws.sample().is_none());
        assert!(ws.sample().is_none());
        assert_eq!(ws.stats().bubbles, 2);
        assert_eq!(ws.stats().sampled, 0);
    }
}

#[cfg(test)]
mod mesh_tests {
    use super::*;
    use std::time::Instant;

    fn t(v: f32) -> Tensor {
        Tensor::f32(vec![2], vec![v, v + 1.0])
    }

    #[test]
    fn insert_accepts_shared_indices_without_reallocating() {
        // The satellite contract: an Arc<[u32]> caller keeps its
        // allocation — the entry aliases it instead of copying.
        let mut ws = WorksetTable::new(2, 5, Sampling::RoundRobin);
        let idx: Arc<[u32]> = vec![7u32, 8, 9].into();
        ws.insert(0, idx.clone(), t(0.0), t(0.0));
        let e = ws.sample().unwrap();
        assert!(Arc::ptr_eq(&e.indices, &idx),
                "shared indices were re-allocated on insert");
        // Vec callers still work (moved into a fresh Arc).
        ws.insert(1, vec![1u32, 2], t(0.0), t(0.0));
        assert_eq!(ws.sample().unwrap().indices.as_ref(), &[1, 2]);
    }

    #[test]
    fn sample_round_mirrors_sample_bookkeeping() {
        // Two tables fed identically; one sampled by policy, the other
        // mirrored by round — they must stay identical state machines.
        let mut primary = WorksetTable::new(3, 2, Sampling::RoundRobin);
        let mut mirror = WorksetTable::new(3, 2, Sampling::RoundRobin);
        for round in 0..3 {
            primary.insert(round, vec![], t(0.0), t(0.0));
            mirror.insert(round, vec![], t(0.0), t(0.0));
        }
        for _ in 0..8 {
            match primary.sample() {
                Some(e) => {
                    let m = mirror.sample_round(e.round)
                        .expect("mirror lane missing the round");
                    assert_eq!(m.round, e.round);
                    assert_eq!(m.uses, e.uses);
                }
                None => assert!(mirror.len() == primary.len()),
            }
        }
        assert_eq!(primary.stats().sampled, mirror.stats().sampled);
        assert_eq!(primary.stats().retired_exhausted,
                   mirror.stats().retired_exhausted);
        assert_eq!(primary.len(), mirror.len());
        assert!(mirror.sample_round(99).is_none());
    }

    #[test]
    fn single_lane_mesh_matches_shared_workset_and_shares_handles() {
        let mesh = MeshWorkset::new(1, 3, 10, Sampling::Consecutive);
        let za = t(1.0);
        let dza = t(5.0);
        mesh.insert(0, vec![0u32, 1], vec![(za.clone(), dza.clone())]);
        let e = mesh.sample().unwrap().unwrap();
        assert_eq!(e.round, 0);
        // Two-party fast path: aggregate == the cached handle.
        assert!(e.za.shares_data(&za));
        assert!(e.dza.shares_data(&dza));
        assert_eq!(mesh.stats().sampled, 1);
    }

    #[test]
    fn multi_lane_mesh_sums_activations_per_round() {
        let mesh = MeshWorkset::new(3, 4, 10, Sampling::RoundRobin);
        for round in 0..2u64 {
            let base = round as f32 * 10.0;
            mesh.insert(round, vec![round as u32],
                        vec![(t(base), t(0.0)), (t(base + 1.0), t(0.0)),
                             (t(base + 2.0), t(0.0))]);
        }
        let e = mesh.sample().unwrap().unwrap();
        assert_eq!(e.round, 0);
        // Σ_k Z_k: lanes held [0,1],[1,2],[2,3] → [3, 6].
        assert_eq!(e.za.as_f32().unwrap(), &[3.0, 6.0]);
        assert_eq!(e.indices.as_ref(), &[0]);
        let e = mesh.sample().unwrap().unwrap();
        assert_eq!(e.round, 1);
        assert_eq!(e.za.as_f32().unwrap(), &[33.0, 36.0]);
    }

    #[test]
    fn mesh_lanes_retire_in_lock_step() {
        // R = 2: after two aggregate samples of the only round, every
        // lane must have retired its entry (no orphan statistics).
        let mesh = MeshWorkset::new(2, 3, 2, Sampling::Consecutive);
        mesh.insert(0, vec![], vec![(t(0.0), t(0.0)), (t(1.0), t(0.0))]);
        assert!(mesh.sample().unwrap().is_some());
        assert!(mesh.sample().unwrap().is_some());
        assert!(mesh.is_empty());
        assert!(mesh.sample().unwrap().is_none());
        assert_eq!(mesh.stats().retired_exhausted, 1);
    }

    #[test]
    fn sample_or_wait_times_out_on_persistent_bubble() {
        let ws = MeshWorkset::new(1, 3, 10, Sampling::RoundRobin);
        let start = Instant::now();
        assert!(ws.sample_or_wait(Duration::from_millis(20))
            .unwrap()
            .is_none());
        let elapsed = start.elapsed();
        assert!(elapsed >= Duration::from_millis(15), "returned too early");
        assert!(ws.stats().bubbles >= 1);
    }

    #[test]
    fn sample_or_wait_is_immediate_with_entries() {
        let ws = MeshWorkset::new(1, 3, 10, Sampling::Consecutive);
        ws.insert(4, vec![], vec![(t(0.0), t(0.0))]);
        let start = Instant::now();
        let e = ws.sample_or_wait(Duration::from_secs(5)).unwrap();
        assert_eq!(e.unwrap().round, 4);
        assert!(start.elapsed() < Duration::from_millis(100),
                "eligible entry must not wait");
    }

    #[test]
    fn accessors_pass_through_to_the_primary_lane() {
        let ws = MeshWorkset::new(2, 2, 10, Sampling::RoundRobin);
        assert!(ws.is_empty());
        assert_eq!(ws.lanes(), 2);
        ws.insert(0, vec![], vec![(t(0.0), t(0.0)), (t(1.0), t(0.0))]);
        assert_eq!(ws.len(), 1);
        assert_eq!(ws.stats().inserted, 1);
        assert!(ws.sample().unwrap().is_some());
    }

    #[test]
    fn mesh_sample_or_wait_wakes_on_insert_and_on_wake_all() {
        let mesh = Arc::new(MeshWorkset::new(
            2, 3, 10, Sampling::RoundRobin));
        let m2 = mesh.clone();
        let waiter = std::thread::spawn(move || {
            m2.sample_or_wait(Duration::from_secs(10)).unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        let start = Instant::now();
        mesh.insert(0, vec![3u32], vec![(t(1.0), t(0.0)),
                                        (t(2.0), t(0.0))]);
        let got = waiter.join().unwrap();
        assert_eq!(got.unwrap().za.as_f32().unwrap(), &[3.0, 5.0]);
        assert!(start.elapsed() < Duration::from_secs(5));

        // wake_all unparks empty-handed.
        let m2 = mesh.clone();
        // Drain eligibility first (round-robin spacing blocks resample).
        let waiter = std::thread::spawn(move || {
            m2.sample_or_wait(Duration::from_secs(10)).unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        mesh.wake_all();
        assert!(waiter.join().unwrap().is_none());
    }

    // -- cross-session cache budget ------------------------------------------

    #[test]
    fn budget_charges_and_settles_across_worksets() {
        let budget = CacheBudget::new(100);
        let a = MeshWorkset::new(2, 4, 1, Sampling::Consecutive)
            .with_budget(budget.clone());
        let b = MeshWorkset::new(1, 4, 10, Sampling::Consecutive)
            .with_budget(budget.clone());
        a.insert(0, vec![], vec![(t(0.0), t(0.0)), (t(1.0), t(0.0))]);
        b.insert(0, vec![], vec![(t(0.0), t(0.0))]);
        assert_eq!(budget.used(), 3); // 2 lanes + 1 lane
        // Retirement at R=1 settles the charge down.
        assert!(a.sample().unwrap().is_some());
        assert_eq!(budget.used(), 1);
        // A dropped workset returns its residency.
        drop(b);
        assert_eq!(budget.used(), 0);
    }

    #[test]
    fn over_budget_insert_evicts_its_own_oldest_rounds() {
        let budget = CacheBudget::new(3);
        let hog = MeshWorkset::new(1, 8, 10, Sampling::RoundRobin)
            .with_budget(budget.clone());
        for round in 0..3u64 {
            hog.insert(round, vec![], vec![(t(0.0), t(0.0))]);
        }
        assert_eq!(budget.used(), 3);
        let tenant = MeshWorkset::new(1, 8, 10, Sampling::RoundRobin)
            .with_budget(budget.clone());
        // The tenant's insert overflows the budget; it pays with its
        // own cache, which only has the fresh entry — kept (a session
        // never evicts below one round), so the transient overshoot is
        // bounded at one round's lanes.
        tenant.insert(0, vec![], vec![(t(0.0), t(0.0))]);
        assert_eq!(tenant.len(), 1);
        assert_eq!(budget.used(), 4);
        // The hog's next insert sees the pressure and sheds its own
        // oldest rounds until the global total fits again: 4 resident
        // after the insert (5 with the tenant's), evict 0 and 1, stop
        // at used == 3.
        hog.insert(3, vec![], vec![(t(0.0), t(0.0))]);
        assert_eq!(hog.len(), 2);
        assert_eq!(budget.used(), 3);
        assert_eq!(hog.stats().evicted_budget, 2);
        assert_eq!(tenant.stats().evicted_budget, 0);
    }

    #[test]
    fn budget_eviction_keeps_mesh_lanes_in_lock_step() {
        let budget = CacheBudget::new(4);
        let mesh = MeshWorkset::new(2, 8, 10, Sampling::RoundRobin)
            .with_budget(budget.clone());
        for round in 0..4u64 {
            let base = round as f32;
            mesh.insert(round, vec![round as u32],
                        vec![(t(base), t(0.0)), (t(base + 1.0), t(0.0))]);
        }
        // 4 rounds × 2 lanes = 8 charged > 4: evicted down lock-step.
        assert!(budget.used() <= 4);
        assert_eq!(mesh.len(), 2);
        // Sampling still aggregates consistent rounds (no out-of-step
        // lane error) and the sum is per-round exact.
        let e = mesh.sample().unwrap().unwrap();
        assert_eq!(e.za.as_f32().unwrap(),
                   &[e.round as f32 * 2.0 + 1.0]);
    }

    #[test]
    fn retire_below_drops_old_rounds_lock_step() {
        let mesh = MeshWorkset::new(2, 8, 10, Sampling::RoundRobin);
        for round in 0..4u64 {
            mesh.insert(round, vec![round as u32],
                        vec![(t(0.0), t(0.0)), (t(1.0), t(0.0))]);
        }
        assert_eq!(mesh.retire_below(2), 2);
        assert_eq!(mesh.len(), 2);
        // Sampling still aggregates in lock-step after the cut.
        let e = mesh.sample().unwrap().unwrap();
        assert!(e.round >= 2);
        // A floor at or below the oldest resident round is a no-op.
        assert_eq!(mesh.retire_below(0), 0);
        assert_eq!(mesh.len(), 2);
    }

    #[test]
    fn fill_reports_the_primary_lane_fraction() {
        let ws = MeshWorkset::new(2, 4, 10, Sampling::RoundRobin);
        assert_eq!(ws.fill(), 0.0);
        ws.insert(0, vec![], vec![(t(0.0), t(0.0)), (t(0.0), t(0.0))]);
        assert_eq!(ws.fill(), 0.25);
        for round in 1..6u64 {
            ws.insert(round, vec![], vec![(t(0.0), t(0.0)),
                                          (t(0.0), t(0.0))]);
        }
        assert_eq!(ws.fill(), 1.0); // capped at W
    }
}
