//! Label-party driver: features + labels, bottom and top models, and
//! the run's control plane (loss tracking, AUC evaluation, stopping).
//! Aggregates over a whole mesh of feature parties: the top model
//! consumes Σ_k Z_k, and since ∂L/∂Z_k = ∂L/∂(Σ_j Z_j) for the sum
//! aggregation, the same derivative frame fans out to every peer — the
//! standard K-party topology (C-VFL). With one link this is exactly the
//! PR-1/PR-2 Party B, byte for byte.
//!
//! Comm worker, per round: collect Z_k from each activation lane (via
//! the supervised [`LaneSet`] — a bounded straggler wait substitutes a
//! lane's cached stale statistics when `--straggler-wait-ms` is set;
//! dead lanes can `Rejoin` through the listener's re-admission point) →
//! exact step on Σ_k Z_k (computes loss + ∇Z, updates θ_B/θ_top) →
//! cache ⟨i, Z_k, ∇Z⟩ into each peer's workset lane → fan the
//! derivative out. Local worker: local steps against the cached
//! aggregate statistics (Algorithm 2, LocalUpdatePartyB) via
//! [`MeshWorkset`], which keeps one [`crate::workset::WorksetTable`]
//! lane per peer in lock-step so uniform sampling and instance
//! weighting stay per-link exact. The label party owns the stop
//! decision and broadcasts Shutdown on every link.
//!
//! The cache insert happens *before* the (WAN-bound) sends: the entries'
//! tensors are `Arc`-shared with the outgoing messages rather than
//! copied, and the local worker can already consume the fresh statistics
//! while the derivatives are still occupying the links (DESIGN.md §4).
//!
//! Codec negotiation is per link (DESIGN.md §5): links whose bootstrap
//! carried the peer's codec mask pre-negotiate and skip the `Hello`
//! exchange entirely; mask-less links answer the peer-initiated `Hello`
//! as before, and a plain first frame means a pre-handshake peer — that
//! link stays on the identity codec, byte-identical to PR 1. On a
//! checkpoint resume ([`LabelRunOpts::resume`]) the snapshot's per-link
//! codec state overrides negotiation, model state is imported, and the
//! round loop continues from the snapshot's round (DESIGN.md §8).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::RunConfig;
use crate::data::batcher::{gather_b_with, GatherScratch};
use crate::data::PartyBData;
use crate::dataset::LabelFeed;
use crate::metrics::facade::Registry;
use crate::metrics::{auc_exact, CosineRecorder, SeriesPoint};
use crate::runtime::{ArtifactSet, PartyBRuntime};
use crate::session::bootstrap::Readmission;
use crate::session::checkpoint::{save_with_retry, SessionSnapshot};
use crate::session::supervisor::{session_epoch, LaneInput, LaneSet,
                                 SessionState};
use crate::session::Link;
use crate::tensor::Tensor;
use crate::util::stats::Ema;
use crate::workset::{CacheBudget, MeshWorkset, WorksetStats};

use super::{eval_batch_count, Ctrl, BUBBLE_PARK};

/// Supervised-lifecycle options for a label run. The default (no
/// re-admission point, no resume) is the historic run-to-completion
/// behaviour.
#[derive(Default)]
pub struct LabelRunOpts {
    /// The bootstrap listener kept alive as a `Rejoin` re-admission
    /// point (`SessionListener::establish_supervised`).
    pub readmission: Option<Readmission>,
    /// Restart from this checkpoint: model state is imported, per-link
    /// codecs are pinned from the snapshot, and the round loop resumes
    /// at `snapshot.round`.
    pub resume: Option<SessionSnapshot>,
    /// Publish lifecycle events and per-link accounting into this
    /// registry (the observability plane — DESIGN.md §10). `None` keeps
    /// a lane-set-private registry; `Session::run_label_with` injects
    /// the session's own.
    pub registry: Option<Arc<Registry>>,
    /// Charge this run's workset cache against a budget shared with
    /// other sessions in the same process (the multi-session server —
    /// DESIGN.md §11). `None` keeps the historic per-run W bound only.
    pub cache_budget: Option<Arc<CacheBudget>>,
}

/// Everything the label party reports after a run. Lifecycle events
/// and per-link accounting are NOT carried here by value any more —
/// they live in the run's [`Registry`] (query
/// [`Registry::events`] / [`Registry::link_rows`], or snapshot through
/// an exporter).
#[derive(Debug, Default)]
pub struct LabelPartyReport {
    pub comm_rounds: u64,
    pub exact_updates: u64,
    pub local_updates: u64,
    pub workset: WorksetStats,
    pub cosine: CosineRecorder,
    pub series: Vec<SeriesPoint>,
    /// Why the run ended.
    pub stop_reason: StopReason,
    /// Lanes re-admitted during the run.
    pub rejoins: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StopReason {
    #[default]
    MaxRounds,
    TargetAuc,
    TimeBudget,
}

/// Run the label party to completion. Training rows arrive through
/// `feed` — in-memory (historic behaviour, byte-identical wire) or
/// streaming over an on-disk table (DESIGN.md §12); the feed's window
/// schedule is the same pure function of `(seed, window)` every
/// feature party computes, so lock-step needs no extra coordination.
pub fn run_label_party(
    cfg: &RunConfig,
    set: Arc<ArtifactSet>,
    mut feed: LabelFeed,
    test: Arc<PartyBData>,
    links: &[Link],
    opts: LabelRunOpts,
) -> anyhow::Result<LabelPartyReport> {
    anyhow::ensure!(!links.is_empty(),
                    "label party needs at least one feature link");
    let LabelRunOpts { readmission, resume, registry, cache_budget } =
        opts;
    let batch = set.manifest.batch;
    let runtime = Arc::new(Mutex::new(PartyBRuntime::new(
        set.clone(),
        // The label party's init stream must differ from the feature
        // parties' but the *batch schedule* seed must match: all derive
        // from cfg.seed.
        cfg.seed,
        cfg.lr as f32,
        cfg.cos_xi() as f32,
        cfg.weighting_enabled(),
    )?));
    let start_round: u64 = match &resume {
        Some(snap) => {
            anyhow::ensure!(
                snap.parties as usize == cfg.parties,
                "checkpoint is for a {}-party session, config says {}",
                snap.parties, cfg.parties
            );
            anyhow::ensure!(
                snap.epoch == session_epoch(cfg.seed),
                "checkpoint epoch {:#x} does not match this config's \
                 session epoch {:#x} — different seed or logical session",
                snap.epoch, session_epoch(cfg.seed)
            );
            anyhow::ensure!(
                (snap.round as usize) < cfg.max_rounds,
                "checkpoint round {} is not before max_rounds {}",
                snap.round, cfg.max_rounds
            );
            runtime
                .lock()
                .unwrap()
                .state
                .import(&snap.params, &snap.accs)?;
            log::info!(
                "resumed label party from checkpoint: round {}, epoch \
                 {:#x}", snap.round, snap.epoch
            );
            snap.round
        }
        None => 0,
    };
    let mut workset = MeshWorkset::new(
        links.len(),
        cfg.effective_w(),
        cfg.effective_r().max(1),
        cfg.sampling(),
    );
    if let Some(budget) = cache_budget {
        workset = workset.with_budget(budget);
    }
    let workset = Arc::new(workset);
    let ctrl = Arc::new(Ctrl::default());
    let cosine = Arc::new(Mutex::new(CosineRecorder::default()));
    let loss_ema = Arc::new(Mutex::new(Ema::new(0.95)));

    // ---- local worker ------------------------------------------------------
    let local_handle = if cfg.effective_r() > 0 {
        let runtime = runtime.clone();
        let workset = workset.clone();
        let ctrl = ctrl.clone();
        let share = feed.share();
        let cosine = cosine.clone();
        let loss_ema = loss_ema.clone();
        Some(std::thread::Builder::new()
            .name("label-party-local".into())
            .spawn(move || -> anyhow::Result<u64> {
                let mut steps = 0u64;
                let mut scratch = GatherScratch::default();
                while !ctrl.stopped() {
                    // Park through §3.2 bubbles; `insert` notifies. The
                    // sampled entry carries the aggregate Σ_k Z_k.
                    match workset.sample_or_wait(BUBBLE_PARK)? {
                        Some(e) => {
                            // Entries below the feed's window floor were
                            // cached against rows a streaming feed has
                            // dropped — skip them (in-memory: floor 0).
                            let (table, floor) = share.snapshot();
                            if e.round < floor {
                                continue;
                            }
                            let (xb, y) = gather_b_with(&table, &e.indices,
                                                        &mut scratch);
                            let (loss, ws) = runtime
                                .lock()
                                .unwrap()
                                .local_step(&xb, &y, &e.za, &e.dza)?;
                            steps += 1;
                            cosine.lock().unwrap().push(steps, &ws);
                            loss_ema.lock().unwrap().push(loss as f64);
                        }
                        None => {}
                    }
                }
                Ok(steps)
            })?)
    } else {
        None
    };

    // ---- comm worker + control plane (this thread) -------------------------
    // The batch schedule is a pure function of (seed, round): on a
    // checkpoint resume the feed fast-forwards to the checkpoint round
    // so every party gathers the same instances for the same round
    // numbers.
    let mut scratch = GatherScratch::default();
    let eval_batches = eval_batch_count(cfg, test.n, batch);
    let start = Instant::now();
    let mut series: Vec<SeriesPoint> = Vec::new();
    let mut stop_reason = StopReason::MaxRounds;
    let mut comm_rounds = start_round;
    let mut lanes = LaneSet::new(cfg, links, readmission);
    if let Some(reg) = registry {
        lanes = lanes.with_registry(reg);
    }

    // Trainer instruments (DESIGN.md §10): round wall-clock and cache
    // fill, exported by both the scrape and watch paths. Names are
    // pinned by the Prometheus golden fixture.
    let round_seconds = lanes.registry().histogram("celu_round_seconds");
    let workset_fill = lanes.registry().gauge("celu_workset_fill");

    let result: anyhow::Result<()> = (|| {
        lanes.handshake(
            cfg,
            resume.as_ref().map(|s| s.links.as_slice()),
        )?;
        for round in start_round..cfg.max_rounds as u64 {
            let round_start = Instant::now();
            let (idx, xb, y) = feed.batch(round, &mut scratch)?;
            // Collect this round's activation from every lane: fresh
            // when the peer delivered inside the straggler budget,
            // stale (its cached last activation — weighted down by the
            // staleness machinery) when it is behind or lost.
            let inputs = lanes.collect(round)?;
            let zas: Vec<Tensor> = inputs
                .iter()
                .filter_map(|i| i.tensor().cloned())
                .collect();
            // Σ_k Z_k — with one lane this is the lane's own handle
            // (no copy), so the two-party exact step is unchanged.
            let zsum = Tensor::sum_f32(&zas)?;
            let (dza, loss) = runtime
                .lock()
                .unwrap()
                .exact_step(&xb, &y, &zsum)?;
            if cfg.compute_delay_s > 0.0 {
                // Optional artificial compute cost (comm:compute ratio
                // studies — see DESIGN.md §3).
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    cfg.compute_delay_s));
            }
            loss_ema.lock().unwrap().push(loss as f64);
            // Cache first (identity: handle share, no payload copy;
            // lossy: that link's dequantized round-trip the peer will
            // also see), then occupy the WANs: the local worker trains
            // on round `i`'s statistics while the derivatives are
            // still in flight. ∂L/∂Z_k is the same for every k, so one
            // exact step serves every outgoing frame.
            let views = lanes.stage_derivatives(round, &dza)?;
            if inputs.iter().all(|i| i.tensor().is_some()) {
                let cached: Vec<(Tensor, Tensor)> = inputs
                    .into_iter()
                    .zip(views)
                    .map(|(input, view)| match input {
                        LaneInput::Fresh(t) | LaneInput::Stale(t) => {
                            (t, view)
                        }
                        LaneInput::Missing => unreachable!(
                            "all inputs checked to carry tensors"),
                    })
                    .collect();
                workset.insert(round, idx, cached);
                // Streaming feeds advance their window floor as chunks
                // are consumed; cached entries from dropped windows
                // must stop being sampled (in-memory: floor 0, no-op).
                workset.retire_below(feed.floor());
            } else {
                // A lane that never contributed has no Z_k to cache; a
                // partial K-tuple would desynchronize the per-peer
                // workset lanes, so this round is not cached at all.
                log::debug!(
                    "round {round}: cache insert skipped (a lane has \
                     no statistics yet)"
                );
            }
            lanes.send_staged(round)?;
            comm_rounds = round + 1;
            round_seconds.observe(round_start.elapsed().as_secs_f64());
            workset_fill.set(workset.fill());

            // Checkpoint lane (DESIGN.md §8): snapshot after the round
            // completes, so a restart replays from a round boundary.
            if !cfg.checkpoint_dir.is_empty()
                && comm_rounds % cfg.checkpoint_every as u64 == 0
            {
                let (params, accs) =
                    runtime.lock().unwrap().state.export()?;
                let snap = SessionSnapshot {
                    epoch: lanes.epoch(),
                    round: comm_rounds,
                    parties: cfg.parties as u16,
                    links: lanes.codec_states(),
                    params,
                    accs,
                };
                // A failed write degrades durability, not the session:
                // bounded retry, then log + event and keep training.
                // `save_with_retry` emits the checkpoint event itself
                // into the registry sink.
                match save_with_retry(comm_rounds,
                                      lanes.registry().as_ref(),
                                      || snap.save(&cfg.checkpoint_dir))
                {
                    Ok(path) => log::info!("checkpoint written: {path}"),
                    Err(e) => log::warn!(
                        "checkpoint at round {comm_rounds} failed \
                         (training continues without it): {e:#}"
                    ),
                }
            }

            // Eval lane + stop decision. Only lanes in lock-step at
            // this round participate; a degraded mesh skips scoring
            // (the eval frames of behind lanes are discarded by later
            // drains, so the round clock stays consistent).
            if comm_rounds % cfg.eval_every as u64 == 0 {
                let mut participants = lanes.current_lanes(round);
                let expected = participants.len();
                let mut complete =
                    expected == lanes.len() && expected > 0;
                let mut scores = Vec::with_capacity(eval_batches * batch);
                let mut labels = Vec::with_capacity(eval_batches * batch);
                for k in 0..eval_batches {
                    if participants.is_empty() {
                        complete = false;
                        break;
                    }
                    let zs = lanes.collect_eval(
                        &mut participants, k as u64, round)?;
                    if zs.len() != expected {
                        complete = false;
                    }
                    if !complete || zs.is_empty() {
                        // Frames still had to be drained for wire
                        // consistency, but an incomplete eval is
                        // discarded anyway — don't burn accelerator
                        // executions on scores that can't be used.
                        continue;
                    }
                    let idx: Vec<u32> = ((k * batch) as u32
                        ..((k + 1) * batch) as u32)
                        .collect();
                    let (xb, y) = gather_b_with(&test, &idx, &mut scratch);
                    let za = Tensor::sum_f32(&zs)?;
                    let yhat =
                        runtime.lock().unwrap().eval(&xb, &za)?;
                    scores.extend(yhat);
                    labels.extend_from_slice(y.as_f32()?);
                }
                if complete {
                    let auc = auc_exact(&scores, &labels);
                    let rt = runtime.lock().unwrap();
                    let updates = rt.exact_updates + rt.local_updates;
                    drop(rt);
                    let point = SeriesPoint {
                        comm_round: comm_rounds,
                        wall_s: start.elapsed().as_secs_f64(),
                        auc,
                        loss: loss_ema.lock().unwrap().get(),
                        updates,
                    };
                    log::info!(
                        "[{}] round {:>6}  auc {:.4}  loss {:.4}  \
                         updates {}",
                        cfg.algorithm.name(), comm_rounds, auc,
                        point.loss, updates
                    );
                    series.push(point);
                    if cfg.target_auc > 0.0 && auc >= cfg.target_auc {
                        stop_reason = StopReason::TargetAuc;
                        return Ok(());
                    }
                } else {
                    log::warn!(
                        "eval at round {comm_rounds} skipped: the mesh \
                         is {} — scoring a partial Σ_k would not be \
                         comparable", lanes.state().label()
                    );
                }
                // The wall-clock budget doesn't depend on scores: it
                // must hold even when the mesh is degraded and evals
                // are being skipped (same boundary cadence as the
                // historic loop).
                if cfg.max_seconds > 0.0
                    && start.elapsed().as_secs_f64() >= cfg.max_seconds
                {
                    stop_reason = StopReason::TimeBudget;
                    return Ok(());
                }
            }
        }
        Ok(())
    })();
    // Broadcast shutdown on every link regardless of how we exited, and
    // close the lifecycle.
    lanes.shutdown();
    ctrl.stop();
    workset.wake_all(); // unpark a local worker sleeping through a bubble
    let local_updates = match local_handle {
        Some(h) => h.join().expect("label party local worker panicked")?,
        None => 0,
    };
    result?;
    debug_assert_eq!(lanes.state(), SessionState::Done);

    let exact_updates = runtime.lock().unwrap().exact_updates;
    let ws_stats = workset.stats();
    let cosine = Arc::try_unwrap(cosine)
        .map(|m| m.into_inner().unwrap())
        .unwrap_or_default();
    let rejoins = lanes.total_rejoins();
    Ok(LabelPartyReport {
        comm_rounds,
        exact_updates,
        local_updates,
        workset: ws_stats,
        cosine,
        series,
        stop_reason,
        rejoins,
    })
}
