//! Label-party driver: features + labels, bottom and top models, and
//! the run's control plane (loss tracking, AUC evaluation, stopping).
//! Aggregates over a whole mesh of feature parties: the top model
//! consumes Σ_k Z_k, and since ∂L/∂Z_k = ∂L/∂(Σ_j Z_j) for the sum
//! aggregation, the same derivative frame fans out to every peer — the
//! standard K-party topology (C-VFL). With one link this is exactly the
//! PR-1/PR-2 Party B, byte for byte.
//!
//! Comm worker, per round: recv Z_k from each activation lane → exact
//! step on Σ_k Z_k (computes loss + ∇Z, updates θ_B/θ_top) → cache
//! ⟨i, Z_k, ∇Z⟩ into each peer's workset lane → fan the derivative out.
//! Local worker: local steps against the cached aggregate statistics
//! (Algorithm 2, LocalUpdatePartyB) via [`MeshWorkset`], which keeps
//! one [`crate::workset::WorksetTable`] lane per peer in lock-step so
//! uniform sampling and instance weighting stay per-link exact. The
//! label party owns the stop decision and broadcasts Shutdown on every
//! link.
//!
//! The cache insert happens *before* the (WAN-bound) sends: the entries'
//! tensors are `Arc`-shared with the outgoing messages rather than
//! copied, and the local worker can already consume the fresh statistics
//! while the derivatives are still occupying the links (DESIGN.md §4).
//!
//! The `Hello` capabilities handshake is answered **per link**,
//! whenever that peer initiates it — even when this party itself is
//! configured uncompressed — and derivative sends are routed through
//! `protocol::outbound_stats` under each link's negotiated codec,
//! caching that link's dequantized round-trip (DESIGN.md §5). A plain
//! first frame on a link means a pre-handshake peer: that link stays on
//! the identity codec and its wire behaviour is byte-identical to PR 1.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::compress::{self, CodecKind};
use crate::config::RunConfig;
use crate::data::batcher::{gather_b_with, BatchCursor, GatherScratch};
use crate::data::PartyBData;
use crate::metrics::{auc_exact, CosineRecorder, SeriesPoint};
use crate::protocol::{outbound_stats, Lane, Message};
use crate::runtime::{ArtifactSet, PartyBRuntime};
use crate::session::{Link, PartyId};
use crate::tensor::Tensor;
use crate::transport::Transport;
use crate::util::stats::Ema;
use crate::workset::{MeshWorkset, WorksetStats};

use super::{eval_batch_count, Ctrl, BUBBLE_PARK};

/// Everything the label party reports after a run.
#[derive(Debug, Default)]
pub struct LabelPartyReport {
    pub comm_rounds: u64,
    pub exact_updates: u64,
    pub local_updates: u64,
    pub workset: WorksetStats,
    pub cosine: CosineRecorder,
    pub series: Vec<SeriesPoint>,
    /// Why the run ended.
    pub stop_reason: StopReason,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StopReason {
    #[default]
    MaxRounds,
    TargetAuc,
    TimeBudget,
}

/// One activation lane: the peer, its transport, the codec negotiated
/// on this link, and the round-0 replay slot for pre-handshake peers.
struct LaneState {
    peer: PartyId,
    transport: Arc<dyn Transport>,
    codec: CodecKind,
    replay: Option<Message>,
}

/// Fan one frame out per lane. The star's links are independent, and
/// `Transport::send` charges the (simulated or real) link occupancy
/// inline — sending lane-by-lane would serialize K−1 transfers that
/// real hardware carries concurrently and overstate K-party comm time
/// by (K−1)×. One lane takes the direct call (the two-party path,
/// thread-free and behaviourally identical to the historic Party B);
/// more fan out on scoped sender threads, one per link.
fn send_fanout(lanes: &[LaneState], mut frames: Vec<Message>)
               -> anyhow::Result<()> {
    debug_assert_eq!(lanes.len(), frames.len());
    if frames.len() == 1 {
        return lanes[0].transport.send(frames.pop().expect("one frame"));
    }
    std::thread::scope(|s| -> anyhow::Result<()> {
        let senders: Vec<_> = lanes
            .iter()
            .zip(frames)
            .map(|(lane, frame)| {
                s.spawn(move || lane.transport.send(frame))
            })
            .collect();
        for sender in senders {
            sender.join().expect("derivative sender panicked")?;
        }
        Ok(())
    })
}

pub fn run_label_party(
    cfg: &RunConfig,
    set: Arc<ArtifactSet>,
    train: Arc<PartyBData>,
    test: Arc<PartyBData>,
    links: &[Link],
) -> anyhow::Result<LabelPartyReport> {
    anyhow::ensure!(!links.is_empty(),
                    "label party needs at least one feature link");
    let batch = set.manifest.batch;
    let runtime = Arc::new(Mutex::new(PartyBRuntime::new(
        set.clone(),
        // The label party's init stream must differ from the feature
        // parties' but the *batch schedule* seed must match: all derive
        // from cfg.seed.
        cfg.seed,
        cfg.lr as f32,
        cfg.cos_xi() as f32,
        cfg.weighting_enabled(),
    )?));
    let workset = Arc::new(MeshWorkset::new(
        links.len(),
        cfg.effective_w(),
        cfg.effective_r().max(1),
        cfg.sampling(),
    ));
    let ctrl = Arc::new(Ctrl::default());
    let cosine = Arc::new(Mutex::new(CosineRecorder::default()));
    let loss_ema = Arc::new(Mutex::new(Ema::new(0.95)));

    // ---- local worker ------------------------------------------------------
    let local_handle = if cfg.effective_r() > 0 {
        let runtime = runtime.clone();
        let workset = workset.clone();
        let ctrl = ctrl.clone();
        let train = train.clone();
        let cosine = cosine.clone();
        let loss_ema = loss_ema.clone();
        Some(std::thread::Builder::new()
            .name("label-party-local".into())
            .spawn(move || -> anyhow::Result<u64> {
                let mut steps = 0u64;
                let mut scratch = GatherScratch::default();
                while !ctrl.stopped() {
                    // Park through §3.2 bubbles; `insert` notifies. The
                    // sampled entry carries the aggregate Σ_k Z_k.
                    match workset.sample_or_wait(BUBBLE_PARK)? {
                        Some(e) => {
                            let (xb, y) = gather_b_with(&train, &e.indices,
                                                        &mut scratch);
                            let (loss, ws) = runtime
                                .lock()
                                .unwrap()
                                .local_step(&xb, &y, &e.za, &e.dza)?;
                            steps += 1;
                            cosine.lock().unwrap().push(steps, &ws);
                            loss_ema.lock().unwrap().push(loss as f64);
                        }
                        None => {}
                    }
                }
                Ok(steps)
            })?)
    } else {
        None
    };

    // ---- comm worker + control plane (this thread) -------------------------
    let mut cursor = BatchCursor::new(cfg.seed, train.n, batch);
    let mut scratch = GatherScratch::default();
    let eval_batches = eval_batch_count(cfg, test.n, batch);
    let start = Instant::now();
    let mut series: Vec<SeriesPoint> = Vec::new();
    let mut stop_reason = StopReason::MaxRounds;
    let mut comm_rounds = 0u64;

    let result: anyhow::Result<()> = (|| {
        // Handshake, per link: feature parties speak first. A `Hello`
        // is answered with our capabilities (whether or not we were
        // configured to compress); any other first frame is a
        // pre-handshake peer and is replayed into round 0 below with
        // the identity codec. Links negotiate independently — one
        // compressed peer does not force (or break) another.
        let mut lanes: Vec<LaneState> = Vec::with_capacity(links.len());
        for link in links {
            let requested = cfg.codec_for(link.peer.0);
            let mut replay = None;
            let codec = match link.transport.recv()? {
                Message::Hello { codecs: peer } => {
                    link.transport.send(Message::Hello {
                        codecs: compress::supported_mask(),
                    })?;
                    let eff = compress::negotiate(requested, Some(peer));
                    if eff != requested {
                        log::warn!(
                            "[{}] peer cannot decode codec {} \
                             (mask {peer:#x}) — sending uncompressed",
                            link.peer,
                            requested.label()
                        );
                    }
                    eff
                }
                first => {
                    if requested != CodecKind::Identity {
                        // The label party cannot initiate (feature
                        // parties speak first in the lock-step
                        // protocol): a plain first frame means the peer
                        // predates or didn't request compression, so
                        // this link's request is dropped — loudly, not
                        // silently.
                        log::warn!(
                            "[{}] compress = {} requested but peer \
                             opened without a handshake — sending \
                             uncompressed",
                            link.peer,
                            requested.label()
                        );
                    }
                    replay = Some(first);
                    CodecKind::Identity
                }
            };
            lanes.push(LaneState {
                peer: link.peer,
                transport: link.transport.clone(),
                codec,
                replay,
            });
        }
        for round in 0..cfg.max_rounds as u64 {
            let idx = cursor.next_indices();
            let (xb, y) = gather_b_with(&train, &idx, &mut scratch);
            // Collect this round's activation from every lane (the
            // protocol is lock-step per link, so lane order is just a
            // join order, not a scheduling constraint).
            let mut zas: Vec<Tensor> = Vec::with_capacity(lanes.len());
            for lane in lanes.iter_mut() {
                let msg = match lane.replay.take() {
                    Some(m) => m,
                    None => lane.transport.recv()?,
                };
                let za = match msg.into_plain()? {
                    Message::Activation { round: r, tensor } => {
                        anyhow::ensure!(
                            r == round,
                            "protocol skew on {}: got activation {r}, \
                             expected {round}", lane.peer
                        );
                        tensor
                    }
                    other => anyhow::bail!(
                        "unexpected message {:?} from {} in round \
                         {round}", other.tag(), lane.peer),
                };
                zas.push(za);
            }
            // Σ_k Z_k — with one lane this is the lane's own handle
            // (no copy), so the two-party exact step is unchanged.
            let zsum = Tensor::sum_f32(&zas)?;
            let (dza, loss) = runtime
                .lock()
                .unwrap()
                .exact_step(&xb, &y, &zsum)?;
            if cfg.compute_delay_s > 0.0 {
                // Optional artificial compute cost (comm:compute ratio
                // studies — see DESIGN.md §3).
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    cfg.compute_delay_s));
            }
            loss_ema.lock().unwrap().push(loss as f64);
            // Cache first (identity: handle share, no payload copy;
            // lossy: that link's dequantized round-trip the peer will
            // also see), then occupy the WANs: the local worker trains
            // on round `i`'s statistics while the derivatives are
            // still in flight. ∂L/∂Z_k is the same for every k, so one
            // exact step serves every outgoing frame.
            let mut outgoing = Vec::with_capacity(lanes.len());
            let mut cached = Vec::with_capacity(lanes.len());
            for (lane, za_k) in lanes.iter().zip(zas) {
                let (dmsg, dza_k) = outbound_stats(
                    lane.codec, Lane::Derivative, round, dza.clone())?;
                outgoing.push(dmsg);
                cached.push((za_k, dza_k));
            }
            workset.insert(round, idx, cached);
            send_fanout(&lanes, outgoing)?;
            comm_rounds = round + 1;

            // Eval lane + stop decision.
            if comm_rounds % cfg.eval_every as u64 == 0 {
                let mut scores = Vec::with_capacity(eval_batches * batch);
                let mut labels = Vec::with_capacity(eval_batches * batch);
                for k in 0..eval_batches {
                    let idx: Vec<u32> = ((k * batch) as u32
                        ..((k + 1) * batch) as u32)
                        .collect();
                    let (xb, y) = gather_b_with(&test, &idx, &mut scratch);
                    let mut zs: Vec<Tensor> =
                        Vec::with_capacity(lanes.len());
                    for lane in lanes.iter() {
                        let za = match lane.transport.recv()?
                            .into_plain()?
                        {
                            Message::EvalActivation { round: r, tensor } =>
                            {
                                anyhow::ensure!(
                                    r == k as u64,
                                    "eval lane skew on {}: {r} != {k}",
                                    lane.peer
                                );
                                tensor
                            }
                            other => anyhow::bail!(
                                "expected eval activation from {}, got \
                                 {:?}", lane.peer, other.tag()),
                        };
                        zs.push(za);
                    }
                    let za = Tensor::sum_f32(&zs)?;
                    let yhat =
                        runtime.lock().unwrap().eval(&xb, &za)?;
                    scores.extend(yhat);
                    labels.extend_from_slice(y.as_f32()?);
                }
                let auc = auc_exact(&scores, &labels);
                let rt = runtime.lock().unwrap();
                let updates = rt.exact_updates + rt.local_updates;
                drop(rt);
                let point = SeriesPoint {
                    comm_round: comm_rounds,
                    wall_s: start.elapsed().as_secs_f64(),
                    auc,
                    loss: loss_ema.lock().unwrap().get(),
                    updates,
                };
                log::info!(
                    "[{}] round {:>6}  auc {:.4}  loss {:.4}  updates {}",
                    cfg.algorithm.name(), comm_rounds, auc, point.loss,
                    updates
                );
                series.push(point);
                if cfg.target_auc > 0.0 && auc >= cfg.target_auc {
                    stop_reason = StopReason::TargetAuc;
                    return Ok(());
                }
                if cfg.max_seconds > 0.0
                    && start.elapsed().as_secs_f64() >= cfg.max_seconds
                {
                    stop_reason = StopReason::TimeBudget;
                    return Ok(());
                }
            }
        }
        Ok(())
    })();
    // Broadcast shutdown on every link regardless of how we exited.
    for link in links {
        let _ = link.transport.send(Message::Shutdown);
    }
    ctrl.stop();
    workset.wake_all(); // unpark a local worker sleeping through a bubble
    let local_updates = match local_handle {
        Some(h) => h.join().expect("label party local worker panicked")?,
        None => 0,
    };
    result?;

    let exact_updates = runtime.lock().unwrap().exact_updates;
    let ws_stats = workset.stats();
    let cosine = Arc::try_unwrap(cosine)
        .map(|m| m.into_inner().unwrap())
        .unwrap_or_default();
    Ok(LabelPartyReport {
        comm_rounds,
        exact_updates,
        local_updates,
        workset: ws_stats,
        cosine,
        series,
        stop_reason,
    })
}
