//! Feature-party driver: one vertical feature slice, no labels, no top
//! model. Parameterized by [`PartyId`] — a K-party session runs K−1
//! instances of this driver, each over its own link to the label party;
//! `parties = 2` runs exactly one and reproduces the PR-1/PR-2 Party A
//! byte stream bit-for-bit.
//!
//! Comm worker: forward → send Z_k → (overlapped) → recv ∇Z → exact
//! update → cache. Local worker: drain the workset with round-robin
//! sampling + instance-weighted local updates (Algorithm 2,
//! LocalUpdatePartyA). The workers share the runtime (params) and the
//! workset table; while the comm worker is blocked on the WAN the local
//! worker keeps the accelerator busy — the paper's §3.1 overlap.
//!
//! Statistics move zero-copy end-to-end (DESIGN.md §4): the forward
//! activations are shared between the outgoing message and the workset
//! entry through one `Arc` allocation, local-update sampling returns
//! handles instead of deep clones, and gathers recycle their destination
//! buffers across rounds.
//!
//! Codec negotiation (DESIGN.md §5): when the bootstrap carried the
//! label party's codec mask (`Link::peer_codecs`), the wire codec is
//! pre-negotiated at join time and no `Hello` is sent at all; mask-less
//! links keep the historic in-band handshake (initiated only when this
//! party's codec — session `compress` or its `[party.<id>]` override —
//! asks for compression, so an identity config stays byte-identical).
//! Either way every outgoing statistic routes through
//! `protocol::outbound_stats`, caching the dequantized round-trip so
//! this party trains on exactly the tensors the label party decodes.
//!
//! Supervised lifecycle (DESIGN.md §8): with a [`RejoinPolicy`], a
//! transport failure mid-session does not kill the run — the local
//! worker keeps draining the workset cache (CELU-VFL's whole premise)
//! while the comm worker re-dials the label party's re-admission point
//! with a `Rejoin` frame, consumes any replayed in-flight derivative,
//! fast-forwards its batch cursor to the acked resume round, and
//! re-enters lock-step.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::compress::{self, CodecKind};
use crate::config::RunConfig;
use crate::data::batcher::{gather_a_with, GatherScratch};
use crate::data::PartyAData;
use crate::dataset::{corrupt_tokens, FeatureFeed};
use crate::metrics::facade::{CounterSink, EventSink, NullSink, Registry};
use crate::metrics::CosineRecorder;
use crate::protocol::{outbound_stats, Lane, Message};
use crate::runtime::{ArtifactSet, PartyARuntime};
use crate::session::bootstrap::rejoin_dial;
use crate::session::checkpoint::{save_with_retry, FeatureSnapshot};
use crate::session::supervisor::session_epoch;
use crate::session::{Link, PartyId, LABEL_PARTY};
use crate::tensor::Tensor;
use crate::transport::Transport;
use crate::util::rng::Pcg;
use crate::workset::{MeshWorkset, WorksetStats};

use super::{eval_batch_count, feature_seed, Ctrl, BUBBLE_PARK};

/// Token-corruption probability of the denoising SSL step (DESIGN.md
/// §12). Fixed rather than configurable: the step is a regularizer, and
/// one fewer knob keeps the lock-step config surface small.
const SSL_CORRUPT_RATE: f32 = 0.15;

/// Pcg stream for the feature party's SSL corruption draws — disjoint
/// from the feed's reservoir stream and every schedule stream.
const SSL_NOISE_STREAM: u64 = 0x55e1_c0de_0f_a015;

/// How a feature party gets back into a session it fell out of.
#[derive(Debug, Clone)]
pub struct RejoinPolicy {
    /// The label party's listener address (its re-admission point).
    pub addr: String,
    /// Overall budget for one reconnect attempt (dial backoff + ack).
    pub timeout: Duration,
}

/// Supervised-lifecycle options for a feature run. Defaults reproduce
/// the historic behaviour: no reconnects, start at round 0.
#[derive(Clone, Default)]
pub struct FeatureRunOpts {
    /// Reconnect policy; `None` propagates transport errors (historic).
    pub rejoin: Option<RejoinPolicy>,
    /// First round to run — non-zero when joining a session resumed
    /// from a checkpoint (`SessionDialer::establish_resumable`).
    pub start_round: u64,
    /// Restart from this party's own checkpoint: bottom-model params
    /// and AdaGrad accumulators are imported and the wire codec is
    /// pinned from the snapshot (no renegotiation — the label party's
    /// lane kept its codec across the rejoin).
    pub resume: Option<FeatureSnapshot>,
    /// Publish this party's link accounting into this registry (the
    /// observability plane — DESIGN.md §10). Rejoin transport swaps
    /// re-bind here with the old counters charged forward, so the
    /// registry row stays cumulative across swaps.
    pub registry: Option<Arc<Registry>>,
}

/// Everything a feature party reports after a run. Link accounting is
/// NOT carried here by value any more — it lives in the run's
/// [`Registry`] (the `(party → label)` row of
/// [`Registry::link_rows`]).
#[derive(Debug)]
pub struct FeaturePartyReport {
    pub party: PartyId,
    pub comm_rounds: u64,
    pub exact_updates: u64,
    pub local_updates: u64,
    /// Self-supervised denoising updates on unaligned rows (zero wire
    /// traffic — DESIGN.md §12). 0 unless the feed carries an SSL pool.
    pub ssl_updates: u64,
    pub workset: WorksetStats,
    pub cosine: CosineRecorder,
    /// Successful re-admissions performed during the run.
    pub rejoins: u64,
}

/// Run feature party `party` to completion (until Shutdown from the
/// label party, a transport error with no rejoin policy, or a failed
/// rejoin) over its mesh link.
///
/// Training rows arrive through `feed` — either the in-memory feed
/// (historic behaviour, byte-identical wire) or a streaming feed over
/// an on-disk table (DESIGN.md §12). The feed also decides whether the
/// party does self-supervised work: when it pools unaligned rows,
/// every communication round is followed by `cfg.ssl_ratio` denoising
/// local updates that never touch the wire.
pub fn run_feature_party(
    cfg: &RunConfig,
    party: PartyId,
    set: Arc<ArtifactSet>,
    mut feed: FeatureFeed,
    test: Arc<PartyAData>,
    link: &Link,
    opts: FeatureRunOpts,
) -> anyhow::Result<FeaturePartyReport> {
    let batch = set.manifest.batch;
    let runtime = Arc::new(Mutex::new(PartyARuntime::new(
        set.clone(),
        // Party 1 seeds exactly as the historic Party A (bit-identical
        // two-party runs); later parties decorrelate their init stream.
        feature_seed(cfg.seed, party),
        cfg.lr as f32,
        cfg.cos_xi() as f32,
        cfg.weighting_enabled(),
    )?));
    if let Some(snap) = &opts.resume {
        runtime
            .lock()
            .unwrap()
            .state
            .import(&snap.params, &snap.accs)?;
        log::info!(
            "[{party}] restored {} params and {} AdaGrad accumulators \
             from a round-{} snapshot",
            snap.params.len(), snap.accs.len(), snap.round
        );
    }
    // Single-lane mesh workset: the feature party has one peer (the
    // label party), so this is exactly the historic shared workset —
    // same policy, same condvar parking, zero-copy handles.
    let workset = Arc::new(MeshWorkset::new(
        1,
        cfg.effective_w(),
        cfg.effective_r().max(1),
        cfg.sampling(),
    ));
    let ctrl = Arc::new(Ctrl::default());
    let cosine = Arc::new(Mutex::new(CosineRecorder::default()));

    // ---- local worker ----------------------------------------------------
    let local_handle = if cfg.effective_r() > 0 {
        let runtime = runtime.clone();
        let workset = workset.clone();
        let ctrl = ctrl.clone();
        let share = feed.share();
        let cosine = cosine.clone();
        Some(std::thread::Builder::new()
            .name(format!("feature-{}-local", party.0))
            .spawn(move || -> anyhow::Result<u64> {
                let mut steps = 0u64;
                let mut scratch = GatherScratch::default();
                while !ctrl.stopped() {
                    // §3.2 bubble handling: park on the workset condvar
                    // until the comm worker inserts (or the timeout
                    // elapses, re-checking the stop flag) — no busy-wait.
                    match workset.sample_or_wait(BUBBLE_PARK)? {
                        Some(e) => {
                            // A consistent (table, floor) snapshot: an
                            // entry below the floor was cached against
                            // a window the streaming feed has dropped —
                            // its indices no longer address these rows.
                            // (In-memory feeds never move the floor.)
                            let (table, floor) = share.snapshot();
                            if e.round < floor {
                                continue;
                            }
                            let xa = gather_a_with(&table, &e.indices,
                                                   &mut scratch);
                            let ws = runtime
                                .lock()
                                .unwrap()
                                .local_update(&xa, &e.za, &e.dza)?;
                            steps += 1;
                            cosine.lock().unwrap().push(steps, &ws);
                        }
                        None => {}
                    }
                }
                Ok(steps)
            })?)
    } else {
        None
    };

    // ---- comm worker (this thread) ----------------------------------------
    let mut scratch = GatherScratch::default();
    let mut ssl_rng =
        Pcg::new(feature_seed(cfg.seed, party), SSL_NOISE_STREAM);
    let vocab = set.manifest.vocab;
    let eval_batches = eval_batch_count(cfg, test.n, batch);
    let mut comm_rounds = opts.start_round;
    let mut transport: Arc<dyn Transport> = link.transport.clone();
    let mut rejoins = 0u64;
    let epoch = session_epoch(cfg.seed);
    let requested = cfg.codec_for(party.0);
    // Checkpoint events on the feature side bump the registry's kind
    // counters only: the bounded event *log* is the label party's
    // lifecycle record, and an in-proc run shares one registry across
    // all K parties.
    let ckpt_sink: Arc<dyn EventSink> = match &opts.registry {
        Some(reg) => Arc::new(CounterSink(reg.clone())),
        None => Arc::new(NullSink),
    };
    let result: anyhow::Result<()> = (|| {
        // Codec handshake. A snapshot resume pins the codec the
        // original join negotiated (the label's lane kept it across
        // the rejoin, so renegotiating could desynchronize the wire).
        // Join-time masks pre-negotiate without any wire exchange;
        // otherwise the historic in-band Hello runs — only when
        // compression is requested, so an identity config keeps the
        // wire byte stream exactly as before.
        let codec = if let Some(snap) = &opts.resume {
            snap.codec
        } else if let Some(mask) = link.peer_codecs {
            let eff = compress::negotiate(requested, Some(mask));
            if eff != requested {
                log::warn!(
                    "[{party}] peer cannot decode codec {} (join-time \
                     mask {mask:#x}) — sending uncompressed",
                    requested.label()
                );
            }
            eff
        } else if requested != CodecKind::Identity {
            transport.send(Message::Hello {
                codecs: compress::supported_mask(),
            })?;
            match transport.recv()? {
                Message::Hello { codecs } => {
                    let eff = compress::negotiate(requested, Some(codecs));
                    if eff != requested {
                        log::warn!(
                            "[{party}] peer cannot decode codec {} \
                             (mask {codecs:#x}) — sending uncompressed",
                            requested.label()
                        );
                    }
                    eff
                }
                Message::Shutdown => return Ok(()),
                other => anyhow::bail!(
                    "expected Hello reply, got {:?}", other.tag()),
            }
        } else {
            CodecKind::Identity
        };
        // The feed fast-forwards its deterministic schedule to the
        // first round this party runs (non-zero when the session
        // resumed from a checkpoint).
        let mut round: u64 = opts.start_round;
        // The in-flight round preserved across a rejoin, so the round
        // can be re-run (or its replayed derivative applied) without
        // re-sampling the schedule.
        struct PendingRound {
            round: u64,
            idx: Vec<u32>,
            xa: Tensor,
            za: Tensor,
        }
        let mut pending: Option<PendingRound> = None;
        // One reconnect: dial the re-admission point, swap transports,
        // consume replays. Returns the resume round.
        // (Free-standing closure so both the send and recv failure
        // sites share it.)
        let do_rejoin = |err: &anyhow::Error,
                             transport: &mut Arc<dyn Transport>,
                             rejoins: &mut u64,
                             last_round: u64|
         -> anyhow::Result<(u64, u32)> {
            let Some(policy) = &opts.rejoin else {
                return Err(anyhow::anyhow!("{err:#}"));
            };
            log::warn!(
                "[{party}] link to the label party lost after {last_round} \
                 rounds: {err:#} — attempting rejoin at {}", policy.addr
            );
            let (t, resume, replays) = rejoin_dial(
                &policy.addr, party, cfg, epoch, last_round,
                policy.timeout,
            )?;
            // Charge the dead transport's totals onto the fresh one's
            // handles, then re-bind: the registry row (and any scrape
            // mid-swap) stays cumulative across the whole run.
            match t.metrics() {
                Some(h) => {
                    h.charge(transport.stats());
                    if let Some(reg) = &opts.registry {
                        reg.bind_link(party, LABEL_PARTY, &h);
                    }
                }
                None => log::warn!(
                    "[{party}] rejoin transport exposes no metrics \
                     handles — pre-rejoin accounting dropped"
                ),
            }
            *transport = t;
            *rejoins += 1;
            Ok((resume, replays))
        };
        // Where lock-step resumes after a rejoin. A resume round
        // *behind* our progress means the label restarted from a
        // checkpoint older than we got to: replay the deterministic
        // feed from round 0 (our model keeps the extra rounds'
        // updates; the staleness-tolerant algorithm absorbs that).
        // Streaming feeds cannot replay — `reset` fails the rejoin
        // loudly instead of silently desynchronizing the schedule.
        let resume_at = |resume: u64, feed: &mut FeatureFeed,
                         comm_rounds: &mut u64|
         -> anyhow::Result<u64> {
            if resume < *comm_rounds {
                log::warn!(
                    "[{party}] label resumed behind this party (round \
                     {resume} < {}) — rewinding the batch feed",
                    *comm_rounds
                );
                feed.reset()?;
                *comm_rounds = resume;
            }
            Ok(resume.max(*comm_rounds))
        };
        'rounds: while round < cfg.max_rounds as u64 {
            let (idx, xa, za_raw) = match pending.take() {
                Some(p) if p.round == round => (p.idx, p.xa, p.za),
                _ => {
                    let (idx, xa) = feed.batch(round, &mut scratch)?;
                    let za = runtime.lock().unwrap().forward(&xa)?;
                    (idx, xa, za)
                }
            };
            // Identity codec: the message and the workset entry below
            // share za's allocation — the clone is a refcount bump, not
            // a copy. Lossy codec: `za` is rebound to the dequantized
            // round-trip so the cache matches what the label decodes.
            let (msg, za) = outbound_stats(codec, Lane::Activation, round,
                                           za_raw.clone())?;
            if let Err(e) = transport.send(msg) {
                // The label never saw this round's activation, so no
                // replay can exist; re-run the round after rejoining
                // (or skip ahead to wherever the session got to).
                let (resume, _replays) = do_rejoin(
                    &e, &mut transport, &mut rejoins, comm_rounds)?;
                if resume == round {
                    pending = Some(PendingRound {
                        round, idx, xa, za: za_raw,
                    });
                }
                round = resume_at(resume, &mut feed, &mut comm_rounds)?;
                continue 'rounds;
            }
            // Block on ∇Z (the local worker keeps training meanwhile).
            let dza = match transport.recv() {
                Ok(m) => match m.into_plain()? {
                    Message::Derivative { round: r, tensor } => {
                        anyhow::ensure!(
                            r == round,
                            "protocol skew: got derivative {r}, \
                             expected {round}"
                        );
                        tensor
                    }
                    Message::Shutdown => return Ok(()),
                    other => anyhow::bail!(
                        "unexpected message {:?} in round {round}",
                        other.tag()),
                },
                Err(e) => {
                    let (resume, replays) = do_rejoin(
                        &e, &mut transport, &mut rejoins, comm_rounds)?;
                    // The label replays the in-flight round's
                    // derivative when it had consumed our activation
                    // before the drop.
                    let mut completed_inflight = false;
                    for _ in 0..replays {
                        match transport.recv()?.into_plain()? {
                            Message::Derivative { round: r, tensor } => {
                                if r == round {
                                    runtime
                                        .lock()
                                        .unwrap()
                                        .exact_update(&xa, &tensor)?;
                                    workset.insert(
                                        round,
                                        idx.clone(),
                                        vec![(za.clone(), tensor)],
                                    );
                                    comm_rounds = round + 1;
                                    completed_inflight = true;
                                } else {
                                    log::warn!(
                                        "[{party}] replayed derivative \
                                         for round {r} no longer \
                                         applies (in-flight round was \
                                         {round}) — dropped"
                                    );
                                }
                            }
                            Message::Shutdown => return Ok(()),
                            other => anyhow::bail!(
                                "unexpected replay message {:?}",
                                other.tag()),
                        }
                    }
                    if !completed_inflight && resume == round {
                        pending = Some(PendingRound {
                            round, idx, xa, za: za_raw,
                        });
                    }
                    round = resume_at(resume, &mut feed, &mut comm_rounds)?;
                    continue 'rounds;
                }
            };
            runtime.lock().unwrap().exact_update(&xa, &dza)?;
            workset.insert(round, idx, vec![(za, dza)]);
            comm_rounds = round + 1;
            // Streaming feeds move their window floor as chunks are
            // consumed; entries cached against dropped windows must
            // stop being sampled (in-memory: floor stays 0 — no-op).
            workset.retire_below(feed.floor());

            // SSL lane (DESIGN.md §12): label-free denoising updates on
            // the unaligned-row reservoir, interleaved at a fixed ratio
            // per communication round. Zero wire traffic by
            // construction — nothing here touches the transport.
            for _ in 0..cfg.ssl_ratio {
                let Some(clean) = feed.ssl_batch(&mut scratch) else {
                    break;
                };
                let noisy = corrupt_tokens(&clean, vocab,
                                           SSL_CORRUPT_RATE,
                                           &mut ssl_rng)?;
                runtime.lock().unwrap().ssl_update(&clean, &noisy)?;
            }

            // Checkpoint lane (DESIGN.md §9), symmetric to the label
            // party's §8 lane: snapshot at the round boundary so a
            // restart resumes from completed work. A failed write
            // degrades durability, never the session.
            if !cfg.checkpoint_dir.is_empty()
                && comm_rounds % cfg.checkpoint_every as u64 == 0
            {
                let (params, accs) =
                    runtime.lock().unwrap().state.export()?;
                let snap = FeatureSnapshot {
                    epoch,
                    round: comm_rounds,
                    parties: cfg.parties as u16,
                    party: party.0,
                    codec,
                    params,
                    accs,
                };
                match save_with_retry(comm_rounds, ckpt_sink.as_ref(),
                                      || snap.save(&cfg.checkpoint_dir))
                {
                    Ok(path) => log::info!(
                        "[{party}] checkpoint written: {path}"),
                    Err(e) => log::warn!(
                        "[{party}] checkpoint at round {comm_rounds} \
                         failed (training continues without it): {e:#}"
                    ),
                }
            }

            // Eval lane.
            if comm_rounds % cfg.eval_every as u64 == 0 {
                for k in 0..eval_batches {
                    let idx: Vec<u32> = ((k * batch) as u32
                        ..((k + 1) * batch) as u32)
                        .collect();
                    let xa = gather_a_with(&test, &idx, &mut scratch);
                    let za = runtime.lock().unwrap().forward(&xa)?;
                    let (msg, _) = outbound_stats(
                        codec, Lane::EvalActivation, k as u64, za)?;
                    if let Err(e) = transport.send(msg) {
                        // Abandon the eval walk (the label excludes
                        // this lane from the partial eval) and rejoin.
                        let (resume, _replays) = do_rejoin(
                            &e, &mut transport, &mut rejoins,
                            comm_rounds)?;
                        round = resume_at(resume, &mut feed,
                                          &mut comm_rounds)?;
                        continue 'rounds;
                    }
                }
            }
            round += 1;
        }
        // Round budget exhausted on this side; wait for the label
        // party's shutdown so the byte accounting stays complete.
        loop {
            match transport.recv() {
                Ok(Message::Shutdown) | Err(_) => return Ok(()),
                Ok(_) => {}
            }
        }
    })();
    ctrl.stop();
    workset.wake_all(); // unpark a local worker sleeping through a bubble
    let local_updates = match local_handle {
        Some(h) => h.join().expect("feature party local worker panicked")?,
        None => 0,
    };
    result?;

    let (exact_updates, ssl_updates) = {
        let rt = runtime.lock().unwrap();
        (rt.exact_updates, rt.ssl_updates)
    };
    let ws_stats = workset.stats();
    let cosine = Arc::try_unwrap(cosine)
        .map(|m| m.into_inner().unwrap())
        .unwrap_or_default();
    Ok(FeaturePartyReport {
        party,
        comm_rounds,
        exact_updates,
        local_updates,
        ssl_updates,
        workset: ws_stats,
        cosine,
        rejoins,
    })
}
