//! Feature-party driver: one vertical feature slice, no labels, no top
//! model. Parameterized by [`PartyId`] — a K-party session runs K−1
//! instances of this driver, each over its own link to the label party;
//! `parties = 2` runs exactly one and reproduces the PR-1/PR-2 Party A
//! byte stream bit-for-bit.
//!
//! Comm worker: forward → send Z_k → (overlapped) → recv ∇Z → exact
//! update → cache. Local worker: drain the workset with round-robin
//! sampling + instance-weighted local updates (Algorithm 2,
//! LocalUpdatePartyA). The workers share the runtime (params) and the
//! workset table; while the comm worker is blocked on the WAN the local
//! worker keeps the accelerator busy — the paper's §3.1 overlap.
//!
//! Statistics move zero-copy end-to-end (DESIGN.md §4): the forward
//! activations are shared between the outgoing message and the workset
//! entry through one `Arc` allocation, local-update sampling returns
//! handles instead of deep clones, and gathers recycle their destination
//! buffers across rounds.
//!
//! When this party's codec (session `compress`, or its `[party.<id>]`
//! override) asks for compression, the feature party initiates the
//! `Hello` capabilities handshake on its link before round 0 and then
//! routes every outgoing statistic through `protocol::outbound_stats`
//! (DESIGN.md §5): the workset caches the *dequantized* round-trip so
//! this party trains on exactly the tensors the label party decodes.
//! With the identity codec no `Hello` is sent and the wire + cache
//! behaviour is byte-identical to the two-party path.

use std::sync::{Arc, Mutex};

use crate::compress::{self, CodecKind};
use crate::config::RunConfig;
use crate::data::batcher::{gather_a_with, BatchCursor, GatherScratch};
use crate::data::PartyAData;
use crate::metrics::CosineRecorder;
use crate::protocol::{outbound_stats, Lane, Message};
use crate::runtime::{ArtifactSet, PartyARuntime};
use crate::session::PartyId;
use crate::transport::Transport;
use crate::workset::{MeshWorkset, WorksetStats};

use super::{eval_batch_count, feature_seed, Ctrl, BUBBLE_PARK};

/// Everything a feature party reports after a run.
#[derive(Debug)]
pub struct FeaturePartyReport {
    pub party: PartyId,
    pub comm_rounds: u64,
    pub exact_updates: u64,
    pub local_updates: u64,
    pub workset: WorksetStats,
    pub cosine: CosineRecorder,
}

/// Run feature party `party` to completion (until Shutdown from the
/// label party or transport error) over its single mesh link.
pub fn run_feature_party(
    cfg: &RunConfig,
    party: PartyId,
    set: Arc<ArtifactSet>,
    train: Arc<PartyAData>,
    test: Arc<PartyAData>,
    transport: Arc<dyn Transport>,
) -> anyhow::Result<FeaturePartyReport> {
    let batch = set.manifest.batch;
    let runtime = Arc::new(Mutex::new(PartyARuntime::new(
        set.clone(),
        // Party 1 seeds exactly as the historic Party A (bit-identical
        // two-party runs); later parties decorrelate their init stream.
        feature_seed(cfg.seed, party),
        cfg.lr as f32,
        cfg.cos_xi() as f32,
        cfg.weighting_enabled(),
    )?));
    // Single-lane mesh workset: the feature party has one peer (the
    // label party), so this is exactly the historic shared workset —
    // same policy, same condvar parking, zero-copy handles.
    let workset = Arc::new(MeshWorkset::new(
        1,
        cfg.effective_w(),
        cfg.effective_r().max(1),
        cfg.sampling(),
    ));
    let ctrl = Arc::new(Ctrl::default());
    let cosine = Arc::new(Mutex::new(CosineRecorder::default()));

    // ---- local worker ----------------------------------------------------
    let local_handle = if cfg.effective_r() > 0 {
        let runtime = runtime.clone();
        let workset = workset.clone();
        let ctrl = ctrl.clone();
        let train = train.clone();
        let cosine = cosine.clone();
        Some(std::thread::Builder::new()
            .name(format!("feature-{}-local", party.0))
            .spawn(move || -> anyhow::Result<u64> {
                let mut steps = 0u64;
                let mut scratch = GatherScratch::default();
                while !ctrl.stopped() {
                    // §3.2 bubble handling: park on the workset condvar
                    // until the comm worker inserts (or the timeout
                    // elapses, re-checking the stop flag) — no busy-wait.
                    match workset.sample_or_wait(BUBBLE_PARK)? {
                        Some(e) => {
                            let xa = gather_a_with(&train, &e.indices,
                                                   &mut scratch);
                            let ws = runtime
                                .lock()
                                .unwrap()
                                .local_update(&xa, &e.za, &e.dza)?;
                            steps += 1;
                            cosine.lock().unwrap().push(steps, &ws);
                        }
                        None => {}
                    }
                }
                Ok(steps)
            })?)
    } else {
        None
    };

    // ---- comm worker (this thread) ----------------------------------------
    let mut cursor = BatchCursor::new(cfg.seed, train.n, batch);
    let mut scratch = GatherScratch::default();
    let eval_batches = eval_batch_count(cfg, test.n, batch);
    let mut comm_rounds = 0u64;
    let requested = cfg.codec_for(party.0);
    let result: anyhow::Result<()> = (|| {
        // Capabilities handshake (DESIGN.md §5): only when compression
        // is requested — an identity config keeps the wire byte stream
        // exactly as before, so pre-handshake peers interoperate.
        let codec = if requested != CodecKind::Identity {
            transport.send(Message::Hello {
                codecs: compress::supported_mask(),
            })?;
            match transport.recv()? {
                Message::Hello { codecs } => {
                    let eff = compress::negotiate(requested, Some(codecs));
                    if eff != requested {
                        log::warn!(
                            "[{party}] peer cannot decode codec {} \
                             (mask {codecs:#x}) — sending uncompressed",
                            requested.label()
                        );
                    }
                    eff
                }
                Message::Shutdown => return Ok(()),
                other => anyhow::bail!(
                    "expected Hello reply, got {:?}", other.tag()),
            }
        } else {
            CodecKind::Identity
        };
        for round in 0..cfg.max_rounds as u64 {
            let idx = cursor.next_indices();
            let xa = gather_a_with(&train, &idx, &mut scratch);
            let za = runtime.lock().unwrap().forward(&xa)?;
            // Identity codec: the message and the workset entry below
            // share za's allocation — the clone is a refcount bump, not
            // a copy. Lossy codec: `za` is rebound to the dequantized
            // round-trip so the cache matches what the label decodes.
            let (msg, za) =
                outbound_stats(codec, Lane::Activation, round, za)?;
            transport.send(msg)?;
            // Block on ∇Z (the local worker keeps training meanwhile).
            let dza = match transport.recv()?.into_plain()? {
                Message::Derivative { round: r, tensor } => {
                    anyhow::ensure!(r == round,
                                    "protocol skew: got derivative {r}, \
                                     expected {round}");
                    tensor
                }
                Message::Shutdown => return Ok(()),
                other => anyhow::bail!("unexpected message {:?} in round \
                                        {round}", other.tag()),
            };
            runtime.lock().unwrap().exact_update(&xa, &dza)?;
            workset.insert(round, idx, vec![(za, dza)]);
            comm_rounds = round + 1;

            // Eval lane.
            if comm_rounds % cfg.eval_every as u64 == 0 {
                for k in 0..eval_batches {
                    let idx: Vec<u32> = ((k * batch) as u32
                        ..((k + 1) * batch) as u32)
                        .collect();
                    let xa = gather_a_with(&test, &idx, &mut scratch);
                    let za = runtime.lock().unwrap().forward(&xa)?;
                    let (msg, _) = outbound_stats(
                        codec, Lane::EvalActivation, k as u64, za)?;
                    transport.send(msg)?;
                }
            }
        }
        // Round budget exhausted on this side; wait for the label
        // party's shutdown so the byte accounting stays complete.
        loop {
            match transport.recv() {
                Ok(Message::Shutdown) | Err(_) => return Ok(()),
                Ok(_) => {}
            }
        }
    })();
    ctrl.stop();
    workset.wake_all(); // unpark a local worker sleeping through a bubble
    let local_updates = match local_handle {
        Some(h) => h.join().expect("feature party local worker panicked")?,
        None => 0,
    };
    result?;

    let exact_updates = runtime.lock().unwrap().exact_updates;
    let ws_stats = workset.stats();
    let cosine = Arc::try_unwrap(cosine)
        .map(|m| m.into_inner().unwrap())
        .unwrap_or_default();
    Ok(FeaturePartyReport {
        party,
        comm_rounds,
        exact_updates,
        local_updates,
        workset: ws_stats,
        cosine,
    })
}
