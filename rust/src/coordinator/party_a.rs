//! Party A driver: features only, no labels, no top model.
//!
//! Comm worker: forward → send Z_A → (overlapped) → recv ∇Z_A → exact
//! update → cache. Local worker: drain the workset with round-robin
//! sampling + instance-weighted local updates (Algorithm 2,
//! LocalUpdatePartyA). The workers share the runtime (params) and the
//! workset table; while the comm worker is blocked on the WAN the local
//! worker keeps the accelerator busy — the paper's §3.1 overlap.
//!
//! Statistics move zero-copy end-to-end (DESIGN.md §4): the forward
//! activations are shared between the outgoing message and the workset
//! entry through one `Arc` allocation, local-update sampling returns
//! handles instead of deep clones, and gathers recycle their destination
//! buffers across rounds.
//!
//! When `cfg.compress` asks for a wire codec, A initiates the `Hello`
//! capabilities handshake before round 0 and then routes every outgoing
//! statistic through `protocol::outbound_stats` (DESIGN.md §5): the
//! workset caches the *dequantized* round-trip so A trains on exactly
//! the tensors B decodes. With the identity codec no `Hello` is sent
//! and the wire + cache behaviour is byte-identical to PR 1.

use std::sync::{Arc, Mutex};

use crate::compress::{self, CodecKind};
use crate::config::RunConfig;
use crate::data::batcher::{gather_a_with, BatchCursor, GatherScratch};
use crate::data::PartyAData;
use crate::metrics::CosineRecorder;
use crate::protocol::{outbound_stats, Lane, Message};
use crate::runtime::{ArtifactSet, PartyARuntime};
use crate::transport::Transport;
use crate::workset::{SharedWorkset, WorksetStats, WorksetTable};

use super::{Ctrl, BUBBLE_PARK};

/// Everything Party A reports after a run.
#[derive(Debug, Default)]
pub struct PartyAReport {
    pub comm_rounds: u64,
    pub exact_updates: u64,
    pub local_updates: u64,
    pub workset: WorksetStats,
    pub cosine: CosineRecorder,
}

/// Run Party A to completion (until Shutdown from B or transport error).
pub fn run_party_a(
    cfg: &RunConfig,
    set: Arc<ArtifactSet>,
    train: Arc<PartyAData>,
    test: Arc<PartyAData>,
    transport: Arc<dyn Transport>,
) -> anyhow::Result<PartyAReport> {
    let batch = set.manifest.batch;
    let runtime = Arc::new(Mutex::new(PartyARuntime::new(
        set.clone(),
        cfg.seed,
        cfg.lr as f32,
        cfg.cos_xi() as f32,
        cfg.weighting_enabled(),
    )?));
    let workset = Arc::new(SharedWorkset::new(WorksetTable::new(
        cfg.effective_w(),
        cfg.effective_r().max(1),
        cfg.sampling(),
    )));
    let ctrl = Arc::new(Ctrl::default());
    let cosine = Arc::new(Mutex::new(CosineRecorder::default()));

    // ---- local worker ----------------------------------------------------
    let local_handle = if cfg.effective_r() > 0 {
        let runtime = runtime.clone();
        let workset = workset.clone();
        let ctrl = ctrl.clone();
        let train = train.clone();
        let cosine = cosine.clone();
        Some(std::thread::Builder::new()
            .name("party-a-local".into())
            .spawn(move || -> anyhow::Result<u64> {
                let mut steps = 0u64;
                let mut scratch = GatherScratch::default();
                while !ctrl.stopped() {
                    // §3.2 bubble handling: park on the workset condvar
                    // until the comm worker inserts (or the timeout
                    // elapses, re-checking the stop flag) — no busy-wait.
                    match workset.sample_or_wait(BUBBLE_PARK) {
                        Some(e) => {
                            let xa = gather_a_with(&train, &e.indices,
                                                   &mut scratch);
                            let ws = runtime
                                .lock()
                                .unwrap()
                                .local_update(&xa, &e.za, &e.dza)?;
                            steps += 1;
                            cosine.lock().unwrap().push(steps, &ws);
                        }
                        None => {}
                    }
                }
                Ok(steps)
            })?)
    } else {
        None
    };

    // ---- comm worker (this thread) ----------------------------------------
    let mut cursor = BatchCursor::new(cfg.seed, train.n, batch);
    let mut scratch = GatherScratch::default();
    let eval_batches = eval_batch_count(cfg, test.n, batch);
    let mut comm_rounds = 0u64;
    let result: anyhow::Result<()> = (|| {
        // Capabilities handshake (DESIGN.md §5): only when compression
        // is requested — an identity config keeps the wire byte stream
        // exactly as before, so pre-handshake peers interoperate.
        let codec = if cfg.compress != CodecKind::Identity {
            transport.send(Message::Hello {
                codecs: compress::supported_mask(),
            })?;
            match transport.recv()? {
                Message::Hello { codecs } => {
                    let eff =
                        compress::negotiate(cfg.compress, Some(codecs));
                    if eff != cfg.compress {
                        log::warn!(
                            "peer cannot decode codec {} (mask {codecs:#x}) \
                             — sending uncompressed",
                            cfg.compress.label()
                        );
                    }
                    eff
                }
                Message::Shutdown => return Ok(()),
                other => anyhow::bail!(
                    "expected Hello reply, got {:?}", other.tag()),
            }
        } else {
            CodecKind::Identity
        };
        for round in 0..cfg.max_rounds as u64 {
            let idx = cursor.next_indices();
            let xa = gather_a_with(&train, &idx, &mut scratch);
            let za = runtime.lock().unwrap().forward(&xa)?;
            // Identity codec: the message and the workset entry below
            // share za's allocation — the clone is a refcount bump, not
            // a copy. Lossy codec: `za` is rebound to the dequantized
            // round-trip so the cache matches what B decodes.
            let (msg, za) =
                outbound_stats(codec, Lane::Activation, round, za)?;
            transport.send(msg)?;
            // Block on ∇Z_A (the local worker keeps training meanwhile).
            let dza = match transport.recv()?.into_plain()? {
                Message::Derivative { round: r, tensor } => {
                    anyhow::ensure!(r == round,
                                    "protocol skew: got derivative {r}, \
                                     expected {round}");
                    tensor
                }
                Message::Shutdown => return Ok(()),
                other => anyhow::bail!("unexpected message {:?} in round \
                                        {round}", other.tag()),
            };
            runtime.lock().unwrap().exact_update(&xa, &dza)?;
            workset.insert(round, idx, za, dza);
            comm_rounds = round + 1;

            // Eval lane.
            if comm_rounds % cfg.eval_every as u64 == 0 {
                for k in 0..eval_batches {
                    let idx: Vec<u32> = ((k * batch) as u32
                        ..((k + 1) * batch) as u32)
                        .collect();
                    let xa = gather_a_with(&test, &idx, &mut scratch);
                    let za = runtime.lock().unwrap().forward(&xa)?;
                    let (msg, _) = outbound_stats(
                        codec, Lane::EvalActivation, k as u64, za)?;
                    transport.send(msg)?;
                }
            }
        }
        // Round budget exhausted on A's side; wait for B's shutdown so the
        // byte accounting stays complete.
        loop {
            match transport.recv() {
                Ok(Message::Shutdown) | Err(_) => return Ok(()),
                Ok(_) => {}
            }
        }
    })();
    ctrl.stop();
    workset.wake_all(); // unpark a local worker sleeping through a bubble
    let local_updates = match local_handle {
        Some(h) => h.join().expect("party A local worker panicked")?,
        None => 0,
    };
    result?;

    let exact_updates = runtime.lock().unwrap().exact_updates;
    let ws_stats = workset.stats();
    let cosine = Arc::try_unwrap(cosine)
        .map(|m| m.into_inner().unwrap())
        .unwrap_or_default();
    Ok(PartyAReport {
        comm_rounds,
        exact_updates,
        local_updates,
        workset: ws_stats,
        cosine,
    })
}

/// Number of held-out batches both parties walk on the eval lane.
pub fn eval_batch_count(cfg: &RunConfig, test_n: usize, batch: usize)
                        -> usize {
    cfg.eval_batches.min(test_n / batch).max(1)
}
