//! Party B driver: features + labels, bottom and top models, and the
//! run's control plane (loss tracking, AUC evaluation, stopping).
//!
//! Comm worker: recv Z_A → exact step (computes loss + ∇Z_A, updates
//! θ_B/θ_top) → cache ⟨i, Z_A, ∇Z_A⟩ → send ∇Z_A. Local worker: local
//! steps against the cached statistics (Algorithm 2, LocalUpdatePartyB).
//! B owns the stop decision and broadcasts Shutdown.
//!
//! The cache insert happens *before* the (WAN-bound) send: the entry's
//! tensors are `Arc`-shared with the outgoing message rather than copied,
//! and the local worker can already consume the fresh statistics while
//! the derivative is still occupying the link (DESIGN.md §4).
//!
//! B answers the `Hello` capabilities handshake whenever A initiates it
//! — even when B itself is configured uncompressed — and routes its
//! derivative sends through `protocol::outbound_stats` under the
//! negotiated codec, caching the dequantized round-trip (DESIGN.md §5).
//! A plain first frame means a pre-handshake peer: B stays on the
//! identity codec and the wire behaviour is byte-identical to PR 1.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::compress::{self, CodecKind};
use crate::config::RunConfig;
use crate::data::batcher::{gather_b_with, BatchCursor, GatherScratch};
use crate::data::PartyBData;
use crate::metrics::{auc_exact, CosineRecorder, SeriesPoint};
use crate::protocol::{outbound_stats, Lane, Message};
use crate::runtime::{ArtifactSet, PartyBRuntime};
use crate::transport::Transport;
use crate::util::stats::Ema;
use crate::workset::{SharedWorkset, WorksetStats, WorksetTable};

use super::party_a::eval_batch_count;
use super::{Ctrl, BUBBLE_PARK};

/// Everything Party B reports after a run.
#[derive(Debug, Default)]
pub struct PartyBReport {
    pub comm_rounds: u64,
    pub exact_updates: u64,
    pub local_updates: u64,
    pub workset: WorksetStats,
    pub cosine: CosineRecorder,
    pub series: Vec<SeriesPoint>,
    /// Why the run ended.
    pub stop_reason: StopReason,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StopReason {
    #[default]
    MaxRounds,
    TargetAuc,
    TimeBudget,
}

pub fn run_party_b(
    cfg: &RunConfig,
    set: Arc<ArtifactSet>,
    train: Arc<PartyBData>,
    test: Arc<PartyBData>,
    transport: Arc<dyn Transport>,
) -> anyhow::Result<PartyBReport> {
    let batch = set.manifest.batch;
    let runtime = Arc::new(Mutex::new(PartyBRuntime::new(
        set.clone(),
        // Party B's init stream must differ from A's but the *batch
        // schedule* seed must match: both derive from cfg.seed.
        cfg.seed,
        cfg.lr as f32,
        cfg.cos_xi() as f32,
        cfg.weighting_enabled(),
    )?));
    let workset = Arc::new(SharedWorkset::new(WorksetTable::new(
        cfg.effective_w(),
        cfg.effective_r().max(1),
        cfg.sampling(),
    )));
    let ctrl = Arc::new(Ctrl::default());
    let cosine = Arc::new(Mutex::new(CosineRecorder::default()));
    let loss_ema = Arc::new(Mutex::new(Ema::new(0.95)));

    // ---- local worker ------------------------------------------------------
    let local_handle = if cfg.effective_r() > 0 {
        let runtime = runtime.clone();
        let workset = workset.clone();
        let ctrl = ctrl.clone();
        let train = train.clone();
        let cosine = cosine.clone();
        let loss_ema = loss_ema.clone();
        Some(std::thread::Builder::new()
            .name("party-b-local".into())
            .spawn(move || -> anyhow::Result<u64> {
                let mut steps = 0u64;
                let mut scratch = GatherScratch::default();
                while !ctrl.stopped() {
                    // Park through §3.2 bubbles; `insert` notifies.
                    match workset.sample_or_wait(BUBBLE_PARK) {
                        Some(e) => {
                            let (xb, y) = gather_b_with(&train, &e.indices,
                                                        &mut scratch);
                            let (loss, ws) = runtime
                                .lock()
                                .unwrap()
                                .local_step(&xb, &y, &e.za, &e.dza)?;
                            steps += 1;
                            cosine.lock().unwrap().push(steps, &ws);
                            loss_ema.lock().unwrap().push(loss as f64);
                        }
                        None => {}
                    }
                }
                Ok(steps)
            })?)
    } else {
        None
    };

    // ---- comm worker + control plane (this thread) -------------------------
    let mut cursor = BatchCursor::new(cfg.seed, train.n, batch);
    let mut scratch = GatherScratch::default();
    let eval_batches = eval_batch_count(cfg, test.n, batch);
    let start = Instant::now();
    let mut series: Vec<SeriesPoint> = Vec::new();
    let mut stop_reason = StopReason::MaxRounds;
    let mut comm_rounds = 0u64;

    let result: anyhow::Result<()> = (|| {
        // Handshake: A speaks first. A `Hello` is answered with our
        // capabilities (whether or not we were configured to compress);
        // any other first frame is a pre-handshake peer and is replayed
        // into round 0 below with the identity codec.
        let mut replay: Option<Message> = None;
        let codec = match transport.recv()? {
            Message::Hello { codecs: peer } => {
                transport.send(Message::Hello {
                    codecs: compress::supported_mask(),
                })?;
                let eff = compress::negotiate(cfg.compress, Some(peer));
                if eff != cfg.compress {
                    log::warn!(
                        "peer cannot decode codec {} (mask {peer:#x}) — \
                         sending uncompressed",
                        cfg.compress.label()
                    );
                }
                eff
            }
            first => {
                if cfg.compress != CodecKind::Identity {
                    // B cannot initiate (A speaks first in the lock-step
                    // protocol): a plain first frame means A predates or
                    // didn't request compression, so B's request is
                    // dropped — loudly, not silently.
                    log::warn!(
                        "compress = {} requested but peer opened without \
                         a handshake — sending uncompressed",
                        cfg.compress.label()
                    );
                }
                replay = Some(first);
                CodecKind::Identity
            }
        };
        for round in 0..cfg.max_rounds as u64 {
            let idx = cursor.next_indices();
            let (xb, y) = gather_b_with(&train, &idx, &mut scratch);
            let msg = match replay.take() {
                Some(m) => m,
                None => transport.recv()?,
            };
            let za = match msg.into_plain()? {
                Message::Activation { round: r, tensor } => {
                    anyhow::ensure!(r == round,
                                    "protocol skew: got activation {r}, \
                                     expected {round}");
                    tensor
                }
                other => anyhow::bail!("unexpected message {:?} in round \
                                        {round}", other.tag()),
            };
            let (dza, loss) = runtime
                .lock()
                .unwrap()
                .exact_step(&xb, &y, &za)?;
            if cfg.compute_delay_s > 0.0 {
                // Optional artificial compute cost (comm:compute ratio
                // studies — see DESIGN.md §3).
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    cfg.compute_delay_s));
            }
            loss_ema.lock().unwrap().push(loss as f64);
            // Cache first (identity: handle share, no payload copy;
            // lossy: the dequantized round-trip A will also see), then
            // occupy the WAN: the local worker trains on round `i`'s
            // statistics while ∇Z_A is still in flight.
            let (dmsg, dza) =
                outbound_stats(codec, Lane::Derivative, round, dza)?;
            workset.insert(round, idx, za, dza);
            transport.send(dmsg)?;
            comm_rounds = round + 1;

            // Eval lane + stop decision.
            if comm_rounds % cfg.eval_every as u64 == 0 {
                let mut scores = Vec::with_capacity(eval_batches * batch);
                let mut labels = Vec::with_capacity(eval_batches * batch);
                for k in 0..eval_batches {
                    let idx: Vec<u32> = ((k * batch) as u32
                        ..((k + 1) * batch) as u32)
                        .collect();
                    let (xb, y) = gather_b_with(&test, &idx, &mut scratch);
                    let za = match transport.recv()?.into_plain()? {
                        Message::EvalActivation { round: r, tensor } => {
                            anyhow::ensure!(r == k as u64,
                                            "eval lane skew: {r} != {k}");
                            tensor
                        }
                        other => anyhow::bail!(
                            "expected eval activation, got {:?}",
                            other.tag()),
                    };
                    let yhat =
                        runtime.lock().unwrap().eval(&xb, &za)?;
                    scores.extend(yhat);
                    labels.extend_from_slice(y.as_f32()?);
                }
                let auc = auc_exact(&scores, &labels);
                let rt = runtime.lock().unwrap();
                let updates = rt.exact_updates + rt.local_updates;
                drop(rt);
                let point = SeriesPoint {
                    comm_round: comm_rounds,
                    wall_s: start.elapsed().as_secs_f64(),
                    auc,
                    loss: loss_ema.lock().unwrap().get(),
                    updates,
                };
                log::info!(
                    "[{}] round {:>6}  auc {:.4}  loss {:.4}  updates {}",
                    cfg.algorithm.name(), comm_rounds, auc, point.loss,
                    updates
                );
                series.push(point);
                if cfg.target_auc > 0.0 && auc >= cfg.target_auc {
                    stop_reason = StopReason::TargetAuc;
                    return Ok(());
                }
                if cfg.max_seconds > 0.0
                    && start.elapsed().as_secs_f64() >= cfg.max_seconds
                {
                    stop_reason = StopReason::TimeBudget;
                    return Ok(());
                }
            }
        }
        Ok(())
    })();
    // Broadcast shutdown regardless of how we exited.
    let _ = transport.send(Message::Shutdown);
    ctrl.stop();
    workset.wake_all(); // unpark a local worker sleeping through a bubble
    let local_updates = match local_handle {
        Some(h) => h.join().expect("party B local worker panicked")?,
        None => 0,
    };
    result?;

    let exact_updates = runtime.lock().unwrap().exact_updates;
    let ws_stats = workset.stats();
    let cosine = Arc::try_unwrap(cosine)
        .map(|m| m.into_inner().unwrap())
        .unwrap_or_default();
    Ok(PartyBReport {
        comm_rounds,
        exact_updates,
        local_updates,
        workset: ws_stats,
        cosine,
        series,
        stop_reason,
    })
}
