//! The L3 coordinator: CELU-VFL's K-party training runtime.
//!
//! Faithful to Figure 2 of the paper, generalized over the session
//! topology (`session` module): each party runs a **communication
//! worker** (the two-phase Z/∇Z exchange plus exact updates) and a
//! **local worker** (local updates from the workset table)
//! concurrently, sharing the party's parameter state and workset behind
//! locks. Parties connect through per-peer `Transport` links (simulated
//! WAN in-proc star or real TCP).
//!
//! Roles (DESIGN.md §6): K−1 **feature parties** (`feature_party`, one
//! driver parameterized by `PartyId`) each hold a vertical feature
//! slice and a bottom model; the **label party** (`label_party`) holds
//! features + labels, aggregates Σ_k Z_k across its activation lanes,
//! and fans the shared derivative out per link.
//!
//! Protocol timeline per communication round `i` (lock-step per link):
//!   feature k: gather X_k → Z_k = fwd → send Activation{i} → … →
//!      recv Derivative → exact update → insert ⟨i, Z_k, ∇Z⟩ into k's
//!      workset
//!   label: recv Activation{i} from every lane → gather X_B,y → exact
//!      step on Σ_k Z_k (emits ∇Z, loss) → cache per-lane → fan out
//!      Derivative{i}
//! Every `eval_every` rounds all parties walk the eval lane (features
//! stream activations for the held-out batches, the label party scores
//! AUC). The label party owns the stopping decision (target AUC / max
//! rounds / time budget) and broadcasts `Shutdown` on every link.
//!
//! The historic two-party entry points ([`run_party_a`],
//! [`run_party_b`], [`trainer::run_training`] with `parties = 2`) are
//! thin wrappers over these drivers and produce byte-identical wire
//! traffic to the pre-session code (pinned by the protocol golden
//! fixtures).

pub mod feature_party;
pub mod label_party;
pub mod trainer;

pub use trainer::{run_training, TrainOutcome};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::config::RunConfig;
use crate::data::{PartyAData, PartyBData};
use crate::dataset::{FeatureFeed, LabelFeed};
use crate::runtime::ArtifactSet;
use crate::session::{Link, PartyId};
use crate::transport::Transport;

use feature_party::{run_feature_party, FeaturePartyReport,
                    FeatureRunOpts};
use label_party::{run_label_party, LabelPartyReport, LabelRunOpts};

use crate::session::LABEL_PARTY;

/// How long a local worker parks on the workset condvar before re-checking
/// its stop flag. §3.2 bubbles are normally broken by an insert notify —
/// this bound only caps shutdown latency (and spurious-wakeup churn).
pub(crate) const BUBBLE_PARK: Duration = Duration::from_millis(2);

/// Number of held-out batches every party walks on the eval lane.
pub fn eval_batch_count(cfg: &RunConfig, test_n: usize, batch: usize)
                        -> usize {
    cfg.eval_batches.min(test_n / batch).max(1)
}

/// Parameter-init seed for feature party `party`. Party 1 uses the run
/// seed unchanged — bit-identical to the historic Party A — and later
/// parties decorrelate by a fixed odd stride so no two bottom models
/// start from the same stream. (The *batch schedule* seed is shared by
/// every party and is not derived from this.)
pub(crate) fn feature_seed(seed: u64, party: PartyId) -> u64 {
    seed.wrapping_add(
        (party.0 as u64).wrapping_sub(1)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15),
    )
}

/// Two-party compat wrapper: run the single feature party (historic
/// "Party A") over one link. Thin shim over
/// [`feature_party::run_feature_party`] with `PartyId(1)`.
pub fn run_party_a(
    cfg: &RunConfig,
    set: Arc<ArtifactSet>,
    train: Arc<PartyAData>,
    test: Arc<PartyAData>,
    transport: Arc<dyn Transport>,
) -> anyhow::Result<FeaturePartyReport> {
    // A raw transport carries no join-time codec mask, so the in-band
    // Hello path (the historic wire) applies.
    let link = Link::new(LABEL_PARTY, transport);
    let feed = FeatureFeed::in_memory(train, cfg.seed, set.manifest.batch);
    run_feature_party(cfg, PartyId(1), set, feed, test, &link,
                      FeatureRunOpts::default())
}

/// Two-party compat wrapper: run the label party (historic "Party B")
/// over one link. Thin shim over [`label_party::run_label_party`].
pub fn run_party_b(
    cfg: &RunConfig,
    set: Arc<ArtifactSet>,
    train: Arc<PartyBData>,
    test: Arc<PartyBData>,
    transport: Arc<dyn Transport>,
) -> anyhow::Result<LabelPartyReport> {
    let links = [Link::new(PartyId(1), transport)];
    let feed = LabelFeed::in_memory(train, cfg.seed, set.manifest.batch);
    run_label_party(cfg, set, feed, test, &links,
                    LabelRunOpts::default())
}

/// Shared stop flag between a party's comm and local workers.
#[derive(Debug, Default)]
pub struct Ctrl {
    stop: AtomicBool,
}

impl Ctrl {
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctrl_flag() {
        let c = Ctrl::default();
        assert!(!c.stopped());
        c.stop();
        assert!(c.stopped());
    }

    #[test]
    fn feature_seeds_are_stable_and_distinct() {
        // Party 1 must reproduce the historic Party A stream exactly.
        assert_eq!(feature_seed(42, PartyId(1)), 42);
        let seeds: Vec<u64> = (1..=5)
            .map(|p| feature_seed(42, PartyId(p)))
            .collect();
        let mut dedup = seeds.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), seeds.len(), "seed collision: {seeds:?}");
    }
}
