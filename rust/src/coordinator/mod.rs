//! The L3 coordinator: CELU-VFL's two-party training runtime.
//!
//! Faithful to Figure 2 of the paper: each party runs a **communication
//! worker** (the two-phase Z_A / ∇Z_A exchange plus exact updates) and a
//! **local worker** (local updates from the workset table) concurrently,
//! sharing the party's parameter state and workset behind locks. The two
//! parties connect through a `Transport` (simulated-WAN in-proc pair or
//! real TCP).
//!
//! Protocol timeline per communication round `i` (lock-step, FIFO):
//!   A: gather X_A → Z_A = fwd → send Activation{i} → … → recv Derivative
//!      → exact update → insert ⟨i, Z_A, ∇Z_A⟩ into A's workset
//!   B: recv Activation{i} → gather X_B,y → exact step (emits ∇Z_A, loss)
//!      → send Derivative{i} → insert into B's workset
//! Every `eval_every` rounds both parties walk the eval lane (A streams
//! activations for the held-out batches, B scores AUC). Party B owns the
//! stopping decision (target AUC / max rounds / time budget) and
//! broadcasts `Shutdown`.

pub mod party_a;
pub mod party_b;
pub mod trainer;

pub use trainer::{run_training, TrainOutcome};

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

/// How long a local worker parks on the workset condvar before re-checking
/// its stop flag. §3.2 bubbles are normally broken by an insert notify —
/// this bound only caps shutdown latency (and spurious-wakeup churn).
pub(crate) const BUBBLE_PARK: Duration = Duration::from_millis(2);

/// Shared stop flag between a party's comm and local workers.
#[derive(Debug, Default)]
pub struct Ctrl {
    stop: AtomicBool,
}

impl Ctrl {
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctrl_flag() {
        let c = Ctrl::default();
        assert!(!c.stopped());
        c.stop();
        assert!(c.stopped());
    }
}
