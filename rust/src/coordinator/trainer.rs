//! Single-process trainer: spins up all `cfg.parties` parties over a
//! simulated-WAN in-proc star mesh (one duplex link per feature party),
//! runs one full training job, and assembles the `RunRecord` consumed
//! by every experiment harness.
//!
//! `parties = 2` is the paper's two-party protocol — one feature thread
//! plus the label party on the calling thread, byte-identical wire
//! traffic to the pre-session trainer. `parties = K` splits the
//! synthetic Party-A features vertically into K−1 slices
//! (`PartyAData::vertical_split`), runs one feature-party thread per
//! slice, and the label party aggregates Σ_k Z_k.
//!
//! Artifact sets are compiled once per process and cached (`set_cache`) —
//! parameter state is per-run, so sweeps over (R, W, ξ, algorithm, seed,
//! parties) reuse the compiled executables.

use std::collections::HashMap;
use std::ops::Range;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::{DataFormat, RunConfig};
use crate::data::{self, PartyAData, PartyBData, SynthDataset};
use crate::dataset::{read_prefix, slice_rows_a, slice_rows_b,
                     split_synthetic, subset_a, subset_b, AlignmentMap,
                     CsvSource, DatasetSource, FeatureFeed, LabelFeed,
                     LibsvmSource};
use crate::metrics::facade::Registry;
use crate::metrics::{MetricsExporter, RunRecord, RunRecordObserver};
use crate::runtime::ArtifactSet;
use crate::session::bootstrap::inproc_mesh;
use crate::session::{PartyId, SessionBuilder};

use super::feature_party::{FeaturePartyReport, FeatureRunOpts};
use super::label_party::{LabelPartyReport, LabelRunOpts, StopReason};

/// Outcome of one training run.
pub struct TrainOutcome {
    pub record: RunRecord,
    pub stop_reason: StopReason,
}

fn set_cache() -> &'static Mutex<HashMap<String, Arc<ArtifactSet>>> {
    use once_cell::sync::OnceCell;
    static CACHE: OnceCell<Mutex<HashMap<String, Arc<ArtifactSet>>>> =
        OnceCell::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Load (or fetch from cache) the artifact set for `cfg`.
pub fn load_set(cfg: &RunConfig) -> anyhow::Result<Arc<ArtifactSet>> {
    let tag = cfg.artifact_tag();
    let mut cache = set_cache().lock().unwrap();
    if let Some(set) = cache.get(&tag) {
        return Ok(set.clone());
    }
    let set = Arc::new(ArtifactSet::load_tagged(&cfg.artifacts_dir, &tag)?);
    cache.insert(tag, set.clone());
    Ok(set)
}

/// Generate the synthetic dataset for `cfg` (vocab from the manifest so
/// ids always index the embedding tables correctly).
pub fn load_data(cfg: &RunConfig, set: &ArtifactSet)
                 -> anyhow::Result<SynthDataset> {
    SynthDataset::generate(
        &cfg.dataset,
        set.manifest.vocab,
        cfg.train_instances,
        cfg.test_instances,
        cfg.label_noise,
        // Data seed is decoupled from the trial seed: trials re-sample
        // init/batching randomness, not the dataset itself.
        0xDA7A ^ cfg.seed / 1000,
    )
}

/// Vertically slice the Party-A feature space across `cfg`'s feature
/// parties and check every slice against the artifact manifest's
/// bottom-model input width. The two-party case moves the data instead
/// of calling `vertical_split(1)` (which clones): the full id matrix
/// is tens of MB at sweep scale and is about to be wrapped in an Arc
/// anyway. Shared by the in-proc trainer and the TCP deployment (which
/// keeps only its own slice).
pub fn feature_slices(
    cfg: &RunConfig,
    set: &ArtifactSet,
    train_a: PartyAData,
    test_a: PartyAData,
) -> anyhow::Result<(Vec<PartyAData>, Vec<PartyAData>)> {
    let k = cfg.feature_parties();
    let (train_slices, test_slices) = if k == 1 {
        (vec![train_a], vec![test_a])
    } else {
        (train_a.vertical_split(k)?, test_a.vertical_split(k)?)
    };
    if k > 1 {
        // The bottom-model artifact has a fixed input width; a K-party
        // run needs artifacts compiled for the per-party slice.
        for (i, s) in train_slices.iter().enumerate() {
            anyhow::ensure!(
                s.fields == set.manifest.fields_a,
                "artifact set '{}' compiles a {}-field bottom model but \
                 feature party {} holds {} of the vertically-split \
                 fields — compile per-party artifacts \
                 (python/compile/aot.py --parties {}) for --parties {}",
                cfg.artifact_tag(), set.manifest.fields_a, i + 1,
                s.fields, cfg.parties, cfg.parties
            );
        }
    }
    Ok((train_slices, test_slices))
}

/// Open a fresh chunked reader over `cfg.data` (csv / libsvm formats).
/// Every party opens its own handle — K readers over one file is the
/// in-proc mirror of K processes each holding their vertical slice.
pub fn open_source(cfg: &RunConfig, set: &ArtifactSet)
                   -> anyhow::Result<Box<dyn DatasetSource + Send>> {
    let (fa, fb) = data::dataset_fields(&cfg.dataset)?;
    let fields = fa + fb;
    let vocab = set.manifest.vocab;
    let path = Path::new(&cfg.data);
    Ok(match cfg.data_format {
        DataFormat::Csv => Box::new(CsvSource::open(path, fields, vocab)?),
        DataFormat::Libsvm => {
            Box::new(LibsvmSource::open(path, fields, vocab)?)
        }
        DataFormat::Synthetic => anyhow::bail!(
            "data_format synthetic has no on-disk source"),
    })
}

/// File columns owned by feature slot `slot` (0-based; party id is
/// `slot + 1`). The file lays Party-A fields first, then the label
/// party's, and feature slices use the exact `vertical_split`
/// arithmetic — so a CSV round-trip of a synthetic table lands every
/// column on the same party.
fn stream_cols_a(cfg: &RunConfig, slot: usize)
                 -> anyhow::Result<Range<usize>> {
    let (fa, _) = data::dataset_fields(&cfg.dataset)?;
    let widths = data::split_widths(fa, cfg.feature_parties())?;
    let start: usize = widths[..slot].iter().sum();
    Ok(start..start + widths[slot])
}

/// Rows reserved at the head of the file as the held-out evaluation
/// prefix: enough for the configured eval walk, never more than
/// `test_instances` — the bounded materialization the streaming plan
/// allows itself.
fn eval_prefix_rows(cfg: &RunConfig, batch: usize) -> usize {
    cfg.test_instances
        .min(cfg.eval_batches.max(1) * batch)
        .max(batch)
}

/// Build feature slot `slot`'s streaming data plane: a window feed over
/// its columns of `cfg.data` plus its materialized eval-prefix slice.
pub fn feature_stream_plan(
    cfg: &RunConfig,
    set: &ArtifactSet,
    slot: usize,
) -> anyhow::Result<(FeatureFeed, Arc<PartyAData>)> {
    let cols = stream_cols_a(cfg, slot)?;
    anyhow::ensure!(
        cols.len() == set.manifest.fields_a,
        "artifact set '{}' compiles a {}-field bottom model but feature \
         party {} streams {} of the file's columns — compile per-party \
         artifacts (python/compile/aot.py --parties {}) for --parties {}",
        cfg.artifact_tag(), set.manifest.fields_a, slot + 1, cols.len(),
        cfg.parties, cfg.parties
    );
    let batch = set.manifest.batch;
    let mut src = open_source(cfg, set)?;
    let test_rows = eval_prefix_rows(cfg, batch);
    let prefix = read_prefix(src.as_mut(), test_rows, cfg.chunk_rows)?;
    let rows: Vec<u32> = (0..prefix.rows() as u32).collect();
    let test = Arc::new(slice_rows_a(&prefix, &rows, &cols));
    src.rewind()?;
    let feed = FeatureFeed::streaming(
        src, cols, AlignmentMap::new(cfg.seed, cfg.overlap), cfg.seed,
        batch, cfg.chunk_rows, test_rows,
    )?;
    Ok((feed, test))
}

/// Build the label party's streaming data plane (its columns follow
/// every feature party's in the file).
pub fn label_stream_plan(
    cfg: &RunConfig,
    set: &ArtifactSet,
) -> anyhow::Result<(LabelFeed, Arc<PartyBData>)> {
    let (fa, fb) = data::dataset_fields(&cfg.dataset)?;
    anyhow::ensure!(
        fb == set.manifest.fields_b,
        "artifact set '{}' compiles a {}-field label bottom model but \
         dataset '{}' carries {} label-party columns",
        cfg.artifact_tag(), set.manifest.fields_b, cfg.dataset, fb
    );
    let cols = fa..fa + fb;
    let batch = set.manifest.batch;
    let mut src = open_source(cfg, set)?;
    let test_rows = eval_prefix_rows(cfg, batch);
    let prefix = read_prefix(src.as_mut(), test_rows, cfg.chunk_rows)?;
    let rows: Vec<u32> = (0..prefix.rows() as u32).collect();
    let test = Arc::new(slice_rows_b(&prefix, &rows, &cols));
    src.rewind()?;
    let feed = LabelFeed::streaming(
        src, cols, AlignmentMap::new(cfg.seed, cfg.overlap), cfg.seed,
        batch, cfg.chunk_rows, test_rows,
    )?;
    Ok((feed, test))
}

/// Row split of a fully-materialized synthetic run at `cfg.overlap`:
/// aligned rows (trained through the CELU cache path on every party)
/// and unaligned rows (each feature party's SSL reservoir). Full
/// overlap returns `None` — the historic zero-copy path applies.
pub fn synthetic_overlap_split(
    cfg: &RunConfig,
    batch: usize,
    n: usize,
) -> anyhow::Result<Option<(Vec<u32>, Vec<u32>)>> {
    if cfg.overlap >= 1.0 {
        return Ok(None);
    }
    let (aligned, unaligned) = split_synthetic(cfg.seed, cfg.overlap, n);
    anyhow::ensure!(
        aligned.len() >= batch,
        "overlap {} leaves {} aligned rows of {n} — fewer than one batch \
         ({batch}); raise --overlap or train_instances",
        cfg.overlap, aligned.len()
    );
    Ok(Some((aligned, unaligned)))
}

/// Wrap one feature party's materialized slice in a feed, applying the
/// overlap split: aligned rows train through the CELU cache path,
/// unaligned rows become the party's SSL reservoir. Full overlap wraps
/// the table zero-copy — the historic byte-identical path. Shared by
/// the in-proc trainer and the TCP deployment.
pub fn feature_memory_plan(
    cfg: &RunConfig,
    set: &ArtifactSet,
    train: PartyAData,
    test: PartyAData,
) -> anyhow::Result<(FeatureFeed, Arc<PartyAData>)> {
    let batch = set.manifest.batch;
    let feed = match synthetic_overlap_split(cfg, batch, train.n)? {
        Some((aligned, unaligned)) => FeatureFeed::in_memory(
            Arc::new(subset_a(&train, &aligned)), cfg.seed, batch,
        )
        .with_ssl_pool(subset_a(&train, &unaligned)),
        None => FeatureFeed::in_memory(Arc::new(train), cfg.seed, batch),
    };
    Ok((feed, Arc::new(test)))
}

/// Label-side mirror of [`feature_memory_plan`]. The label party keeps
/// no SSL reservoir — its unaligned rows are simply dropped, exactly as
/// post-PSI training discards out-of-intersection labels.
pub fn label_memory_plan(
    cfg: &RunConfig,
    set: &ArtifactSet,
    train: PartyBData,
    test: PartyBData,
) -> anyhow::Result<(LabelFeed, Arc<PartyBData>)> {
    let batch = set.manifest.batch;
    let train = match synthetic_overlap_split(cfg, batch, train.n)? {
        Some((aligned, _)) => Arc::new(subset_b(&train, &aligned)),
        None => Arc::new(train),
    };
    Ok((LabelFeed::in_memory(train, cfg.seed, batch), Arc::new(test)))
}

/// Run one full K-party training job in-process (K = `cfg.parties`;
/// 2 is the classic two-party run).
pub fn run_training(cfg: &RunConfig) -> anyhow::Result<TrainOutcome> {
    cfg.validate()?;
    let set = load_set(cfg)?;
    let batch = set.manifest.batch;
    let k = cfg.feature_parties();

    // Data plane (DESIGN.md §12): one feed + held-out table per party.
    // Synthetic at full overlap is the historic zero-copy path — the
    // feeds wrap the generated tables through shared `Arc`s and replay
    // the batch-cursor sequence verbatim, keeping the wire
    // byte-identical. Partial overlap splits rows once (one map, every
    // party) before wrapping; csv/libsvm stream windows from disk.
    let (feature_plans, label_feed, test_b):
        (Vec<(FeatureFeed, Arc<PartyAData>)>, LabelFeed, Arc<PartyBData>) =
        if cfg.data_format.is_streaming() {
            let plans = (0..k)
                .map(|slot| feature_stream_plan(cfg, &set, slot))
                .collect::<anyhow::Result<Vec<_>>>()?;
            let (feed_b, test_b) = label_stream_plan(cfg, &set)?;
            (plans, feed_b, test_b)
        } else {
            anyhow::ensure!(
                cfg.train_instances >= batch,
                "train_instances {} < batch {}", cfg.train_instances,
                batch
            );
            let data = load_data(cfg, &set)?;
            let (train_slices, test_slices) =
                feature_slices(cfg, &set, data.train_a, data.test_a)?;
            let plans = train_slices
                .into_iter()
                .zip(test_slices)
                .map(|(train, test)|
                    feature_memory_plan(cfg, &set, train, test))
                .collect::<anyhow::Result<Vec<_>>>()?;
            let (feed_b, test_b) =
                label_memory_plan(cfg, &set, data.train_b, data.test_b)?;
            (plans, feed_b, test_b)
        };

    // Same bootstrap surface as the TCP deployment: the in-proc star is
    // just the pre-wired MeshBootstrap, so the trainer exercises the
    // exact session-construction path a K-process launch does. One
    // registry is shared by every party, so all 2(K−1) directed links
    // (and the label supervisor's lifecycle events) are visible through
    // a single scrape / push stream / terminal snapshot (DESIGN.md §10).
    let registry = Registry::new();
    let (label_bootstrap, feature_bootstraps) = inproc_mesh(cfg);
    let label_session =
        SessionBuilder::bootstrap_builder(cfg, label_bootstrap)?
            .with_registry(registry.clone())
            .build()?;

    let start = Instant::now();
    let mut handles = Vec::with_capacity(k);
    for ((i, bootstrap), (feed, test)) in feature_bootstraps
        .into_iter()
        .enumerate()
        .zip(feature_plans)
    {
        let party = PartyId(i as u16 + 1);
        let session = SessionBuilder::bootstrap_builder(cfg, bootstrap)?
            .with_registry(registry.clone())
            .build()?;
        let set_f = set.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("feature-{}", party.0))
                .spawn(move || -> anyhow::Result<FeaturePartyReport> {
                    session.run_feature_data(set_f, feed, test,
                                             FeatureRunOpts::default())
                })?,
        );
    }
    let b_report: LabelPartyReport = label_session.run_label_data(
        set.clone(), label_feed, test_b, LabelRunOpts::default())?;
    let mut feature_reports = Vec::with_capacity(k);
    for h in handles {
        feature_reports.push(h.join().expect("feature party panicked")?);
    }
    let wall = start.elapsed();

    // Per-link accounting: one row per directed link of the star, from
    // the shared registry (whose rows survived any transport swaps a
    // supervised run performed — rejoins charge the old totals onto the
    // fresh handles). The terminal observer is the RunRecord's leg of
    // the exporter API: scrape, push and this snapshot all read the
    // same rows, which is what the `scrape_k3` parity gate pins.
    let observer = RunRecordObserver::new();
    observer.export(&registry)?;
    let links = observer.links();
    let events = observer.events();
    let comm_busy: Duration = registry
        .link_rows()
        .iter()
        .map(|r| r.stats.busy)
        .sum();

    debug_assert!(feature_reports
        .iter()
        .all(|r| r.comm_rounds == b_report.comm_rounds));
    let feature_local_updates: Vec<u64> =
        feature_reports.iter().map(|r| r.local_updates).collect();
    let feature_ssl_updates: Vec<u64> =
        feature_reports.iter().map(|r| r.ssl_updates).collect();
    let primary = feature_reports.swap_remove(0);
    let record = RunRecord {
        label: format!("{}/{}", cfg.algorithm.name(), cfg.artifact_tag()),
        series: b_report.series,
        cosine: primary.cosine,
        cosine_b: b_report.cosine,
        comm_rounds: b_report.comm_rounds,
        exact_updates: b_report.exact_updates,
        local_updates: b_report.local_updates,
        feature_local_updates,
        feature_ssl_updates,
        links,
        comm_busy,
        wall,
        compute_busy: set.clock_a.busy() + set.clock_b.busy(),
        events,
    };
    log::info!(
        "run {} finished: {} parties, {} rounds, {} local updates \
         (label), wall {:.1}s, comm busy {:.1}s ({:.0}% per link)",
        record.label,
        cfg.parties,
        record.comm_rounds,
        record.local_updates,
        wall.as_secs_f64(),
        record.comm_busy.as_secs_f64(),
        // comm_busy sums every directed link, so the per-link average
        // divides by the link count (2 for the two-party run).
        100.0 * record.comm_fraction() / record.links.len().max(1) as f64
    );
    Ok(TrainOutcome { record, stop_reason: b_report.stop_reason })
}

/// Run `cfg.trials` trials with seeds seed, seed+1, … and return the
/// per-trial records.
pub fn run_trials(cfg: &RunConfig) -> anyhow::Result<Vec<TrainOutcome>> {
    let mut outcomes = Vec::with_capacity(cfg.trials);
    for t in 0..cfg.trials.max(1) {
        let mut c = cfg.clone();
        c.seed = cfg.seed + t as u64;
        outcomes.push(run_training(&c)?);
    }
    Ok(outcomes)
}
