//! Single-process trainer: spins up both parties over a simulated-WAN
//! in-proc transport pair, runs one full training job, and assembles the
//! `RunRecord` consumed by every experiment harness.
//!
//! Artifact sets are compiled once per process and cached (`set_cache`) —
//! parameter state is per-run, so sweeps over (R, W, ξ, algorithm, seed)
//! reuse the compiled executables.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::RunConfig;
use crate::data::SynthDataset;
use crate::metrics::RunRecord;
use crate::runtime::ArtifactSet;
use crate::transport::{inproc_pair, Transport};

use super::party_a::run_party_a;
use super::party_b::{run_party_b, PartyBReport, StopReason};

/// Outcome of one training run.
pub struct TrainOutcome {
    pub record: RunRecord,
    pub stop_reason: StopReason,
}

fn set_cache() -> &'static Mutex<HashMap<String, Arc<ArtifactSet>>> {
    use once_cell::sync::OnceCell;
    static CACHE: OnceCell<Mutex<HashMap<String, Arc<ArtifactSet>>>> =
        OnceCell::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Load (or fetch from cache) the artifact set for `cfg`.
pub fn load_set(cfg: &RunConfig) -> anyhow::Result<Arc<ArtifactSet>> {
    let tag = cfg.artifact_tag();
    let mut cache = set_cache().lock().unwrap();
    if let Some(set) = cache.get(&tag) {
        return Ok(set.clone());
    }
    let set = Arc::new(ArtifactSet::load_tagged(&cfg.artifacts_dir, &tag)?);
    cache.insert(tag, set.clone());
    Ok(set)
}

/// Generate the synthetic dataset for `cfg` (vocab from the manifest so
/// ids always index the embedding tables correctly).
pub fn load_data(cfg: &RunConfig, set: &ArtifactSet)
                 -> anyhow::Result<SynthDataset> {
    SynthDataset::generate(
        &cfg.dataset,
        set.manifest.vocab,
        cfg.train_instances,
        cfg.test_instances,
        cfg.label_noise,
        // Data seed is decoupled from the trial seed: trials re-sample
        // init/batching randomness, not the dataset itself.
        0xDA7A ^ cfg.seed / 1000,
    )
}

/// Run one full two-party training job in-process.
pub fn run_training(cfg: &RunConfig) -> anyhow::Result<TrainOutcome> {
    cfg.validate()?;
    let set = load_set(cfg)?;
    anyhow::ensure!(
        cfg.train_instances >= set.manifest.batch,
        "train_instances {} < batch {}", cfg.train_instances,
        set.manifest.batch
    );
    let data = load_data(cfg, &set)?;
    let train_a = Arc::new(data.train_a);
    let test_a = Arc::new(data.test_a);
    let train_b = Arc::new(data.train_b);
    let test_b = Arc::new(data.test_b);

    let (ta, tb) = inproc_pair(cfg.wan);
    let ta: Arc<dyn Transport> = Arc::new(ta);
    let tb: Arc<dyn Transport> = Arc::new(tb);

    let start = Instant::now();
    let cfg_a = cfg.clone();
    let set_a = set.clone();
    let ta_for_a = ta.clone();
    let a_handle = std::thread::Builder::new()
        .name("party-a".into())
        .spawn(move || {
            run_party_a(&cfg_a, set_a, train_a, test_a, ta_for_a)
        })?;
    let b_report: PartyBReport =
        run_party_b(cfg, set.clone(), train_b, test_b, tb.clone())?;
    let a_report = a_handle.join().expect("party A panicked")?;
    let wall = start.elapsed();

    let a_stats = ta.stats();
    let b_stats = tb.stats();
    let mut record = RunRecord {
        label: format!("{}/{}", cfg.algorithm.name(), cfg.artifact_tag()),
        series: b_report.series,
        cosine: a_report.cosine,
        cosine_b: b_report.cosine,
        comm_rounds: b_report.comm_rounds,
        exact_updates: b_report.exact_updates,
        local_updates: b_report.local_updates,
        bytes_a_to_b: a_stats.bytes,
        bytes_b_to_a: b_stats.bytes,
        raw_bytes_a_to_b: a_stats.raw_bytes,
        raw_bytes_b_to_a: b_stats.raw_bytes,
        comm_busy: a_stats.busy + b_stats.busy,
        wall,
        compute_busy: set.clock_a.busy() + set.clock_b.busy(),
    };
    // Per-run compute accounting: clocks are cumulative per artifact set,
    // so snapshot deltas would be needed for overlapping runs; trainer
    // runs are sequential per process, so we reset by subtraction at the
    // harness level instead. Record A-side counts too.
    record.exact_updates = b_report.exact_updates;
    debug_assert_eq!(a_report.comm_rounds, b_report.comm_rounds);
    log::info!(
        "run {} finished: {} rounds, {} local updates (B), wall {:.1}s, \
         comm busy {:.1}s ({:.0}%)",
        record.label,
        record.comm_rounds,
        record.local_updates,
        wall.as_secs_f64(),
        record.comm_busy.as_secs_f64(),
        100.0 * record.comm_fraction() / 2.0
    );
    Ok(TrainOutcome { record, stop_reason: b_report.stop_reason })
}

/// Run `cfg.trials` trials with seeds seed, seed+1, … and return the
/// per-trial records.
pub fn run_trials(cfg: &RunConfig) -> anyhow::Result<Vec<TrainOutcome>> {
    let mut outcomes = Vec::with_capacity(cfg.trials);
    for t in 0..cfg.trials.max(1) {
        let mut c = cfg.clone();
        c.seed = cfg.seed + t as u64;
        outcomes.push(run_training(&c)?);
    }
    Ok(outcomes)
}
