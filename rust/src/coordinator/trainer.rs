//! Single-process trainer: spins up all `cfg.parties` parties over a
//! simulated-WAN in-proc star mesh (one duplex link per feature party),
//! runs one full training job, and assembles the `RunRecord` consumed
//! by every experiment harness.
//!
//! `parties = 2` is the paper's two-party protocol — one feature thread
//! plus the label party on the calling thread, byte-identical wire
//! traffic to the pre-session trainer. `parties = K` splits the
//! synthetic Party-A features vertically into K−1 slices
//! (`PartyAData::vertical_split`), runs one feature-party thread per
//! slice, and the label party aggregates Σ_k Z_k.
//!
//! Artifact sets are compiled once per process and cached (`set_cache`) —
//! parameter state is per-run, so sweeps over (R, W, ξ, algorithm, seed,
//! parties) reuse the compiled executables.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::RunConfig;
use crate::data::{PartyAData, SynthDataset};
use crate::metrics::facade::Registry;
use crate::metrics::{MetricsExporter, RunRecord, RunRecordObserver};
use crate::runtime::ArtifactSet;
use crate::session::bootstrap::inproc_mesh;
use crate::session::{PartyId, SessionBuilder};

use super::feature_party::FeaturePartyReport;
use super::label_party::{LabelPartyReport, StopReason};

/// Outcome of one training run.
pub struct TrainOutcome {
    pub record: RunRecord,
    pub stop_reason: StopReason,
}

fn set_cache() -> &'static Mutex<HashMap<String, Arc<ArtifactSet>>> {
    use once_cell::sync::OnceCell;
    static CACHE: OnceCell<Mutex<HashMap<String, Arc<ArtifactSet>>>> =
        OnceCell::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Load (or fetch from cache) the artifact set for `cfg`.
pub fn load_set(cfg: &RunConfig) -> anyhow::Result<Arc<ArtifactSet>> {
    let tag = cfg.artifact_tag();
    let mut cache = set_cache().lock().unwrap();
    if let Some(set) = cache.get(&tag) {
        return Ok(set.clone());
    }
    let set = Arc::new(ArtifactSet::load_tagged(&cfg.artifacts_dir, &tag)?);
    cache.insert(tag, set.clone());
    Ok(set)
}

/// Generate the synthetic dataset for `cfg` (vocab from the manifest so
/// ids always index the embedding tables correctly).
pub fn load_data(cfg: &RunConfig, set: &ArtifactSet)
                 -> anyhow::Result<SynthDataset> {
    SynthDataset::generate(
        &cfg.dataset,
        set.manifest.vocab,
        cfg.train_instances,
        cfg.test_instances,
        cfg.label_noise,
        // Data seed is decoupled from the trial seed: trials re-sample
        // init/batching randomness, not the dataset itself.
        0xDA7A ^ cfg.seed / 1000,
    )
}

/// Vertically slice the Party-A feature space across `cfg`'s feature
/// parties and check every slice against the artifact manifest's
/// bottom-model input width. The two-party case moves the data instead
/// of calling `vertical_split(1)` (which clones): the full id matrix
/// is tens of MB at sweep scale and is about to be wrapped in an Arc
/// anyway. Shared by the in-proc trainer and the TCP deployment (which
/// keeps only its own slice).
pub fn feature_slices(
    cfg: &RunConfig,
    set: &ArtifactSet,
    train_a: PartyAData,
    test_a: PartyAData,
) -> anyhow::Result<(Vec<PartyAData>, Vec<PartyAData>)> {
    let k = cfg.feature_parties();
    let (train_slices, test_slices) = if k == 1 {
        (vec![train_a], vec![test_a])
    } else {
        (train_a.vertical_split(k)?, test_a.vertical_split(k)?)
    };
    if k > 1 {
        // The bottom-model artifact has a fixed input width; a K-party
        // run needs artifacts compiled for the per-party slice.
        for (i, s) in train_slices.iter().enumerate() {
            anyhow::ensure!(
                s.fields == set.manifest.fields_a,
                "artifact set '{}' compiles a {}-field bottom model but \
                 feature party {} holds {} of the vertically-split \
                 fields — compile per-party artifacts \
                 (python/compile/aot.py --parties {}) for --parties {}",
                cfg.artifact_tag(), set.manifest.fields_a, i + 1,
                s.fields, cfg.parties, cfg.parties
            );
        }
    }
    Ok((train_slices, test_slices))
}

/// Run one full K-party training job in-process (K = `cfg.parties`;
/// 2 is the classic two-party run).
pub fn run_training(cfg: &RunConfig) -> anyhow::Result<TrainOutcome> {
    cfg.validate()?;
    let set = load_set(cfg)?;
    anyhow::ensure!(
        cfg.train_instances >= set.manifest.batch,
        "train_instances {} < batch {}", cfg.train_instances,
        set.manifest.batch
    );
    let k = cfg.feature_parties();
    let data = load_data(cfg, &set)?;
    let (train_slices, test_slices) =
        feature_slices(cfg, &set, data.train_a, data.test_a)?;
    let train_b = Arc::new(data.train_b);
    let test_b = Arc::new(data.test_b);

    // Same bootstrap surface as the TCP deployment: the in-proc star is
    // just the pre-wired MeshBootstrap, so the trainer exercises the
    // exact session-construction path a K-process launch does. One
    // registry is shared by every party, so all 2(K−1) directed links
    // (and the label supervisor's lifecycle events) are visible through
    // a single scrape / push stream / terminal snapshot (DESIGN.md §10).
    let registry = Registry::new();
    let (label_bootstrap, feature_bootstraps) = inproc_mesh(cfg);
    let label_session =
        SessionBuilder::bootstrap_builder(cfg, label_bootstrap)?
            .with_registry(registry.clone())
            .build()?;

    let start = Instant::now();
    let mut handles = Vec::with_capacity(k);
    for ((i, bootstrap), (train, test)) in feature_bootstraps
        .into_iter()
        .enumerate()
        .zip(train_slices.into_iter().zip(test_slices))
    {
        let party = PartyId(i as u16 + 1);
        let session = SessionBuilder::bootstrap_builder(cfg, bootstrap)?
            .with_registry(registry.clone())
            .build()?;
        let set_f = set.clone();
        let train = Arc::new(train);
        let test = Arc::new(test);
        handles.push(
            std::thread::Builder::new()
                .name(format!("feature-{}", party.0))
                .spawn(move || -> anyhow::Result<FeaturePartyReport> {
                    session.run_feature(set_f, train, test)
                })?,
        );
    }
    let b_report: LabelPartyReport =
        label_session.run_label(set.clone(), train_b, test_b)?;
    let mut feature_reports = Vec::with_capacity(k);
    for h in handles {
        feature_reports.push(h.join().expect("feature party panicked")?);
    }
    let wall = start.elapsed();

    // Per-link accounting: one row per directed link of the star, from
    // the shared registry (whose rows survived any transport swaps a
    // supervised run performed — rejoins charge the old totals onto the
    // fresh handles). The terminal observer is the RunRecord's leg of
    // the exporter API: scrape, push and this snapshot all read the
    // same rows, which is what the `scrape_k3` parity gate pins.
    let observer = RunRecordObserver::new();
    observer.export(&registry)?;
    let links = observer.links();
    let events = observer.events();
    let comm_busy: Duration = registry
        .link_rows()
        .iter()
        .map(|r| r.stats.busy)
        .sum();

    debug_assert!(feature_reports
        .iter()
        .all(|r| r.comm_rounds == b_report.comm_rounds));
    let feature_local_updates: Vec<u64> =
        feature_reports.iter().map(|r| r.local_updates).collect();
    let primary = feature_reports.swap_remove(0);
    let record = RunRecord {
        label: format!("{}/{}", cfg.algorithm.name(), cfg.artifact_tag()),
        series: b_report.series,
        cosine: primary.cosine,
        cosine_b: b_report.cosine,
        comm_rounds: b_report.comm_rounds,
        exact_updates: b_report.exact_updates,
        local_updates: b_report.local_updates,
        feature_local_updates,
        links,
        comm_busy,
        wall,
        compute_busy: set.clock_a.busy() + set.clock_b.busy(),
        events,
    };
    log::info!(
        "run {} finished: {} parties, {} rounds, {} local updates \
         (label), wall {:.1}s, comm busy {:.1}s ({:.0}% per link)",
        record.label,
        cfg.parties,
        record.comm_rounds,
        record.local_updates,
        wall.as_secs_f64(),
        record.comm_busy.as_secs_f64(),
        // comm_busy sums every directed link, so the per-link average
        // divides by the link count (2 for the two-party run).
        100.0 * record.comm_fraction() / record.links.len().max(1) as f64
    );
    Ok(TrainOutcome { record, stop_reason: b_report.stop_reason })
}

/// Run `cfg.trials` trials with seeds seed, seed+1, … and return the
/// per-trial records.
pub fn run_trials(cfg: &RunConfig) -> anyhow::Result<Vec<TrainOutcome>> {
    let mut outcomes = Vec::with_capacity(cfg.trials);
    for t in 0..cfg.trials.max(1) {
        let mut c = cfg.clone();
        c.seed = cfg.seed + t as u64;
        outcomes.push(run_training(&c)?);
    }
    Ok(outcomes)
}
