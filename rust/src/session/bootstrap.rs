//! Session bootstrap: how meshes come into existence (DESIGN.md §7).
//!
//! The earlier TCP path hard-wired the degenerate two-process topology
//! (dial exactly one peer). This module replaces that with a
//! listener/acceptor session-server API so the paper's actual
//! deployment shape — one label party, K−1 geo-distributed feature
//! parties — can be launched as K OS processes:
//!
//! - [`SessionListener`] (label side): bind once, accept connections
//!   until every expected feature party has presented a valid
//!   [`Message::Join`] frame (claimed [`PartyId`] + codec
//!   capabilities), answering each with a [`Message::JoinAck`].
//!   Duplicate ids, out-of-range ids, wrong-version joins and
//!   wrong-size sessions are rejected (connection dropped, loudly
//!   logged) without disturbing the peers that already joined; if the
//!   mesh is still incomplete at the deadline, `establish` fails
//!   naming exactly the parties that never arrived.
//! - [`SessionDialer`] (feature side): connect with exponential
//!   backoff until the label party is up (launch order between shells
//!   must not matter), send `Join`, verify the `JoinAck` echoes this
//!   party's id and session size.
//! - [`MeshBootstrap`] unifies the above with the in-proc star
//!   ([`inproc_mesh`]): `SessionBuilder::from_bootstrap` produces the
//!   same topology-validated [`Session`](super::Session) object
//!   regardless of transport, so the trainer and the CLI are
//!   transport-agnostic.
//!
//! The handshake runs on the **raw socket**, before the
//! [`TcpTransport`] is constructed: `LinkStats` therefore counts
//! training traffic only, and a K-party TCP session's per-link byte
//! totals are identical to the in-proc mesh of the same config (the
//! `tcp_mesh_k3` example asserts this in CI). Two-party sessions keep
//! v1 (headerless) training frames — byte-identical to the historic
//! wire — while `parties > 2` promotes every link to v2 identity
//! framing via [`TcpTransport::with_identity`].

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::compress;
use crate::config::RunConfig;
use crate::protocol::{decode_frame, encode_frame_into, Message};
use crate::transport::tcp::{connect_with_backoff, TcpTransport};
use crate::transport::Transport;

use super::{inproc_star, Link, PartyId, LABEL_PARTY};

/// Default time budget for a mesh to assemble. Generous because the
/// human launching three shells is part of the loop; override with
/// [`SessionListener::with_timeout`] / [`SessionDialer::with_timeout`].
pub const DEFAULT_JOIN_TIMEOUT: Duration = Duration::from_secs(30);

/// Hard cap on a bootstrap frame body. `Join`/`JoinAck` are fixed
/// 18-byte bodies; anything longer is not a session peer, and the cap
/// is checked before the body buffer is allocated (the hostile-header
/// discipline of the protocol layer, applied to the socket read).
const MAX_BOOTSTRAP_FRAME: usize = 64;

/// Poll interval of the accept loop while waiting for joiners.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Cap on how long `admit` waits for one connection's `Join` frame.
/// The accept loop vets joiners serially, so this must be small: a
/// connection that never speaks (health-check probe, port scanner)
/// may stall the loop for at most this long, not the whole join
/// window.
const JOIN_READ_TIMEOUT: Duration = Duration::from_secs(2);

/// One way of bringing a party's mesh into existence. Implementations
/// carry everything transport-specific (sockets, deadlines, pre-wired
/// channels); `SessionBuilder::from_bootstrap` consumes one and
/// produces the same topology-validated `Session` regardless of which
/// implementation did the wiring.
pub trait MeshBootstrap {
    /// The party this bootstrap assembles links for.
    fn id(&self) -> PartyId;

    /// Block until every link exists (or fail). Returns one [`Link`]
    /// per peer; `SessionBuilder::build` re-validates the topology.
    fn establish(self, cfg: &RunConfig) -> anyhow::Result<Vec<Link>>
    where
        Self: Sized;
}

// ---- in-proc ---------------------------------------------------------------

/// Pre-wired in-proc bootstrap: the links already exist (channel pairs
/// coupled at construction), so `establish` just hands them over. One
/// value per party; see [`inproc_mesh`].
pub struct InprocBootstrap {
    id: PartyId,
    links: Vec<Link>,
}

impl MeshBootstrap for InprocBootstrap {
    fn id(&self) -> PartyId {
        self.id
    }

    fn establish(self, _cfg: &RunConfig) -> anyhow::Result<Vec<Link>> {
        Ok(self.links)
    }
}

/// Build the in-proc star for `cfg.parties` parties as bootstrap
/// values: the label party's bootstrap (K−1 links) plus one bootstrap
/// per feature party in id order (1..K), each holding its single link
/// back to the label party. The in-proc analogue of one
/// [`SessionListener`] + K−1 [`SessionDialer`]s, minus the handshake —
/// channel pairs are coupled at construction, so identity is
/// structural and there is nothing to verify.
pub fn inproc_mesh(cfg: &RunConfig)
                   -> (InprocBootstrap, Vec<InprocBootstrap>) {
    let (label_links, feature_links) = inproc_star(cfg);
    let features = feature_links
        .into_iter()
        .enumerate()
        .map(|(i, link)| InprocBootstrap {
            id: PartyId(i as u16 + 1),
            links: vec![link],
        })
        .collect();
    (InprocBootstrap { id: LABEL_PARTY, links: label_links }, features)
}

// ---- TCP: label side -------------------------------------------------------

/// Label-party session server: bind once, accept K−1 identified
/// connections, assemble the star mesh.
pub struct SessionListener {
    listener: TcpListener,
    timeout: Duration,
}

impl SessionListener {
    /// Bind the session listener. Accepting (and the join deadline)
    /// starts at `establish`, so a bound listener can be advertised
    /// (e.g. print [`Self::local_addr`]) before the mesh assembles.
    pub fn bind(addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr).map_err(|e| {
            anyhow::anyhow!("binding session listener on {addr}: {e}")
        })?;
        Ok(SessionListener { listener, timeout: DEFAULT_JOIN_TIMEOUT })
    }

    /// Replace the default join deadline.
    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Vet one accepted connection: read its `Join`, enforce the
    /// session-level rules the codec cannot (size agreement, no
    /// duplicates), ack it. Frame-level rules (version, id ranges) are
    /// already enforced by `Message::decode` before this sees a
    /// `Join` at all.
    fn admit(mut stream: TcpStream, parties: u16,
             joined: &BTreeMap<u16, TcpStream>, deadline: Instant)
             -> anyhow::Result<(u16, TcpStream)> {
        // Accepted sockets must not inherit the listener's
        // non-blocking mode. The whole Join frame is bounded by
        // JOIN_READ_TIMEOUT (not the remaining join window): the
        // accept loop vets joiners serially, so a peer that connects
        // but never speaks — or trickles bytes — may stall it for at
        // most this long, never monopolize it.
        stream.set_nonblocking(false)?;
        let frame_deadline =
            (Instant::now() + JOIN_READ_TIMEOUT).min(deadline);
        let (party, claimed, codecs) =
            match recv_bootstrap_frame(&mut stream, frame_deadline)? {
                Message::Join { party, parties, codecs } => {
                    (party, parties, codecs)
                }
                other => anyhow::bail!(
                    "expected Join, got message tag {}", other.tag()),
            };
        anyhow::ensure!(
            claimed == parties,
            "{party} joined for a {claimed}-party session, this \
             listener hosts {parties} parties — config mismatch"
        );
        anyhow::ensure!(
            !joined.contains_key(&party.0),
            "duplicate join: {party} is already in the session"
        );
        log::info!(
            "session listener: {party} joined ({}/{} feature parties, \
             codec mask {codecs:#x})",
            joined.len() + 1,
            parties - 1
        );
        send_bootstrap_frame(&mut stream, &Message::JoinAck {
            party,
            parties,
            codecs: compress::supported_mask(),
        })?;
        Ok((party.0, stream))
    }
}

impl MeshBootstrap for SessionListener {
    fn id(&self) -> PartyId {
        LABEL_PARTY
    }

    /// Accept until ids 1..`cfg.parties` have all joined, then wrap
    /// each socket into a [`TcpTransport`] (identity-framed when the
    /// session spans more than two parties). A rejected joiner is
    /// dropped — its dialer observes EOF instead of a `JoinAck` — and
    /// the loop keeps serving; the deadline failure names exactly the
    /// ids still missing.
    fn establish(self, cfg: &RunConfig) -> anyhow::Result<Vec<Link>> {
        cfg.validate()?;
        let parties = cfg.parties as u16;
        let expected = parties - 1;
        let deadline = Instant::now() + self.timeout;
        self.listener.set_nonblocking(true)?;
        let mut joined: BTreeMap<u16, TcpStream> = BTreeMap::new();
        while (joined.len() as u16) < expected {
            // Deadline check at the top of the loop, not only on idle:
            // a steady stream of junk connections keeps accept()
            // succeeding and must not defer the timeout forever.
            if Instant::now() >= deadline {
                let missing: Vec<String> = (1..parties)
                    .filter(|id| !joined.contains_key(id))
                    .map(|id| format!("P{id}"))
                    .collect();
                anyhow::bail!(
                    "session bootstrap timed out after {:?}: {} of {} \
                     feature parties never joined ({})",
                    self.timeout,
                    missing.len(),
                    expected,
                    missing.join(", ")
                );
            }
            match self.listener.accept() {
                Ok((stream, peer_addr)) => {
                    match Self::admit(stream, parties, &joined, deadline) {
                        Ok((id, stream)) => {
                            joined.insert(id, stream);
                        }
                        Err(e) => log::warn!(
                            "session listener: rejected {peer_addr}: {e:#}"
                        ),
                    }
                }
                Err(e) if e.kind()
                    == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(e) => {
                    return Err(anyhow::anyhow!(
                        "session listener accept: {e}"
                    ))
                }
            }
        }
        let v2 = parties > 2;
        joined
            .into_iter()
            .map(|(id, stream)| {
                stream.set_read_timeout(None)?;
                let peer = PartyId(id);
                let mut t = TcpTransport::from_stream(stream, cfg.wan)?;
                if v2 {
                    t = t.with_identity(LABEL_PARTY, peer);
                }
                Ok(Link { peer, transport: Arc::new(t) as Arc<dyn Transport> })
            })
            .collect()
    }
}

// ---- TCP: feature side -----------------------------------------------------

/// Feature-party dialer: connect (with backoff, so launch order
/// between shells doesn't matter), claim an id via `Join`, verify the
/// `JoinAck`.
pub struct SessionDialer {
    addr: String,
    party: PartyId,
    timeout: Duration,
}

impl SessionDialer {
    pub fn new(addr: &str, party: PartyId) -> Self {
        SessionDialer {
            addr: addr.to_string(),
            party,
            timeout: DEFAULT_JOIN_TIMEOUT,
        }
    }

    /// Replace the default connect/join deadline.
    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }
}

impl MeshBootstrap for SessionDialer {
    fn id(&self) -> PartyId {
        self.party
    }

    fn establish(self, cfg: &RunConfig) -> anyhow::Result<Vec<Link>> {
        cfg.validate()?;
        let parties = cfg.parties as u16;
        anyhow::ensure!(
            self.party.0 >= 1 && self.party.0 < parties,
            "feature party id {} out of range for a {parties}-party \
             session (valid: 1..={})",
            self.party,
            parties - 1
        );
        let deadline = Instant::now() + self.timeout;
        let mut stream = connect_with_backoff(&self.addr, deadline)
            .map_err(|e| anyhow::anyhow!(
                "{}: label party at {} never came up: {e:#}",
                self.party, self.addr
            ))?;
        send_bootstrap_frame(&mut stream, &Message::Join {
            party: self.party,
            parties,
            codecs: compress::supported_mask(),
        })?;
        // The ack may legitimately take a while (the listener vets
        // joiners serially), so it gets the whole remaining window —
        // but bounded end to end, not per read.
        let ack = recv_bootstrap_frame(&mut stream, deadline).map_err(|e| {
            anyhow::anyhow!(
                "{}: no JoinAck from the label party at {} — the join \
                 was rejected (duplicate id? config mismatch?) or the \
                 listener died: {e:#}",
                self.party, self.addr
            )
        })?;
        let (party, acked, codecs) = match ack {
            Message::JoinAck { party, parties, codecs } => {
                (party, parties, codecs)
            }
            other => anyhow::bail!(
                "{}: expected JoinAck, got message tag {}",
                self.party, other.tag()
            ),
        };
        anyhow::ensure!(
            party == self.party,
            "label party acked {party}, but this process joined as {}",
            self.party
        );
        anyhow::ensure!(
            acked == parties,
            "session size mismatch: label party hosts {acked} parties, \
             this config says {parties}"
        );
        log::info!(
            "{} joined the {parties}-party session at {} (label codec \
             mask {codecs:#x})",
            self.party, self.addr
        );
        stream.set_read_timeout(None)?;
        let mut t = TcpTransport::from_stream(stream, cfg.wan)?;
        if parties > 2 {
            t = t.with_identity(self.party, LABEL_PARTY);
        }
        Ok(vec![Link {
            peer: LABEL_PARTY,
            transport: Arc::new(t) as Arc<dyn Transport>,
        }])
    }
}

// ---- raw-socket frame I/O --------------------------------------------------

/// Write one headerless (v1) frame to a raw bootstrap socket.
fn send_bootstrap_frame(stream: &mut TcpStream, msg: &Message)
                        -> anyhow::Result<()> {
    let mut buf = Vec::with_capacity(msg.wire_bytes());
    encode_frame_into(None, msg, &mut buf);
    stream.write_all(&buf)?;
    stream.flush()?;
    Ok(())
}

/// `read_exact` with an overall deadline: the socket read timeout is
/// shrunk to the remainder before every read syscall, so a
/// byte-trickling peer cannot stretch one frame past `deadline` the
/// way a plain per-read timeout would allow (each drip resets a
/// per-read clock; it cannot reset this one).
fn read_exact_deadline(stream: &mut TcpStream, buf: &mut [u8],
                       deadline: Instant) -> anyhow::Result<()> {
    use std::io::ErrorKind;
    let mut filled = 0;
    while filled < buf.len() {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            anyhow::bail!("timed out mid-frame ({filled}/{} bytes)",
                          buf.len());
        }
        stream.set_read_timeout(Some(remaining))?;
        match stream.read(&mut buf[filled..]) {
            Ok(0) => anyhow::bail!("connection closed mid-frame"),
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock
                || e.kind() == ErrorKind::TimedOut => {
                anyhow::bail!("timed out mid-frame ({filled}/{} bytes)",
                              buf.len())
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Read one headerless frame from a raw bootstrap socket, bounded by
/// `deadline` end to end. The length word is capped at
/// [`MAX_BOOTSTRAP_FRAME`] *before* the body buffer is allocated: a
/// peer that opens with a multi-MiB length (or any non-bootstrap
/// traffic) is refused by arithmetic alone.
fn recv_bootstrap_frame(stream: &mut TcpStream, deadline: Instant)
                        -> anyhow::Result<Message> {
    let mut len_buf = [0u8; 4];
    read_exact_deadline(stream, &mut len_buf, deadline)
        .map_err(|e| anyhow::anyhow!("reading bootstrap frame: {e:#}"))?;
    let len = u32::from_le_bytes(len_buf) as usize;
    anyhow::ensure!(
        len > 0 && len <= MAX_BOOTSTRAP_FRAME,
        "bootstrap frame of {len} bytes (max {MAX_BOOTSTRAP_FRAME}) — \
         peer is not speaking the session handshake"
    );
    let mut buf = vec![0u8; len];
    read_exact_deadline(stream, &mut buf, deadline)
        .map_err(|e| anyhow::anyhow!("reading bootstrap frame: {e:#}"))?;
    let (header, msg) = decode_frame(&buf)?;
    anyhow::ensure!(
        header.is_none(),
        "bootstrap frames are headerless — link identity is \
         established by Join itself, not the v2 envelope"
    );
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WanProfile;
    use crate::protocol::FRAME_V2_OVERHEAD;
    use crate::session::SessionBuilder;

    fn cfg_with_parties(k: usize) -> RunConfig {
        let mut cfg = RunConfig::quick();
        cfg.parties = k;
        cfg.wan = WanProfile::instant();
        cfg
    }

    /// Raw-socket joiner for handshake-level tests: sends `Join`, then
    /// returns the ack (or the receive error).
    fn raw_join(addr: &str, party: u16, parties: u16)
                -> anyhow::Result<(TcpStream, Message)> {
        let mut s = TcpStream::connect(addr)?;
        send_bootstrap_frame(&mut s, &Message::Join {
            party: PartyId(party),
            parties,
            codecs: compress::supported_mask(),
        })?;
        let ack = recv_bootstrap_frame(
            &mut s, Instant::now() + Duration::from_secs(5))?;
        Ok((s, ack))
    }

    #[test]
    fn k3_bootstrap_assembles_and_exchanges_v2_frames() {
        let cfg = cfg_with_parties(3);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || SessionBuilder::from_bootstrap(&cfg, listener)
        });
        let mut dialers = Vec::new();
        for p in [1u16, 2] {
            let cfg = cfg.clone();
            let addr = addr.clone();
            dialers.push(std::thread::spawn(move || {
                let session = SessionBuilder::from_bootstrap(
                    &cfg,
                    SessionDialer::new(&addr, PartyId(p))
                        .with_timeout(Duration::from_secs(10)),
                )
                .unwrap();
                // One frame each way proves the link is live and
                // identity-framed.
                let t = &session.mesh().links()[0].transport;
                t.send(Message::EvalAck { round: p as u64 }).unwrap();
                assert_eq!(t.recv().unwrap().round(), 100 + p as u64);
                t.stats()
            }));
        }
        let session = label.join().unwrap().unwrap();
        assert_eq!(session.id(), LABEL_PARTY);
        assert_eq!(session.mesh().len(), 2);
        for p in [1u16, 2] {
            let t = session.mesh().transport(PartyId(p)).unwrap();
            assert_eq!(t.recv().unwrap().round(), p as u64);
            t.send(Message::EvalAck { round: 100 + p as u64 }).unwrap();
        }
        for d in dialers {
            let stats = d.join().unwrap();
            // K > 2: the v2 envelope is charged, and the Join/JoinAck
            // handshake is NOT (it ran pre-transport), so the per-link
            // accounting equals exactly one framed EvalAck.
            assert_eq!(
                stats.bytes,
                (Message::EvalAck { round: 0 }.wire_bytes()
                 + FRAME_V2_OVERHEAD) as u64
            );
            assert_eq!(stats.messages, 1);
        }
    }

    #[test]
    fn two_party_bootstrap_keeps_v1_framing() {
        let cfg = cfg_with_parties(2);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || SessionBuilder::from_bootstrap(&cfg, listener)
        });
        let feature = SessionBuilder::from_bootstrap(
            &cfg,
            SessionDialer::new(&addr, PartyId(1))
                .with_timeout(Duration::from_secs(10)),
        )
        .unwrap();
        let session = label.join().unwrap().unwrap();
        let msg = Message::EvalAck { round: 9 };
        let ft = &feature.mesh().links()[0].transport;
        ft.send(msg.clone()).unwrap();
        assert_eq!(
            session.mesh().transport(PartyId(1)).unwrap().recv().unwrap(),
            msg
        );
        // No envelope: the training wire is the historic v1 stream.
        assert_eq!(ft.stats().bytes, msg.wire_bytes() as u64);
    }

    #[test]
    fn duplicate_and_hostile_joins_are_rejected_without_killing_the_mesh() {
        let cfg = cfg_with_parties(3);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || SessionBuilder::from_bootstrap(&cfg, listener)
        });

        // 1. P1 joins cleanly.
        let (_s1, ack1) = raw_join(&addr, 1, 3).unwrap();
        assert!(matches!(ack1, Message::JoinAck { party: PartyId(1), .. }));

        // 2. A duplicate P1 is refused: the connection is dropped
        //    before any ack, so the dialer sees EOF, not a JoinAck.
        assert!(raw_join(&addr, 1, 3).is_err(), "duplicate id acked");

        // 3. A join for the wrong session size is refused.
        assert!(raw_join(&addr, 1, 2).is_err(), "wrong-size join acked");

        // 4. A wrong-version join dies at decode (listener side).
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            let mut frame = Message::Join {
                party: PartyId(2),
                parties: 3,
                codecs: 0,
            }
            .encode();
            frame[9] = 9; // bend the join version byte
            let mut framed =
                ((frame.len() as u32).to_le_bytes()).to_vec();
            framed.extend_from_slice(&frame);
            s.write_all(&framed).unwrap();
            assert!(recv_bootstrap_frame(
                        &mut s, Instant::now() + Duration::from_secs(5))
                    .is_err(),
                    "wrong version acked");
        }

        // 5. An out-of-range id dies at decode likewise (the id never
        //    reaches session logic).
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            let mut frame = Message::Join {
                party: PartyId(2),
                parties: 3,
                codecs: 0,
            }
            .encode();
            frame[10] = 0x30; // party := 0x30 = 48 ≥ parties
            let mut framed =
                ((frame.len() as u32).to_le_bytes()).to_vec();
            framed.extend_from_slice(&frame);
            s.write_all(&framed).unwrap();
            assert!(recv_bootstrap_frame(
                        &mut s, Instant::now() + Duration::from_secs(5))
                    .is_err(),
                    "out-of-range id acked");
        }

        // 6. The legitimate P2 still completes the mesh.
        let (_s2, ack2) = raw_join(&addr, 2, 3).unwrap();
        assert!(matches!(ack2, Message::JoinAck { party: PartyId(2), .. }));
        let session = label.join().unwrap().unwrap();
        assert_eq!(session.mesh().len(), 2);
    }

    #[test]
    fn a_mute_connection_cannot_wedge_the_bootstrap() {
        // A probe that connects and never finishes a frame (health
        // check, port scan, byte-trickler) may stall the serial accept
        // loop for at most JOIN_READ_TIMEOUT — the frame read is
        // bounded end to end, so partial bytes don't reset the clock —
        // and the real joiner behind it must still be admitted.
        let cfg = cfg_with_parties(2);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish(&cfg)
        });
        let mut probe = TcpStream::connect(&addr).unwrap();
        // Half a length word, then silence: exercises the mid-frame
        // deadline, not just the never-spoke path.
        probe.write_all(&[0x12, 0x00]).unwrap();
        // Let the probe reach the accept loop first.
        std::thread::sleep(Duration::from_millis(100));
        let (_s, ack) = raw_join(&addr, 1, 2).unwrap();
        assert!(matches!(ack, Message::JoinAck { party: PartyId(1), .. }));
        let links = label.join().unwrap().unwrap();
        assert_eq!(links.len(), 1);
    }

    #[test]
    fn listener_timeout_names_the_missing_parties() {
        let cfg = cfg_with_parties(4);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_millis(400));
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish(&cfg)
        });
        // Only P2 of {1, 2, 3} shows up.
        let (_s, _ack) = raw_join(&addr, 2, 4).unwrap();
        let e = label.join().unwrap().unwrap_err().to_string();
        assert!(e.contains("P1") && e.contains("P3"),
                "missing ids not named: {e}");
        assert!(!e.contains("P2,") && !e.contains("P2)"),
                "joined id wrongly reported missing: {e}");
    }

    #[test]
    fn dialer_retries_until_the_listener_binds() {
        // Launch order must not matter: the dialer backs off until the
        // label party appears.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe); // free the port (racy but fine, as elsewhere)
        let cfg = cfg_with_parties(2);
        let dialer = std::thread::spawn({
            let cfg = cfg.clone();
            let addr = addr.clone();
            move || {
                SessionDialer::new(&addr, PartyId(1))
                    .with_timeout(Duration::from_secs(10))
                    .establish(&cfg)
            }
        });
        std::thread::sleep(Duration::from_millis(300));
        let listener = SessionListener::bind(&addr)
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let links = listener.establish(&cfg).unwrap();
        assert_eq!(links.len(), 1);
        let dlinks = dialer.join().unwrap().unwrap();
        assert_eq!(dlinks[0].peer, LABEL_PARTY);
    }

    #[test]
    fn dialer_rejects_out_of_range_ids_locally() {
        let cfg = cfg_with_parties(3);
        for bad in [0u16, 3, 9] {
            let e = SessionDialer::new("127.0.0.1:1", PartyId(bad))
                .establish(&cfg);
            assert!(e.is_err(), "party {bad} dialed");
        }
    }

    #[test]
    fn inproc_mesh_bootstraps_every_party() {
        let cfg = cfg_with_parties(3);
        let (label_bs, feature_bs) = inproc_mesh(&cfg);
        assert_eq!(label_bs.id(), LABEL_PARTY);
        let session =
            SessionBuilder::from_bootstrap(&cfg, label_bs).unwrap();
        assert_eq!(session.mesh().len(), 2);
        for (i, bs) in feature_bs.into_iter().enumerate() {
            let p = PartyId(i as u16 + 1);
            assert_eq!(bs.id(), p);
            let fs = SessionBuilder::from_bootstrap(&cfg, bs).unwrap();
            fs.mesh().links()[0]
                .transport
                .send(Message::EvalAck { round: p.0 as u64 })
                .unwrap();
            assert_eq!(
                session.mesh().transport(p).unwrap().recv().unwrap()
                    .round(),
                p.0 as u64
            );
        }
    }
}
