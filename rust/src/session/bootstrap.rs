//! Session bootstrap: how meshes come into existence (DESIGN.md §7).
//!
//! The earlier TCP path hard-wired the degenerate two-process topology
//! (dial exactly one peer). This module replaces that with a
//! listener/acceptor session-server API so the paper's actual
//! deployment shape — one label party, K−1 geo-distributed feature
//! parties — can be launched as K OS processes:
//!
//! - [`SessionListener`] (label side): bind once, accept connections
//!   until every expected feature party has presented a valid
//!   [`Message::Join`] frame (claimed [`PartyId`] + codec
//!   capabilities), answering each with a [`Message::JoinAck`].
//!   Duplicate ids, out-of-range ids, wrong-version joins and
//!   wrong-size sessions are rejected (connection dropped, loudly
//!   logged) without disturbing the peers that already joined; if the
//!   mesh is still incomplete at the deadline, `establish` fails
//!   naming exactly the parties that never arrived.
//! - [`SessionDialer`] (feature side): connect with exponential
//!   backoff until the label party is up (launch order between shells
//!   must not matter), send `Join`, verify the `JoinAck` echoes this
//!   party's id and session size.
//! - [`MeshBootstrap`] unifies the above with the in-proc star
//!   ([`inproc_mesh`]): `SessionBuilder::from_bootstrap` produces the
//!   same topology-validated [`Session`](super::Session) object
//!   regardless of transport, so the trainer and the CLI are
//!   transport-agnostic.
//!
//! The handshake runs on the **raw socket**, before the
//! [`TcpTransport`] is constructed: `LinkStats` therefore counts
//! training traffic only, and a K-party TCP session's per-link byte
//! totals are identical to the in-proc mesh of the same config (the
//! `tcp_mesh_k3` example asserts this in CI). Two-party sessions keep
//! v1 (headerless) training frames — byte-identical to the historic
//! wire — while `parties > 2` promotes every link to v2 identity
//! framing via [`TcpTransport::with_identity`].

use std::collections::{BTreeMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::compress;
use crate::config::RunConfig;
use crate::metrics::exporters::prometheus;
use crate::metrics::exporters::push::PushExporter;
use crate::metrics::facade::Registry;
use crate::metrics::MetricsExporter;
use crate::protocol::{decode_frame, encode_frame_into, Message,
                      RejectReason};
use crate::transport::tcp::{connect_with_backoff_jittered, TcpTransport};
use crate::transport::Transport;

use super::supervisor::session_epoch;
use super::{inproc_star, Link, PartyId, LABEL_PARTY};

/// Default time budget for a mesh to assemble. Generous because the
/// human launching three shells is part of the loop; override with
/// [`SessionListener::with_timeout`] / [`SessionDialer::with_timeout`].
pub const DEFAULT_JOIN_TIMEOUT: Duration = Duration::from_secs(30);

/// Hard cap on a bootstrap frame body. `Join`/`JoinAck` are fixed
/// 18-byte bodies and `Rejoin`/`RejoinAck` fixed 30-byte bodies;
/// anything longer is not a session peer, and the cap is checked
/// before the body buffer is allocated (the hostile-header discipline
/// of the protocol layer, applied to the socket read).
pub(crate) const MAX_BOOTSTRAP_FRAME: usize = 64;

/// Poll interval of the accept loop while waiting for joiners.
pub(crate) const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Cap on how long one connection's `Join`/`Rejoin` frame read may
/// take. Frame reads run on a bounded admit pool (see
/// [`ADMIT_WORKERS`]), so a connection that never speaks (health-check
/// probe, port scanner) ties up one pool slot for at most this long —
/// never the accept loop itself.
pub(crate) const JOIN_READ_TIMEOUT: Duration = Duration::from_secs(2);

/// Bound on concurrently-vetted joiners. The accept loop used to vet
/// serially, so at K=64 cold start one slow peer (or a stream of junk
/// probes) amplified into a stalled bootstrap for everyone behind it;
/// with a pool, up to this many frame reads run in parallel while the
/// accept loop keeps accepting. Session-level validation (size
/// agreement, duplicates) stays on the accept thread, where the joined
/// map lives.
const ADMIT_WORKERS: usize = 8;

/// Cap on one HTTP-shaped request's header block on the session port.
/// A scrape request is a few dozen bytes; anything bigger is not a
/// scraper.
pub(crate) const MAX_HTTP_REQUEST: usize = 1024;

/// Cadence of the `/watch` push stream: one cumulative tag-14
/// [`Message::Metrics`] frame per tick.
const WATCH_TICK: Duration = Duration::from_millis(250);

/// One way of bringing a party's mesh into existence. Implementations
/// carry everything transport-specific (sockets, deadlines, pre-wired
/// channels); `SessionBuilder::from_bootstrap` consumes one and
/// produces the same topology-validated `Session` regardless of which
/// implementation did the wiring.
pub trait MeshBootstrap {
    /// The party this bootstrap assembles links for.
    fn id(&self) -> PartyId;

    /// Block until every link exists (or fail). Returns one [`Link`]
    /// per peer; `SessionBuilder::build` re-validates the topology.
    fn establish(self, cfg: &RunConfig) -> anyhow::Result<Vec<Link>>
    where
        Self: Sized;
}

// ---- in-proc ---------------------------------------------------------------

/// Pre-wired in-proc bootstrap: the links already exist (channel pairs
/// coupled at construction), so `establish` just hands them over. One
/// value per party; see [`inproc_mesh`].
pub struct InprocBootstrap {
    id: PartyId,
    links: Vec<Link>,
}

impl MeshBootstrap for InprocBootstrap {
    fn id(&self) -> PartyId {
        self.id
    }

    fn establish(self, _cfg: &RunConfig) -> anyhow::Result<Vec<Link>> {
        Ok(self.links)
    }
}

/// Build the in-proc star for `cfg.parties` parties as bootstrap
/// values: the label party's bootstrap (K−1 links) plus one bootstrap
/// per feature party in id order (1..K), each holding its single link
/// back to the label party. The in-proc analogue of one
/// [`SessionListener`] + K−1 [`SessionDialer`]s, minus the handshake —
/// channel pairs are coupled at construction, so identity is
/// structural and there is nothing to verify.
pub fn inproc_mesh(cfg: &RunConfig)
                   -> (InprocBootstrap, Vec<InprocBootstrap>) {
    let (label_links, feature_links) = inproc_star(cfg);
    // Both ends live in one process, so each peer's decodable codec
    // mask is known structurally — the in-proc analogue of the
    // Join/JoinAck mask exchange, letting coordinators pre-negotiate
    // and skip the first-round Hello exactly like a TCP session.
    let mask = compress::supported_mask();
    let features = feature_links
        .into_iter()
        .enumerate()
        .map(|(i, link)| InprocBootstrap {
            id: PartyId(i as u16 + 1),
            links: vec![link.with_peer_codecs(mask)],
        })
        .collect();
    let label_links = label_links
        .into_iter()
        .map(|l| l.with_peer_codecs(mask))
        .collect();
    (InprocBootstrap { id: LABEL_PARTY, links: label_links }, features)
}

// ---- TCP: label side -------------------------------------------------------

/// Label-party session server: bind once, accept K−1 identified
/// connections, assemble the star mesh. In resume mode
/// ([`Self::with_resume`]) the listener instead expects `Rejoin`
/// frames from the parties of a checkpointed session; and via
/// [`Self::establish_supervised`] it stays alive *after* bootstrap as
/// the session's re-admission point ([`Readmission`]).
pub struct SessionListener {
    listener: TcpListener,
    timeout: Duration,
    /// `Some((epoch, resume_round))` when restarting from a checkpoint:
    /// joiners must present `Rejoin` with this epoch and are acked with
    /// this resume round.
    resume: Option<(u32, u64)>,
    /// Registry served on this port (DESIGN.md §10): a connection whose
    /// first four bytes are `GET ` is an observability request, not a
    /// bootstrap peer — `/metrics` gets a one-shot Prometheus text
    /// exposition, `/watch` (once the session is live) a tag-14 push
    /// stream. `None` treats HTTP-shaped traffic as hostile, exactly as
    /// before the observability plane existed.
    metrics: Option<Arc<Registry>>,
    /// Shared-token gate on the observability endpoints: when set,
    /// `GET /metrics` / `GET /watch` must carry
    /// `Authorization: Bearer <token>` or they get a 401. Sessions
    /// (Join/Rejoin frames) are never gated — parties authenticate by
    /// epoch, not by header.
    token: Option<String>,
}

/// Outcome of session-level vetting: admit (with the ack to send), or
/// refuse with a frame-level reason the dialer can log. `Refuse` is
/// reserved for *resume-mode* refusals of otherwise well-formed peers —
/// a dialer racing the epoch check deserves "epoch mismatch (snapshot
/// is round R)", not a bare EOF. Hostile or malformed traffic stays a
/// plain error (silent drop): junk earns no diagnostic frame.
enum Vetted {
    Admit { party: PartyId, codecs: u32, ack: Message },
    Refuse { reject: Message, why: String },
}

impl SessionListener {
    /// Bind the session listener. Accepting (and the join deadline)
    /// starts at `establish`, so a bound listener can be advertised
    /// (e.g. print [`Self::local_addr`]) before the mesh assembles.
    pub fn bind(addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr).map_err(|e| {
            anyhow::anyhow!("binding session listener on {addr}: {e}")
        })?;
        Ok(SessionListener {
            listener,
            timeout: DEFAULT_JOIN_TIMEOUT,
            resume: None,
            metrics: None,
            token: None,
        })
    }

    /// Replace the default join deadline.
    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }

    /// Serve the observability plane on the session port: `GET
    /// /metrics` scrapes `registry` as Prometheus text, `GET /watch`
    /// (served by the re-admission point once the mesh is live) streams
    /// cumulative tag-14 metric frames. Join/Rejoin vetting is
    /// untouched — the dispatch happens on the first four bytes, before
    /// any frame logic runs.
    pub fn with_metrics(mut self, registry: Arc<Registry>) -> Self {
        self.metrics = Some(registry);
        self
    }

    /// Gate the observability endpoints behind a shared token
    /// (`Authorization: Bearer <token>`): unauthenticated `/metrics`
    /// and `/watch` requests get a 401. An empty token means open —
    /// the pre-auth behaviour — so a config's `metrics_token = ""`
    /// default plumbs through as a no-op.
    pub fn with_auth_token(mut self, token: &str) -> Self {
        self.token = if token.is_empty() {
            None
        } else {
            Some(token.to_string())
        };
        self
    }

    /// Restart mode: expect every party of checkpoint epoch `epoch` to
    /// `Rejoin`, and ack each with `resume_round`. Fresh `Join`s are
    /// refused (the dialer falls back to `Rejoin` automatically — see
    /// [`SessionDialer::establish_resumable`]).
    pub fn with_resume(mut self, epoch: u32, resume_round: u64) -> Self {
        self.resume = Some((epoch, resume_round));
        self
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Session-level vetting of one decoded bootstrap frame: size
    /// agreement, duplicates, fresh-vs-resumed mode, epoch. Admits with
    /// the ack to send, or — for resume-mode refusals only — refuses
    /// with a [`Message::RejoinReject`] naming the reason (see
    /// [`Vetted`]). Frame-level rules (version, id ranges) were already
    /// enforced by `Message::decode` on the admit worker.
    fn vet(msg: Message, parties: u16, resume: Option<(u32, u64)>,
           joined: &BTreeMap<u16, (TcpStream, u32)>)
           -> anyhow::Result<Vetted> {
        let (party, claimed, codecs, ack) = match (msg, resume) {
            (Message::Join { party, parties: claimed, codecs }, None) => {
                let ack = Message::JoinAck {
                    party,
                    parties,
                    codecs: compress::supported_mask(),
                };
                (party, claimed, codecs, ack)
            }
            (Message::Join { party, .. }, Some((_, resume_round))) => {
                return Ok(Vetted::Refuse {
                    reject: Message::RejoinReject {
                        party,
                        reason: RejectReason::NeedRejoin,
                        round: resume_round,
                    },
                    why: format!(
                        "{party} sent a fresh Join but this session is \
                         resuming from a checkpoint (round \
                         {resume_round}) — the dialer must Rejoin (the \
                         `celu-vfl party` dialer falls back \
                         automatically)"
                    ),
                });
            }
            (Message::Rejoin { party, parties: claimed, epoch,
                               last_round, codecs },
             Some((want_epoch, resume_round))) => {
                if epoch != want_epoch {
                    return Ok(Vetted::Refuse {
                        reject: Message::RejoinReject {
                            party,
                            reason: RejectReason::EpochMismatch,
                            round: resume_round,
                        },
                        why: format!(
                            "{party} rejoined with session epoch \
                             {epoch:#x}, this checkpoint is epoch \
                             {want_epoch:#x} — different logical \
                             session (seed/config mismatch?)"
                        ),
                    });
                }
                if last_round > resume_round {
                    // A survivor of a label crash that happened after
                    // the snapshot: it ran ahead of the checkpoint and
                    // must rewind. The ack's resume round tells it
                    // where to (the dialer rebuilds its deterministic
                    // batch cursor); its model state keeps the extra
                    // rounds' updates, which the staleness-tolerant
                    // algorithm absorbs.
                    log::info!(
                        "{party} survived ahead of the checkpoint \
                         ({last_round} completed rounds > resume \
                         {resume_round}) — rewinding it"
                    );
                }
                let ack = Message::RejoinAck {
                    party,
                    parties,
                    epoch,
                    resume_round,
                    replays: 0,
                };
                (party, claimed, codecs, ack)
            }
            (Message::Rejoin { party, .. }, None) => anyhow::bail!(
                "{party} sent Rejoin but this listener hosts a fresh \
                 session (no checkpoint) — expected Join"
            ),
            (other, _) => anyhow::bail!(
                "expected Join, got message tag {}", other.tag()),
        };
        anyhow::ensure!(
            claimed == parties,
            "{party} joined for a {claimed}-party session, this \
             listener hosts {parties} parties — config mismatch"
        );
        anyhow::ensure!(
            !joined.contains_key(&party.0),
            "duplicate join: {party} is already in the session"
        );
        Ok(Vetted::Admit { party, codecs, ack })
    }

    /// Accept until ids 1..`cfg.parties` have all joined. Frame reads
    /// run on a bounded admit pool ([`ADMIT_WORKERS`]): the accept
    /// thread keeps accepting while up to that many joiners are vetted
    /// concurrently, so one slow (or mute) peer no longer amplifies
    /// into a serial stall for the whole cold start. A rejected joiner
    /// is dropped — its dialer observes EOF instead of an ack, except
    /// resume-mode refusals, which first send a [`Message::RejoinReject`]
    /// naming the reason — and the loop keeps serving; the deadline
    /// failure names exactly the ids still missing.
    fn establish_streams(&self, cfg: &RunConfig)
                         -> anyhow::Result<BTreeMap<u16, (TcpStream, u32)>>
    {
        cfg.validate()?;
        let parties = cfg.parties as u16;
        let expected = parties - 1;
        let deadline = Instant::now() + self.timeout;
        self.listener.set_nonblocking(true)?;
        let mut joined: BTreeMap<u16, (TcpStream, u32)> = BTreeMap::new();
        let active = Arc::new(AtomicUsize::new(0));
        type AdmitResult = (SocketAddr,
                            anyhow::Result<(Message, TcpStream)>);
        let (result_tx, result_rx) = channel::<AdmitResult>();
        let mut backlog: VecDeque<(TcpStream, SocketAddr)> =
            VecDeque::new();
        while (joined.len() as u16) < expected {
            // Deadline check at the top of the loop, not only on idle:
            // a steady stream of junk connections keeps accept()
            // succeeding and must not defer the timeout forever.
            if Instant::now() >= deadline {
                let missing: Vec<String> = (1..parties)
                    .filter(|id| !joined.contains_key(id))
                    .map(|id| format!("P{id}"))
                    .collect();
                anyhow::bail!(
                    "session bootstrap timed out after {:?}: {} of {} \
                     feature parties never joined ({})",
                    self.timeout,
                    missing.len(),
                    expected,
                    missing.join(", ")
                );
            }
            let mut progressed = false;
            // 1. Accept everything currently pending.
            loop {
                match self.listener.accept() {
                    Ok(pair) => {
                        backlog.push_back(pair);
                        progressed = true;
                    }
                    Err(e) if e.kind()
                        == std::io::ErrorKind::WouldBlock => break,
                    Err(e) => {
                        return Err(anyhow::anyhow!(
                            "session listener accept: {e}"
                        ))
                    }
                }
            }
            // 2. Dispatch to the admit pool while slots are free.
            while active.load(Ordering::SeqCst) < ADMIT_WORKERS {
                let Some((stream, addr)) = backlog.pop_front() else {
                    break;
                };
                active.fetch_add(1, Ordering::SeqCst);
                let tx = result_tx.clone();
                let active = active.clone();
                let metrics = self.metrics.clone();
                let token = self.token.clone();
                std::thread::spawn(move || {
                    let res = read_first_contact(stream, deadline);
                    active.fetch_sub(1, Ordering::SeqCst);
                    let res = match res {
                        Ok(FirstContact::Frame(msg, stream)) => {
                            Ok((msg, stream))
                        }
                        Ok(FirstContact::Http { req, stream }) => {
                            match metrics {
                                // Served entirely on this worker;
                                // nothing reaches the joined map. No
                                // /watch during bootstrap: the mesh is
                                // still assembling (503).
                                Some(reg) => {
                                    serve_observability(
                                        &req, stream, &reg, None,
                                        token.as_deref());
                                    return;
                                }
                                None => Err(anyhow::anyhow!(
                                    "HTTP-shaped request ({}) on a \
                                     session port with no metrics \
                                     registry attached", req.path
                                )),
                            }
                        }
                        Err(e) => Err(e),
                    };
                    let _ = tx.send((addr, res));
                });
                progressed = true;
            }
            // 3. Vet + ack completed reads (session-level rules live
            //    here, with the joined map).
            while let Ok((addr, res)) = result_rx.try_recv() {
                progressed = true;
                let admitted = res.and_then(|(msg, mut stream)| {
                    match Self::vet(msg, parties, self.resume,
                                    &joined)? {
                        Vetted::Admit { party, codecs, ack } => {
                            send_bootstrap_frame(&mut stream, &ack)?;
                            Ok((party, codecs, stream))
                        }
                        Vetted::Refuse { reject, why } => {
                            // Best-effort: name the reason on the wire
                            // before the drop, so the dialer logs it
                            // instead of a bare EOF.
                            let _ = send_bootstrap_frame(&mut stream,
                                                         &reject);
                            Err(anyhow::anyhow!(why))
                        }
                    }
                });
                match admitted {
                    Ok((party, codecs, stream)) => {
                        log::info!(
                            "session listener: {party} joined ({}/{} \
                             feature parties, codec mask {codecs:#x})",
                            joined.len() + 1,
                            expected
                        );
                        joined.insert(party.0, (stream, codecs));
                    }
                    Err(e) => log::warn!(
                        "session listener: rejected {addr}: {e:#}"
                    ),
                }
            }
            if !progressed {
                std::thread::sleep(ACCEPT_POLL);
            }
        }
        Ok(joined)
    }

    /// Wrap admitted sockets into mesh links (identity-framed when the
    /// session spans more than two parties), carrying each peer's
    /// join-time codec mask so the coordinators can skip the
    /// first-round `Hello` exchange.
    pub(crate) fn wrap_links(cfg: &RunConfig,
                             joined: BTreeMap<u16, (TcpStream, u32)>)
                             -> anyhow::Result<Vec<Link>> {
        let v2 = cfg.parties > 2;
        joined
            .into_iter()
            .map(|(id, (stream, codecs))| {
                stream.set_read_timeout(None)?;
                let peer = PartyId(id);
                let mut t = TcpTransport::from_stream(stream, cfg.wan)?;
                if v2 {
                    t = t.with_identity(LABEL_PARTY, peer);
                }
                Ok(Link::new(peer, Arc::new(t) as Arc<dyn Transport>)
                    .with_peer_codecs(codecs))
            })
            .collect()
    }

    /// Establish the mesh and keep the listener alive as the session's
    /// re-admission point: a feature party that drops mid-session can
    /// re-dial and present `Rejoin` for the returned [`Readmission`]
    /// to queue (DESIGN.md §8). Also returns the session epoch and the
    /// round the session starts at (0 fresh; the checkpoint's round in
    /// resume mode).
    pub fn establish_supervised(self, cfg: &RunConfig)
                                -> anyhow::Result<(Vec<Link>, Readmission,
                                                   u32, u64)> {
        let (epoch, start_round) = match self.resume {
            Some((e, r)) => (e, r),
            None => (session_epoch(cfg.seed), 0),
        };
        let joined = self.establish_streams(cfg)?;
        let links = Self::wrap_links(cfg, joined)?;
        let readmission = Readmission::spawn_with_token(
            self.listener, cfg.parties as u16, epoch,
            self.metrics.clone(), self.token.clone())?;
        Ok((links, readmission, epoch, start_round))
    }
}

/// A connection's opening bytes, classified. The session port carries
/// two protocols, told apart by the first four bytes: bootstrap frames
/// open with a little-endian length word whose value is at most
/// [`MAX_BOOTSTRAP_FRAME`] (so bytes 1–3 are always zero), while an
/// HTTP observability request opens with the ASCII `GET ` — which read
/// as a length word is ~540 MB, unambiguous by arithmetic alone.
pub(crate) enum FirstContact {
    /// A decoded bootstrap frame: the historic Join/Rejoin path.
    Frame(Message, TcpStream),
    /// An HTTP-shaped request (`GET <path> …`), header block consumed.
    Http { req: HttpRequest, stream: TcpStream },
}

/// The parts of an observability request the session port acts on: the
/// request path and, for the shared-token gate, whatever the client
/// sent in its `Authorization` header (verbatim, scheme included).
pub(crate) struct HttpRequest {
    pub(crate) path: String,
    pub(crate) auth: Option<String>,
}

/// Read one connection's opening bootstrap frame — or HTTP request —
/// on an admit worker.
pub(crate) fn read_first_contact(mut stream: TcpStream, deadline: Instant)
                                 -> anyhow::Result<FirstContact> {
    // Accepted sockets must not inherit the listener's non-blocking
    // mode. The whole read is bounded by JOIN_READ_TIMEOUT (not the
    // remaining join window): a peer that never speaks — or trickles
    // bytes — ties up one pool slot for at most this long.
    stream.set_nonblocking(false)?;
    let frame_deadline = (Instant::now() + JOIN_READ_TIMEOUT).min(deadline);
    let mut head = [0u8; 4];
    read_exact_deadline(&mut stream, &mut head, frame_deadline)
        .map_err(|e| anyhow::anyhow!("reading bootstrap frame: {e:#}"))?;
    if &head == b"GET " {
        let req = read_http_request(&mut stream, frame_deadline)?;
        return Ok(FirstContact::Http { req, stream });
    }
    let len = u32::from_le_bytes(head) as usize;
    let msg = recv_bootstrap_body(&mut stream, len, frame_deadline)?;
    Ok(FirstContact::Frame(msg, stream))
}

/// Consume an HTTP request whose `GET ` prefix was already read off the
/// socket: capture the path from the request line and the
/// `Authorization` header (if any) from the header block — bounded by
/// [`MAX_HTTP_REQUEST`] and the frame deadline, so an HTTP-shaped
/// byte-trickler is no more able to wedge a worker slot than a mute
/// bootstrap probe is.
fn read_http_request(stream: &mut TcpStream, deadline: Instant)
                     -> anyhow::Result<HttpRequest> {
    let mut buf: Vec<u8> = Vec::with_capacity(128);
    let mut byte = [0u8; 1];
    while !buf.ends_with(b"\r\n\r\n") {
        anyhow::ensure!(
            buf.len() < MAX_HTTP_REQUEST,
            "HTTP request on the session port exceeds \
             {MAX_HTTP_REQUEST} bytes — not a scraper"
        );
        read_exact_deadline(stream, &mut byte, deadline)
            .map_err(|e| anyhow::anyhow!("reading HTTP request: {e:#}"))?;
        buf.push(byte[0]);
    }
    parse_http_request(&buf)
}

/// Parse a consumed header block (everything after the `GET ` prefix,
/// terminator included) into the parts the session port acts on. Shared
/// by the blocking admit-worker reader above and the server reactor's
/// incremental one.
pub(crate) fn parse_http_request(buf: &[u8]) -> anyhow::Result<HttpRequest> {
    // Request line after the consumed `GET ` prefix: `<path> HTTP/1.x`.
    let mut lines = buf.split(|&b| b == b'\r');
    let line = lines.next().unwrap_or(&[]);
    let line = String::from_utf8_lossy(line);
    let path = line.split_whitespace().next().unwrap_or("").to_string();
    anyhow::ensure!(!path.is_empty(), "empty HTTP request path");
    // Header names are case-insensitive (RFC 9110 §5.1); values keep
    // their scheme and spelling verbatim for the gate to compare.
    let auth = lines
        .map(|l| String::from_utf8_lossy(l.strip_prefix(b"\n").unwrap_or(l))
            .into_owned())
        .find_map(|l| {
            let (name, value) = l.split_once(':')?;
            name.trim().eq_ignore_ascii_case("authorization")
                .then(|| value.trim().to_string())
        });
    Ok(HttpRequest { path, auth })
}

/// One-shot HTTP response on the session port. Best-effort: a scraper
/// that hung up mid-response costs nothing but this socket. The
/// connection closes when `stream` drops (HTTP/1.0 semantics, and the
/// response says `Connection: close` explicitly).
pub(crate) fn send_http_response(stream: &mut TcpStream, status: &str,
                                 content_type: &str, body: &str) {
    let challenge = if status.starts_with("401") {
        "WWW-Authenticate: Bearer\r\n"
    } else {
        ""
    };
    let head = format!(
        "HTTP/1.0 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n{challenge}\r\n",
        body.len()
    );
    let _ = stream
        .write_all(head.as_bytes())
        .and_then(|()| stream.write_all(body.as_bytes()))
        .and_then(|()| stream.flush());
}

/// Serve one observability request (already classified and parsed).
/// `watch` carries what a `/watch` stream needs beyond the registry —
/// the session's stop flag; `None` means this endpoint cannot stream
/// yet (the bootstrap accept loop: the mesh is still assembling, and
/// there is no lifecycle flag to end a stream against). When `token`
/// is set, every observability path demands `Authorization: Bearer
/// <token>` and answers 401 otherwise — the shared-token gate guards
/// the read-only endpoints only; Join/Rejoin never pass through here.
pub(crate) fn serve_observability(req: &HttpRequest, mut stream: TcpStream,
                                  registry: &Arc<Registry>,
                                  watch: Option<&Arc<AtomicBool>>,
                                  token: Option<&str>) {
    if let Some(token) = token {
        let expect = format!("Bearer {token}");
        if req.auth.as_deref() != Some(expect.as_str()) {
            send_http_response(
                &mut stream, "401 Unauthorized", "text/plain",
                "observability endpoints require \
                 `Authorization: Bearer <token>`\n");
            return;
        }
    }
    match req.path.as_str() {
        "/metrics" => {
            let body = prometheus::render(registry);
            send_http_response(&mut stream, "200 OK",
                               "text/plain; version=0.0.4", &body);
        }
        "/watch" => match watch {
            Some(stop) => {
                let registry = registry.clone();
                let stop = stop.clone();
                // Detached on purpose: the stream lives as long as
                // the watcher (or the session), not the short-lived
                // vetting thread that classified the request.
                let _ = std::thread::Builder::new()
                    .name("session-watch-stream".into())
                    .spawn(move || {
                        watch_stream_loop(stream, registry, stop)
                    });
            }
            None => send_http_response(
                &mut stream, "503 Service Unavailable", "text/plain",
                "session still assembling — /watch is served once \
                 training starts\n"),
        },
        other => send_http_response(
            &mut stream, "404 Not Found", "text/plain",
            &format!("unknown path {other} — try /metrics or /watch\n")),
    }
}

/// The `/watch` push stream: one cumulative tag-14 metric frame per
/// [`WATCH_TICK`] until the watcher hangs up or the session stops —
/// with the stop flag latched *before* each export, so the frame sent
/// after observing stop is a final snapshot carrying exactly the
/// totals `RunRecord` reports.
pub(crate) fn watch_stream_loop(stream: TcpStream, registry: Arc<Registry>,
                                stop: Arc<AtomicBool>) {
    let push = PushExporter::new(stream);
    loop {
        let last = stop.load(Ordering::SeqCst);
        if push.export(&registry).is_err() {
            return; // watcher hung up
        }
        if last {
            return; // that frame was the final, post-stop snapshot
        }
        std::thread::sleep(WATCH_TICK);
    }
}

impl MeshBootstrap for SessionListener {
    fn id(&self) -> PartyId {
        LABEL_PARTY
    }

    /// Bootstrap-only establish: assemble the mesh and drop the
    /// listener (no re-admission point). [`Self::establish_supervised`]
    /// is the lifecycle-aware variant.
    fn establish(self, cfg: &RunConfig) -> anyhow::Result<Vec<Link>> {
        let joined = self.establish_streams(cfg)?;
        Self::wrap_links(cfg, joined)
    }
}

// ---- re-admission ----------------------------------------------------------

/// A validated `Rejoin` dial waiting for the label loop to swap it in.
pub struct RejoinRequest {
    pub party: PartyId,
    /// Communication rounds the dialer completed before the drop.
    pub last_round: u64,
    /// The dialer's decodable codec mask (advisory; the lane keeps its
    /// originally-negotiated codec).
    pub codecs: u32,
    /// The raw socket, positioned right after the `Rejoin` frame. The
    /// `RejoinAck` and the transport wrap happen at the consumer, where
    /// lane state lives.
    pub stream: TcpStream,
}

/// The session's re-admission point: the bootstrap listener kept alive
/// after `establish`, accepting `Rejoin` dials on a background thread.
/// Frame/epoch validation happens on that thread; session-level checks
/// (known lane, sane round claim) happen wherever requests are consumed
/// ([`try_take`](Self::try_take) — the supervised label loop polls it
/// between rounds and inside straggler waits). Dropped on shutdown,
/// which stops the thread.
pub struct Readmission {
    rx: Mutex<Receiver<RejoinRequest>>,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Readmission {
    /// Keep `listener` serving `Rejoin`s for a `parties`-party session
    /// of logical epoch `epoch`. With a `metrics` registry attached
    /// the same port serves the live observability plane: `/metrics`
    /// one-shot scrapes, and `/watch` push streams that end (with one
    /// final-totals frame) when this `Readmission` is dropped.
    pub fn spawn(listener: TcpListener, parties: u16, epoch: u32,
                 metrics: Option<Arc<Registry>>)
                 -> anyhow::Result<Readmission> {
        Self::spawn_with_token(listener, parties, epoch, metrics, None)
    }

    /// [`Self::spawn`] with the observability shared-token gate: when
    /// `token` is set, `/metrics` and `/watch` on the re-admission port
    /// answer 401 without `Authorization: Bearer <token>`. Rejoin
    /// frames are never gated.
    pub fn spawn_with_token(listener: TcpListener, parties: u16,
                            epoch: u32, metrics: Option<Arc<Registry>>,
                            token: Option<String>)
                            -> anyhow::Result<Readmission> {
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_t = stop.clone();
        let (tx, rx) = channel::<RejoinRequest>();
        let handle = std::thread::Builder::new()
            .name("session-readmission".into())
            .spawn(move || readmission_loop(listener, parties, epoch,
                                            metrics, token, stop_t, tx))?;
        Ok(Readmission {
            rx: Mutex::new(rx),
            stop,
            handle: Some(handle),
        })
    }

    /// A re-admission point fed by an external router instead of an
    /// owned listener thread: the returned `Sender` queues
    /// [`RejoinRequest`]s exactly as the spawned loop would (the
    /// multi-session server vets and epoch-routes rejoin dials
    /// centrally, then forwards them here). The stop flag still ends
    /// `/watch` streams a server hands to [`watch_stream_loop`].
    pub fn external() -> (Sender<RejoinRequest>, Readmission) {
        let (tx, rx) = channel::<RejoinRequest>();
        let readmission = Readmission {
            rx: Mutex::new(rx),
            stop: Arc::new(AtomicBool::new(false)),
            handle: None,
        };
        (tx, readmission)
    }

    /// The session's stop flag (latched on drop): `/watch` streamers
    /// follow it to know when to send their final-totals frame.
    pub(crate) fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Next pending rejoin, if any (non-blocking).
    pub fn try_take(&self) -> Option<RejoinRequest> {
        self.rx.lock().unwrap().try_recv().ok()
    }
}

impl Drop for Readmission {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Bound on concurrently-vetted re-admission dials: the same
/// serial-stall argument as [`ADMIT_WORKERS`], applied to the whole
/// session lifetime — a mute probe must tie up one short-lived vetting
/// thread for [`JOIN_READ_TIMEOUT`], never the accept loop a genuine
/// rejoiner is queued behind. At the cap further connections are
/// dropped (EOF) rather than queued: rejoiners retry via their
/// backoff, probes don't get to build a backlog.
const READMIT_WORKERS: usize = 4;

fn readmission_loop(listener: TcpListener, parties: u16, epoch: u32,
                    metrics: Option<Arc<Registry>>, token: Option<String>,
                    stop: Arc<AtomicBool>, tx: Sender<RejoinRequest>) {
    let active = Arc::new(AtomicUsize::new(0));
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, addr)) => {
                if active.load(Ordering::SeqCst) >= READMIT_WORKERS {
                    log::warn!(
                        "re-admission: dropping {addr} — all \
                         {READMIT_WORKERS} vetting slots busy"
                    );
                    continue; // drop → dialer sees EOF and retries
                }
                active.fetch_add(1, Ordering::SeqCst);
                let active = active.clone();
                let tx = tx.clone();
                let metrics = metrics.clone();
                let token = token.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let vetted = vet_readmission_contact(
                        stream, parties, epoch, &metrics,
                        token.as_deref(), &stop);
                    active.fetch_sub(1, Ordering::SeqCst);
                    match vetted {
                        Ok(Some(req)) => {
                            log::info!(
                                "re-admission: {} queued (last round \
                                 {})", req.party, req.last_round
                            );
                            let _ = tx.send(req);
                        }
                        Ok(None) => {} // observability request, served
                        Err(e) => log::warn!(
                            "re-admission: rejected {addr}: {e:#}"
                        ),
                    }
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => {
                log::warn!("re-admission accept: {e}");
                std::thread::sleep(ACCEPT_POLL);
            }
        }
    }
}

/// Frame + session-identity vetting of one re-admission dial (runs on
/// a short-lived vetting thread; lane-level checks happen at the
/// consumer). `Ok(None)` means the connection was an observability
/// request and was served in full — `/metrics` right here, `/watch` by
/// handing the socket to a detached streamer that follows `stop`.
fn vet_readmission_contact(stream: TcpStream, parties: u16, epoch: u32,
                           metrics: &Option<Arc<Registry>>,
                           token: Option<&str>,
                           stop: &Arc<AtomicBool>)
                           -> anyhow::Result<Option<RejoinRequest>> {
    let contact =
        read_first_contact(stream, Instant::now() + JOIN_READ_TIMEOUT)?;
    let (msg, mut stream) = match contact {
        FirstContact::Frame(msg, stream) => (msg, stream),
        FirstContact::Http { req, stream } => match metrics {
            Some(reg) => {
                serve_observability(&req, stream, reg, Some(stop), token);
                return Ok(None);
            }
            None => anyhow::bail!(
                "HTTP-shaped request ({}) on a re-admission port \
                 with no metrics registry attached", req.path
            ),
        },
    };
    let Message::Rejoin { party, parties: claimed, epoch: e, last_round,
                          codecs } = msg
    else {
        anyhow::bail!(
            "expected Rejoin on the re-admission socket, got message \
             tag {}", msg.tag()
        );
    };
    anyhow::ensure!(
        claimed == parties,
        "{party} rejoined for a {claimed}-party session, this session \
         has {parties} parties"
    );
    if e != epoch {
        // A well-formed peer from the wrong logical session: name the
        // reason on the wire (best-effort) before the drop, so its
        // dialer logs the mismatch instead of retrying blindly. Round
        // is 0 — a live session has no snapshot round to cite.
        let _ = send_bootstrap_frame(&mut stream, &Message::RejoinReject {
            party,
            reason: RejectReason::EpochMismatch,
            round: 0,
        });
        anyhow::bail!(
            "{party} rejoined with epoch {e:#x}, this session is epoch \
             {epoch:#x} — different logical session"
        );
    }
    Ok(Some(RejoinRequest { party, last_round, codecs, stream }))
}

/// Re-dial a running (or restarted) session and resume a lane: connect
/// with the party's deterministically-jittered backoff (a mass
/// reconnect after a label blip must not thundering-herd the
/// listener), present `Rejoin`, verify the `RejoinAck` echo, wrap the
/// socket. Returns the fresh transport, the round the lane resumes at,
/// and how many buffered derivative frames the label will replay first.
pub fn rejoin_dial(addr: &str, party: PartyId, cfg: &RunConfig,
                   epoch: u32, last_round: u64, timeout: Duration)
                   -> anyhow::Result<(Arc<dyn Transport>, u64, u32)> {
    let parties = cfg.parties as u16;
    anyhow::ensure!(
        party.0 >= 1 && party.0 < parties,
        "feature party id {party} out of range for a {parties}-party \
         session"
    );
    let deadline = Instant::now() + timeout;
    let mut stream =
        connect_with_backoff_jittered(addr, deadline,
                                      Some(party.0 as u64))
            .map_err(|e| anyhow::anyhow!(
                "{party}: label party at {addr} never came back: {e:#}"
            ))?;
    send_bootstrap_frame(&mut stream, &Message::Rejoin {
        party,
        parties,
        epoch,
        last_round,
        codecs: compress::supported_mask(),
    })?;
    let ack = recv_bootstrap_frame(&mut stream, deadline).map_err(|e| {
        anyhow::anyhow!(
            "{party}: no RejoinAck from the label party at {addr} — \
             the rejoin was refused (wrong epoch? unknown lane?) or \
             the label died again: {e:#}"
        )
    })?;
    let (p, acked, e, resume_round, replays) = match ack {
        Message::RejoinAck { party, parties, epoch, resume_round,
                             replays } => {
            (party, parties, epoch, resume_round, replays)
        }
        Message::RejoinReject { reason, round, .. } => match reason {
            RejectReason::EpochMismatch => anyhow::bail!(
                "{party}: rejoin refused by the label at {addr}: epoch \
                 mismatch (snapshot is round {round}) — this process's \
                 seed/config derives a different session epoch"
            ),
            RejectReason::NeedRejoin => anyhow::bail!(
                "{party}: label at {addr} refused the dial asking for \
                 a Rejoin, but this *was* one (snapshot is round \
                 {round}) — check that both sides run the same build"
            ),
        },
        other => anyhow::bail!(
            "{party}: expected RejoinAck, got message tag {}",
            other.tag()
        ),
    };
    anyhow::ensure!(p == party,
                    "label party acked {p}, but this process rejoined \
                     as {party}");
    anyhow::ensure!(acked == parties,
                    "session size mismatch on rejoin: label hosts \
                     {acked}, this config says {parties}");
    anyhow::ensure!(e == epoch,
                    "label acked epoch {e:#x}, expected {epoch:#x}");
    stream.set_read_timeout(None)?;
    let mut t = TcpTransport::from_stream(stream, cfg.wan)?;
    if parties > 2 {
        t = t.with_identity(party, LABEL_PARTY);
    }
    log::info!(
        "{party} rejoined the session at {addr}: resume round \
         {resume_round}, {replays} replays"
    );
    Ok((Arc::new(t) as Arc<dyn Transport>, resume_round, replays))
}

// ---- TCP: feature side -----------------------------------------------------

/// Feature-party dialer: connect (with backoff, so launch order
/// between shells doesn't matter), claim an id via `Join`, verify the
/// `JoinAck`.
pub struct SessionDialer {
    addr: String,
    party: PartyId,
    timeout: Duration,
}

impl SessionDialer {
    pub fn new(addr: &str, party: PartyId) -> Self {
        SessionDialer {
            addr: addr.to_string(),
            party,
            timeout: DEFAULT_JOIN_TIMEOUT,
        }
    }

    /// Replace the default connect/join deadline.
    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }
}

impl SessionDialer {
    /// One `Join` attempt against a fresh session, bounded by
    /// `deadline`. On success the link carries the label party's
    /// join-time codec mask, so the coordinator can pre-negotiate and
    /// skip the first-round `Hello` exchange.
    fn try_join(&self, cfg: &RunConfig, deadline: Instant)
                -> anyhow::Result<Link> {
        let parties = cfg.parties as u16;
        // Deterministic per-party jitter on the connect backoff: after
        // a label-party blip every dialer retries at once, and without
        // jitter their schedules are phase-locked into a thundering
        // herd (see `transport::tcp::backoff_jitter`).
        let mut stream = connect_with_backoff_jittered(
            &self.addr, deadline, Some(self.party.0 as u64))
            .map_err(|e| anyhow::anyhow!(
                "{}: label party at {} never came up: {e:#}",
                self.party, self.addr
            ))?;
        send_bootstrap_frame(&mut stream, &Message::Join {
            party: self.party,
            parties,
            codecs: compress::supported_mask(),
        })?;
        // The ack may legitimately take a while (the admit pool is
        // bounded), so it gets the whole remaining window — but
        // bounded end to end, not per read.
        let ack = recv_bootstrap_frame(&mut stream, deadline).map_err(|e| {
            anyhow::anyhow!(
                "{}: no JoinAck from the label party at {} — the join \
                 was rejected (duplicate id? config mismatch? resumed \
                 session expecting Rejoin?) or the listener died: {e:#}",
                self.party, self.addr
            )
        })?;
        let (party, acked, codecs) = match ack {
            Message::JoinAck { party, parties, codecs } => {
                (party, parties, codecs)
            }
            Message::RejoinReject { reason, round, .. } => {
                let why = match reason {
                    RejectReason::NeedRejoin => {
                        "it resumed from a checkpoint and only \
                         re-admits Rejoin"
                    }
                    RejectReason::EpochMismatch => "session epoch \
                                                    mismatch",
                };
                anyhow::bail!(
                    "{}: label party at {} refused the Join ({why}; \
                     snapshot is round {round})",
                    self.party, self.addr
                );
            }
            other => anyhow::bail!(
                "{}: expected JoinAck, got message tag {}",
                self.party, other.tag()
            ),
        };
        anyhow::ensure!(
            party == self.party,
            "label party acked {party}, but this process joined as {}",
            self.party
        );
        anyhow::ensure!(
            acked == parties,
            "session size mismatch: label party hosts {acked} parties, \
             this config says {parties}"
        );
        log::info!(
            "{} joined the {parties}-party session at {} (label codec \
             mask {codecs:#x})",
            self.party, self.addr
        );
        stream.set_read_timeout(None)?;
        let mut t = TcpTransport::from_stream(stream, cfg.wan)?;
        if parties > 2 {
            t = t.with_identity(self.party, LABEL_PARTY);
        }
        Ok(Link::new(LABEL_PARTY, Arc::new(t) as Arc<dyn Transport>)
            .with_peer_codecs(codecs))
    }

    /// Join a session that may be fresh *or* restarting from a
    /// checkpoint: try `Join` first, and when the listener refuses it
    /// (a resumed session drops fresh joins pre-ack), retry as a
    /// zero-round `Rejoin`. Returns the link plus the round this party
    /// starts at (0 fresh; the checkpoint's resume round otherwise —
    /// the caller fast-forwards its batch cursor there).
    pub fn establish_resumable(self, cfg: &RunConfig)
                               -> anyhow::Result<(Link, u64)> {
        self.establish_resumable_from(cfg, 0)
    }

    /// [`establish_resumable`](Self::establish_resumable) for a process
    /// restarting from a *feature snapshot* of `last_round` completed
    /// rounds: the fallback `Rejoin` claims that round (so a live
    /// label replays the in-flight derivative instead of treating this
    /// as a relaunched-from-scratch process), and the restored-state
    /// path logs as a recovery, not a fresh-state warning.
    pub fn establish_resumable_from(self, cfg: &RunConfig,
                                    last_round: u64)
                                    -> anyhow::Result<(Link, u64)> {
        cfg.validate()?;
        self.check_range(cfg)?;
        let deadline = Instant::now() + self.timeout;
        let join_err = match self.try_join(cfg, deadline) {
            Ok(link) => return Ok((link, 0)),
            Err(e) => e,
        };
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(join_err);
        }
        log::warn!(
            "{}: Join refused ({join_err:#}); retrying as Rejoin in \
             case the label party resumed from a checkpoint",
            self.party
        );
        let epoch = session_epoch(cfg.seed);
        let (transport, resume_round, replays) =
            rejoin_dial(&self.addr, self.party, cfg, epoch, last_round,
                        remaining)
                .map_err(|rejoin_err| anyhow::anyhow!(
                    "{}: both bootstrap paths failed — Join: \
                     {join_err:#}; Rejoin: {rejoin_err:#}", self.party
                ))?;
        // A *live* (non-checkpoint-resumed) session may admit this
        // Rejoin through its re-admission point and replay the
        // derivative of the claimed round if it is still buffered.
        // Either way the replay is discarded: a fresh process has no
        // in-flight round to apply it to, and a snapshot-restarted one
        // fast-forwards past it (the ack's resume round is where the
        // session is now, not where this party died).
        for _ in 0..replays {
            let m = transport.recv().map_err(|e| anyhow::anyhow!(
                "{}: reading replayed frame after rejoin: {e:#}",
                self.party
            ))?;
            if last_round > 0 {
                log::info!(
                    "{}: discarding replayed frame (tag {}) — the \
                     session moved past the snapshot's in-flight round \
                     while this party was down", self.party, m.tag()
                );
            } else {
                log::warn!(
                    "{}: discarding replayed frame (tag {}) — this \
                     process has no in-flight round",
                    self.party, m.tag()
                );
            }
        }
        if resume_round > 0 {
            if last_round > 0 {
                log::info!(
                    "{}: re-entering the session at round \
                     {resume_round} with model state restored from a \
                     snapshot of {last_round} completed rounds",
                    self.party
                );
            } else {
                log::warn!(
                    "{}: re-entering the session at round \
                     {resume_round} with freshly initialized local \
                     state — run with --checkpoint-dir and restart \
                     with --resume to carry the bottom model and \
                     AdaGrad state across a crash",
                    self.party
                );
            }
        }
        // A rejoin ack carries no codec mask; the epoch check already
        // proved the session shares this config's seed, and sessions
        // are deployed from one build, so the peer's decodable families
        // are taken to be this build's own.
        Ok((Link::new(LABEL_PARTY, transport)
                .with_peer_codecs(compress::supported_mask()),
            resume_round))
    }

    fn check_range(&self, cfg: &RunConfig) -> anyhow::Result<()> {
        let parties = cfg.parties as u16;
        anyhow::ensure!(
            self.party.0 >= 1 && self.party.0 < parties,
            "feature party id {} out of range for a {parties}-party \
             session (valid: 1..={})",
            self.party,
            parties - 1
        );
        Ok(())
    }
}

impl MeshBootstrap for SessionDialer {
    fn id(&self) -> PartyId {
        self.party
    }

    fn establish(self, cfg: &RunConfig) -> anyhow::Result<Vec<Link>> {
        cfg.validate()?;
        self.check_range(cfg)?;
        let deadline = Instant::now() + self.timeout;
        Ok(vec![self.try_join(cfg, deadline)?])
    }
}

// ---- raw-socket frame I/O --------------------------------------------------

/// Write one headerless (v1) frame to a raw bootstrap socket.
pub(crate) fn send_bootstrap_frame(stream: &mut TcpStream, msg: &Message)
                                   -> anyhow::Result<()> {
    let mut buf = Vec::with_capacity(msg.wire_bytes());
    encode_frame_into(None, msg, &mut buf);
    stream.write_all(&buf)?;
    stream.flush()?;
    Ok(())
}

/// `read_exact` with an overall deadline: the socket read timeout is
/// shrunk to the remainder before every read syscall, so a
/// byte-trickling peer cannot stretch one frame past `deadline` the
/// way a plain per-read timeout would allow (each drip resets a
/// per-read clock; it cannot reset this one).
fn read_exact_deadline(stream: &mut TcpStream, buf: &mut [u8],
                       deadline: Instant) -> anyhow::Result<()> {
    use std::io::ErrorKind;
    let mut filled = 0;
    while filled < buf.len() {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            anyhow::bail!("timed out mid-frame ({filled}/{} bytes)",
                          buf.len());
        }
        stream.set_read_timeout(Some(remaining))?;
        match stream.read(&mut buf[filled..]) {
            Ok(0) => anyhow::bail!("connection closed mid-frame"),
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock
                || e.kind() == ErrorKind::TimedOut => {
                anyhow::bail!("timed out mid-frame ({filled}/{} bytes)",
                              buf.len())
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Read one headerless frame from a raw bootstrap socket, bounded by
/// `deadline` end to end. The length word is capped at
/// [`MAX_BOOTSTRAP_FRAME`] *before* the body buffer is allocated: a
/// peer that opens with a multi-MiB length (or any non-bootstrap
/// traffic) is refused by arithmetic alone.
pub(crate) fn recv_bootstrap_frame(stream: &mut TcpStream,
                                   deadline: Instant)
                                   -> anyhow::Result<Message> {
    let mut len_buf = [0u8; 4];
    read_exact_deadline(stream, &mut len_buf, deadline)
        .map_err(|e| anyhow::anyhow!("reading bootstrap frame: {e:#}"))?;
    recv_bootstrap_body(stream, u32::from_le_bytes(len_buf) as usize,
                        deadline)
}

/// The body half of [`recv_bootstrap_frame`], for callers that already
/// consumed the length word (the first-contact dispatch reads it to
/// tell frames from HTTP).
fn recv_bootstrap_body(stream: &mut TcpStream, len: usize,
                       deadline: Instant) -> anyhow::Result<Message> {
    anyhow::ensure!(
        len > 0 && len <= MAX_BOOTSTRAP_FRAME,
        "bootstrap frame of {len} bytes (max {MAX_BOOTSTRAP_FRAME}) — \
         peer is not speaking the session handshake"
    );
    let mut buf = vec![0u8; len];
    read_exact_deadline(stream, &mut buf, deadline)
        .map_err(|e| anyhow::anyhow!("reading bootstrap frame: {e:#}"))?;
    let (header, msg) = decode_frame(&buf)?;
    anyhow::ensure!(
        header.is_none(),
        "bootstrap frames are headerless — link identity is \
         established by Join itself, not the v2 envelope"
    );
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WanProfile;
    use crate::protocol::FRAME_V2_OVERHEAD;
    use crate::session::SessionBuilder;

    fn cfg_with_parties(k: usize) -> RunConfig {
        let mut cfg = RunConfig::quick();
        cfg.parties = k;
        cfg.wan = WanProfile::instant();
        cfg
    }

    /// Raw-socket joiner for handshake-level tests: sends `Join`, then
    /// returns the ack (or the receive error).
    fn raw_join(addr: &str, party: u16, parties: u16)
                -> anyhow::Result<(TcpStream, Message)> {
        let mut s = TcpStream::connect(addr)?;
        send_bootstrap_frame(&mut s, &Message::Join {
            party: PartyId(party),
            parties,
            codecs: compress::supported_mask(),
        })?;
        let ack = recv_bootstrap_frame(
            &mut s, Instant::now() + Duration::from_secs(5))?;
        Ok((s, ack))
    }

    #[test]
    fn k3_bootstrap_assembles_and_exchanges_v2_frames() {
        let cfg = cfg_with_parties(3);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || SessionBuilder::from_bootstrap(&cfg, listener)
        });
        let mut dialers = Vec::new();
        for p in [1u16, 2] {
            let cfg = cfg.clone();
            let addr = addr.clone();
            dialers.push(std::thread::spawn(move || {
                let session = SessionBuilder::from_bootstrap(
                    &cfg,
                    SessionDialer::new(&addr, PartyId(p))
                        .with_timeout(Duration::from_secs(10)),
                )
                .unwrap();
                // One frame each way proves the link is live and
                // identity-framed.
                let t = &session.mesh().links()[0].transport;
                t.send(Message::EvalAck { round: p as u64 }).unwrap();
                assert_eq!(t.recv().unwrap().round(), 100 + p as u64);
                t.stats()
            }));
        }
        let session = label.join().unwrap().unwrap();
        assert_eq!(session.id(), LABEL_PARTY);
        assert_eq!(session.mesh().len(), 2);
        for p in [1u16, 2] {
            let t = session.mesh().transport(PartyId(p)).unwrap();
            assert_eq!(t.recv().unwrap().round(), p as u64);
            t.send(Message::EvalAck { round: 100 + p as u64 }).unwrap();
        }
        for d in dialers {
            let stats = d.join().unwrap();
            // K > 2: the v2 envelope is charged, and the Join/JoinAck
            // handshake is NOT (it ran pre-transport), so the per-link
            // accounting equals exactly one framed EvalAck.
            assert_eq!(
                stats.bytes,
                (Message::EvalAck { round: 0 }.wire_bytes()
                 + FRAME_V2_OVERHEAD) as u64
            );
            assert_eq!(stats.messages, 1);
        }
    }

    #[test]
    fn two_party_bootstrap_keeps_v1_framing() {
        let cfg = cfg_with_parties(2);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || SessionBuilder::from_bootstrap(&cfg, listener)
        });
        let feature = SessionBuilder::from_bootstrap(
            &cfg,
            SessionDialer::new(&addr, PartyId(1))
                .with_timeout(Duration::from_secs(10)),
        )
        .unwrap();
        let session = label.join().unwrap().unwrap();
        let msg = Message::EvalAck { round: 9 };
        let ft = &feature.mesh().links()[0].transport;
        ft.send(msg.clone()).unwrap();
        assert_eq!(
            session.mesh().transport(PartyId(1)).unwrap().recv().unwrap(),
            msg
        );
        // No envelope: the training wire is the historic v1 stream.
        assert_eq!(ft.stats().bytes, msg.wire_bytes() as u64);
    }

    #[test]
    fn duplicate_and_hostile_joins_are_rejected_without_killing_the_mesh() {
        let cfg = cfg_with_parties(3);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || SessionBuilder::from_bootstrap(&cfg, listener)
        });

        // 1. P1 joins cleanly.
        let (_s1, ack1) = raw_join(&addr, 1, 3).unwrap();
        assert!(matches!(ack1, Message::JoinAck { party: PartyId(1), .. }));

        // 2. A duplicate P1 is refused: the connection is dropped
        //    before any ack, so the dialer sees EOF, not a JoinAck.
        assert!(raw_join(&addr, 1, 3).is_err(), "duplicate id acked");

        // 3. A join for the wrong session size is refused.
        assert!(raw_join(&addr, 1, 2).is_err(), "wrong-size join acked");

        // 4. A wrong-version join dies at decode (listener side).
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            let mut frame = Message::Join {
                party: PartyId(2),
                parties: 3,
                codecs: 0,
            }
            .encode();
            frame[9] = 9; // bend the join version byte
            let mut framed =
                ((frame.len() as u32).to_le_bytes()).to_vec();
            framed.extend_from_slice(&frame);
            s.write_all(&framed).unwrap();
            assert!(recv_bootstrap_frame(
                        &mut s, Instant::now() + Duration::from_secs(5))
                    .is_err(),
                    "wrong version acked");
        }

        // 5. An out-of-range id dies at decode likewise (the id never
        //    reaches session logic).
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            let mut frame = Message::Join {
                party: PartyId(2),
                parties: 3,
                codecs: 0,
            }
            .encode();
            frame[10] = 0x30; // party := 0x30 = 48 ≥ parties
            let mut framed =
                ((frame.len() as u32).to_le_bytes()).to_vec();
            framed.extend_from_slice(&frame);
            s.write_all(&framed).unwrap();
            assert!(recv_bootstrap_frame(
                        &mut s, Instant::now() + Duration::from_secs(5))
                    .is_err(),
                    "out-of-range id acked");
        }

        // 6. The legitimate P2 still completes the mesh.
        let (_s2, ack2) = raw_join(&addr, 2, 3).unwrap();
        assert!(matches!(ack2, Message::JoinAck { party: PartyId(2), .. }));
        let session = label.join().unwrap().unwrap();
        assert_eq!(session.mesh().len(), 2);
    }

    #[test]
    fn a_mute_connection_cannot_wedge_the_bootstrap() {
        // A probe that connects and never finishes a frame (health
        // check, port scan, byte-trickler) may stall the serial accept
        // loop for at most JOIN_READ_TIMEOUT — the frame read is
        // bounded end to end, so partial bytes don't reset the clock —
        // and the real joiner behind it must still be admitted.
        let cfg = cfg_with_parties(2);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish(&cfg)
        });
        let mut probe = TcpStream::connect(&addr).unwrap();
        // Half a length word, then silence: exercises the mid-frame
        // deadline, not just the never-spoke path.
        probe.write_all(&[0x12, 0x00]).unwrap();
        // Let the probe reach the accept loop first.
        std::thread::sleep(Duration::from_millis(100));
        let (_s, ack) = raw_join(&addr, 1, 2).unwrap();
        assert!(matches!(ack, Message::JoinAck { party: PartyId(1), .. }));
        let links = label.join().unwrap().unwrap();
        assert_eq!(links.len(), 1);
    }

    #[test]
    fn listener_timeout_names_the_missing_parties() {
        let cfg = cfg_with_parties(4);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_millis(400));
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish(&cfg)
        });
        // Only P2 of {1, 2, 3} shows up.
        let (_s, _ack) = raw_join(&addr, 2, 4).unwrap();
        let e = label.join().unwrap().unwrap_err().to_string();
        assert!(e.contains("P1") && e.contains("P3"),
                "missing ids not named: {e}");
        assert!(!e.contains("P2,") && !e.contains("P2)"),
                "joined id wrongly reported missing: {e}");
    }

    #[test]
    fn dialer_retries_until_the_listener_binds() {
        // Launch order must not matter: the dialer backs off until the
        // label party appears.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe); // free the port (racy but fine, as elsewhere)
        let cfg = cfg_with_parties(2);
        let dialer = std::thread::spawn({
            let cfg = cfg.clone();
            let addr = addr.clone();
            move || {
                SessionDialer::new(&addr, PartyId(1))
                    .with_timeout(Duration::from_secs(10))
                    .establish(&cfg)
            }
        });
        std::thread::sleep(Duration::from_millis(300));
        let listener = SessionListener::bind(&addr)
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let links = listener.establish(&cfg).unwrap();
        assert_eq!(links.len(), 1);
        let dlinks = dialer.join().unwrap().unwrap();
        assert_eq!(dlinks[0].peer, LABEL_PARTY);
    }

    #[test]
    fn dialer_rejects_out_of_range_ids_locally() {
        let cfg = cfg_with_parties(3);
        for bad in [0u16, 3, 9] {
            let e = SessionDialer::new("127.0.0.1:1", PartyId(bad))
                .establish(&cfg);
            assert!(e.is_err(), "party {bad} dialed");
        }
    }

    #[test]
    fn parallel_admit_survives_a_wave_of_mute_probes() {
        // Satellite contract (ROADMAP "bootstrap hardening"): frame
        // reads run on a bounded pool, so a wave of mute connections
        // ahead of the real dialers costs ONE JOIN_READ_TIMEOUT in
        // parallel, not one per probe in series. With ADMIT_WORKERS=8
        // probes and a 4-feature session under an 8 s deadline, the
        // old serial loop would burn 8 × 2 s before admitting anyone
        // and time out; the pool admits everyone with seconds to
        // spare — the test only has to assert success.
        let cfg = cfg_with_parties(5);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(8));
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish(&cfg)
        });
        // Fill every admit slot with a mute probe (half a length word,
        // then silence), held open so the slots stay busy.
        let mut probes = Vec::new();
        for _ in 0..8 {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(&[0x08]).unwrap();
            probes.push(s);
        }
        std::thread::sleep(Duration::from_millis(200));
        // The real mesh dials behind the wave.
        let dialers: Vec<_> = (1u16..=4)
            .map(|p| {
                let addr = addr.clone();
                std::thread::spawn(move || raw_join(&addr, p, 5))
            })
            .collect();
        for d in dialers {
            let (_s, ack) = d.join().unwrap().unwrap();
            assert!(matches!(ack, Message::JoinAck { .. }));
        }
        let links = label.join().unwrap().unwrap();
        assert_eq!(links.len(), 4);
        drop(probes);
    }

    #[test]
    fn join_time_masks_ride_on_the_links() {
        // Satellite contract: the Join/JoinAck codec bitmasks are not
        // just validated — they surface on the Link so coordinators can
        // pre-negotiate and skip the first-round Hello exchange.
        let cfg = cfg_with_parties(2);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish(&cfg)
        });
        let feature_links = SessionDialer::new(&addr, PartyId(1))
            .with_timeout(Duration::from_secs(10))
            .establish(&cfg)
            .unwrap();
        assert_eq!(feature_links[0].peer_codecs,
                   Some(compress::supported_mask()));
        let label_links = label.join().unwrap().unwrap();
        assert_eq!(label_links[0].peer_codecs,
                   Some(compress::supported_mask()));
        // The in-proc mesh carries the same structural knowledge.
        let (label_bs, feature_bs) = inproc_mesh(&cfg);
        assert!(label_bs.links[0].peer_codecs.is_some());
        assert!(feature_bs[0].links[0].peer_codecs.is_some());
        // A raw star (no bootstrap) stays mask-less: in-band Hello.
        let (raw_label, _raw_features) = inproc_star(&cfg);
        assert_eq!(raw_label[0].peer_codecs, None);
    }

    /// Raw-socket rejoiner: sends `Rejoin`, returns the ack or error.
    fn raw_rejoin(addr: &str, party: u16, parties: u16, epoch: u32,
                  last_round: u64)
                  -> anyhow::Result<(TcpStream, Message)> {
        let mut s = TcpStream::connect(addr)?;
        send_bootstrap_frame(&mut s, &Message::Rejoin {
            party: PartyId(party),
            parties,
            epoch,
            last_round,
            codecs: compress::supported_mask(),
        })?;
        let ack = recv_bootstrap_frame(
            &mut s, Instant::now() + Duration::from_secs(5))?;
        Ok((s, ack))
    }

    #[test]
    fn resumed_listener_accepts_rejoin_and_refuses_fresh_join() {
        let cfg = cfg_with_parties(3);
        let epoch = session_epoch(cfg.seed);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10))
            .with_resume(epoch, 7);
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish(&cfg)
        });
        // 1. A fresh Join is refused with a frame-level reason — the
        //    dialer reads a RejoinReject naming the snapshot round,
        //    not a bare EOF.
        let (_s, reject) = raw_join(&addr, 1, 3).unwrap();
        assert_eq!(reject, Message::RejoinReject {
            party: PartyId(1),
            reason: RejectReason::NeedRejoin,
            round: 7,
        });
        // 2. A wrong-epoch Rejoin is refused likewise, with the reason
        //    the satellite contract asks the dialer to log.
        let (_s, reject) = raw_rejoin(&addr, 1, 3, epoch ^ 1, 3).unwrap();
        assert_eq!(reject, Message::RejoinReject {
            party: PartyId(1),
            reason: RejectReason::EpochMismatch,
            round: 7,
        });
        // 3. Valid rejoins are acked with the checkpoint's resume round
        //    and zero replays — including a survivor that ran AHEAD of
        //    the checkpoint (P1 claims 9 > 7): it is admitted and the
        //    echoed resume round tells it to rewind.
        for (p, last_round) in [(1u16, 9u64), (2, 3)] {
            let (_s, ack) =
                raw_rejoin(&addr, p, 3, epoch, last_round).unwrap();
            match ack {
                Message::RejoinAck { party, parties, epoch: e,
                                     resume_round, replays } => {
                    assert_eq!(party, PartyId(p));
                    assert_eq!(parties, 3);
                    assert_eq!(e, epoch);
                    assert_eq!(resume_round, 7);
                    assert_eq!(replays, 0);
                }
                other => panic!("expected RejoinAck, got tag {}",
                                other.tag()),
            }
        }
        let links = label.join().unwrap().unwrap();
        assert_eq!(links.len(), 2);
    }

    #[test]
    fn dialer_falls_back_to_rejoin_on_a_resumed_session() {
        let cfg = cfg_with_parties(2);
        let epoch = session_epoch(cfg.seed);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10))
            .with_resume(epoch, 5);
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish(&cfg)
        });
        let (link, start_round) = SessionDialer::new(&addr, PartyId(1))
            .with_timeout(Duration::from_secs(10))
            .establish_resumable(&cfg)
            .unwrap();
        assert_eq!(start_round, 5,
                   "dialer must learn the checkpoint's resume round");
        assert_eq!(link.peer, LABEL_PARTY);
        assert!(link.peer_codecs.is_some());
        let links = label.join().unwrap().unwrap();
        assert_eq!(links.len(), 1);
    }

    #[test]
    fn readmission_queues_valid_rejoins_and_rejects_strangers() {
        let cfg = cfg_with_parties(2);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish_supervised(&cfg)
        });
        let _feature = SessionDialer::new(&addr, PartyId(1))
            .with_timeout(Duration::from_secs(10))
            .establish(&cfg)
            .unwrap();
        let (links, readmission, epoch, start_round) =
            label.join().unwrap().unwrap();
        assert_eq!(links.len(), 1);
        assert_eq!(start_round, 0);
        assert_eq!(epoch, session_epoch(cfg.seed));
        assert!(readmission.try_take().is_none());
        // A wrong-epoch dial is rejected on the re-admission thread:
        // a RejoinReject names the reason, then the socket is dropped
        // and nothing is queued.
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            send_bootstrap_frame(&mut s, &Message::Rejoin {
                party: PartyId(1),
                parties: 2,
                epoch: epoch ^ 0xdead,
                last_round: 0,
                codecs: 0,
            })
            .unwrap();
            let reply = recv_bootstrap_frame(
                &mut s, Instant::now() + Duration::from_secs(3))
                .expect("reject frame");
            assert_eq!(reply, Message::RejoinReject {
                party: PartyId(1),
                reason: RejectReason::EpochMismatch,
                round: 0,
            });
        }
        assert!(readmission.try_take().is_none());
        // A valid Rejoin is queued with its claim intact. (The ack is
        // the consumer's job — the supervised label loop — so the raw
        // socket sees silence here, not an ack.)
        let mut s = TcpStream::connect(&addr).unwrap();
        send_bootstrap_frame(&mut s, &Message::Rejoin {
            party: PartyId(1),
            parties: 2,
            epoch,
            last_round: 4,
            codecs: 0x0f,
        })
        .unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let req = loop {
            if let Some(r) = readmission.try_take() {
                break r;
            }
            assert!(Instant::now() < deadline, "rejoin never queued");
            std::thread::sleep(Duration::from_millis(10));
        };
        assert_eq!(req.party, PartyId(1));
        assert_eq!(req.last_round, 4);
        assert_eq!(req.codecs, 0x0f);
    }

    /// Raw HTTP GET against the session port; returns the full
    /// response (status line + headers + body), reading to EOF.
    fn http_get(addr: &str, path: &str) -> anyhow::Result<String> {
        let mut s = TcpStream::connect(addr)?;
        s.write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes())?;
        s.set_read_timeout(Some(Duration::from_secs(5)))?;
        let mut out = String::new();
        s.read_to_string(&mut out)?;
        Ok(out)
    }

    #[test]
    fn metrics_scrape_is_served_during_bootstrap() {
        let cfg = cfg_with_parties(2);
        let registry = Registry::new();
        registry.set_round(5);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10))
            .with_metrics(registry.clone());
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish(&cfg)
        });
        // Scrape while the mesh is still assembling: the accept loop
        // classifies the GET by its first four bytes and serves it
        // without consuming a join slot or disturbing vetting.
        let resp = http_get(&addr, "/metrics").unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp}");
        assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");
        assert!(resp.contains("celu_session_round 5\n"), "{resp}");
        // /watch has no lifecycle flag during bootstrap: refused with
        // a diagnostic, not hung and not treated as hostile.
        let resp = http_get(&addr, "/watch").unwrap();
        assert!(resp.starts_with("HTTP/1.0 503"), "{resp}");
        // Unknown paths get a 404 naming the real endpoints.
        let resp = http_get(&addr, "/nope").unwrap();
        assert!(resp.starts_with("HTTP/1.0 404"), "{resp}");
        // The real joiner is unaffected by the HTTP traffic.
        let (_s, ack) = raw_join(&addr, 1, 2).unwrap();
        assert!(matches!(ack, Message::JoinAck { .. }));
        let links = label.join().unwrap().unwrap();
        assert_eq!(links.len(), 1);
    }

    #[test]
    fn watch_stream_follows_the_registry_and_ends_with_final_totals() {
        use crate::metrics::exporters::push::{frame_rows,
                                              read_metrics_frame};
        use crate::metrics::facade::LinkHandles;
        use crate::transport::LinkStats;

        let cfg = cfg_with_parties(2);
        let registry = Registry::new();
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10))
            .with_metrics(registry.clone());
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish_supervised(&cfg)
        });
        let _feature = SessionDialer::new(&addr, PartyId(1))
            .with_timeout(Duration::from_secs(10))
            .establish(&cfg)
            .unwrap();
        let (_links, readmission, _epoch, _round) =
            label.join().unwrap().unwrap();
        // Charge totals the stream must report.
        let h = LinkHandles::detached();
        h.charge(LinkStats {
            messages: 3,
            bytes: 300,
            raw_bytes: 600,
            busy: Duration::from_millis(2),
        });
        registry.bind_link(PartyId(1), LABEL_PARTY, &h);
        registry.set_round(9);
        // Scrapes are served from the re-admission port too.
        let resp = http_get(&addr, "/metrics").unwrap();
        assert!(resp.contains(
            "celu_link_wire_bytes_total{src=\"1\",dst=\"0\"} 300\n"),
            "{resp}");
        // Attach a watcher and read one live tag-14 frame.
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"GET /watch HTTP/1.0\r\n\r\n").unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let first = read_metrics_frame(&mut s).unwrap();
        assert_eq!(frame_rows(&first).len(), 1);
        // The registry keeps moving; then the session ends. The stream
        // must close with one final frame carrying the exact totals —
        // the stop flag is latched before each export, so the frame
        // sent after observing stop is a complete final snapshot.
        h.record(100, 200, Duration::from_millis(1));
        registry.set_round(10);
        drop(readmission);
        let mut last = first;
        while let Ok(f) = read_metrics_frame(&mut s) {
            last = f;
        }
        let final_rows: Vec<_> = registry
            .link_rows()
            .iter()
            .map(|r| (r.src, r.dst, r.stats))
            .collect();
        assert_eq!(frame_rows(&last), final_rows);
        assert_eq!(last.round(), 10);
    }

    /// `http_get` with an arbitrary extra header line (e.g. an
    /// `Authorization` header for the shared-token gate).
    fn http_get_with_header(addr: &str, path: &str, header: &str)
                            -> anyhow::Result<String> {
        let mut s = TcpStream::connect(addr)?;
        s.write_all(
            format!("GET {path} HTTP/1.0\r\n{header}\r\n\r\n").as_bytes())?;
        s.set_read_timeout(Some(Duration::from_secs(5)))?;
        let mut out = String::new();
        s.read_to_string(&mut out)?;
        Ok(out)
    }

    #[test]
    fn shared_token_gates_observability_but_not_sessions() {
        let cfg = cfg_with_parties(2);
        let registry = Registry::new();
        registry.set_round(3);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10))
            .with_metrics(registry.clone())
            .with_auth_token("hunter2");
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish_supervised(&cfg)
        });
        // Bootstrap phase: no header and wrong token are both 401 with
        // a Bearer challenge; the right token scrapes as usual.
        let resp = http_get(&addr, "/metrics").unwrap();
        assert!(resp.starts_with("HTTP/1.0 401"), "{resp}");
        assert!(resp.contains("WWW-Authenticate: Bearer"), "{resp}");
        assert!(!resp.contains("celu_session_round"), "leaked: {resp}");
        let resp = http_get_with_header(
            &addr, "/metrics", "Authorization: Bearer wrong").unwrap();
        assert!(resp.starts_with("HTTP/1.0 401"), "{resp}");
        let resp = http_get_with_header(
            &addr, "/metrics", "authorization: Bearer hunter2").unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp}");
        assert!(resp.contains("celu_session_round 3\n"), "{resp}");
        // Sessions are never gated: the joiner presents no header and
        // is admitted exactly as on an open port.
        let (_s, ack) = raw_join(&addr, 1, 2).unwrap();
        assert!(matches!(ack, Message::JoinAck { .. }));
        let (_links, readmission, _epoch, _round) =
            label.join().unwrap().unwrap();
        // The gate carries over to the re-admission port: /watch
        // without the token is 401 (not 503, not a stream), with it a
        // live stream begins.
        let resp = http_get(&addr, "/watch").unwrap();
        assert!(resp.starts_with("HTTP/1.0 401"), "{resp}");
        let resp = http_get_with_header(
            &addr, "/metrics", "Authorization: Bearer hunter2").unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp}");
        drop(readmission);
    }

    #[test]
    fn empty_token_leaves_the_plane_open() {
        let cfg = cfg_with_parties(2);
        let registry = Registry::new();
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10))
            .with_metrics(registry)
            .with_auth_token("");
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish(&cfg)
        });
        let resp = http_get(&addr, "/metrics").unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp}");
        let (_s, ack) = raw_join(&addr, 1, 2).unwrap();
        assert!(matches!(ack, Message::JoinAck { .. }));
        let links = label.join().unwrap().unwrap();
        assert_eq!(links.len(), 1);
    }

    #[test]
    fn http_without_a_registry_stays_hostile() {
        let cfg = cfg_with_parties(2);
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish(&cfg)
        });
        // No observability plane attached: the GET gets nothing back —
        // the connection is dropped exactly like pre-plane builds
        // dropped any non-bootstrap traffic.
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut buf = Vec::new();
        let n = s.read_to_end(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "metrics served without a registry");
        // The mesh still assembles behind the rejected request.
        let (_s, ack) = raw_join(&addr, 1, 2).unwrap();
        assert!(matches!(ack, Message::JoinAck { .. }));
        let links = label.join().unwrap().unwrap();
        assert_eq!(links.len(), 1);
    }

    #[test]
    fn oversized_http_requests_are_cut_off() {
        // An HTTP-shaped byte-trickler with an unbounded header block
        // is refused at MAX_HTTP_REQUEST, same discipline as hostile
        // bootstrap length words.
        let cfg = cfg_with_parties(2);
        let registry = Registry::new();
        let listener = SessionListener::bind("127.0.0.1:0")
            .unwrap()
            .with_timeout(Duration::from_secs(10))
            .with_metrics(registry);
        let addr = listener.local_addr().unwrap().to_string();
        let label = std::thread::spawn({
            let cfg = cfg.clone();
            move || listener.establish(&cfg)
        });
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"GET /metrics HTTP/1.0\r\n").unwrap();
        // Headers that never terminate, well past the cap.
        let junk = vec![b'x'; 4 * MAX_HTTP_REQUEST];
        let _ = s.write_all(&junk);
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut buf = Vec::new();
        let n = s.read_to_end(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "oversized request was answered");
        // A legitimate scrape and the joiner both still get through.
        let resp = http_get(&addr, "/metrics").unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp}");
        let (_s, ack) = raw_join(&addr, 1, 2).unwrap();
        assert!(matches!(ack, Message::JoinAck { .. }));
        let links = label.join().unwrap().unwrap();
        assert_eq!(links.len(), 1);
    }

    #[test]
    fn inproc_mesh_bootstraps_every_party() {
        let cfg = cfg_with_parties(3);
        let (label_bs, feature_bs) = inproc_mesh(&cfg);
        assert_eq!(label_bs.id(), LABEL_PARTY);
        let session =
            SessionBuilder::from_bootstrap(&cfg, label_bs).unwrap();
        assert_eq!(session.mesh().len(), 2);
        for (i, bs) in feature_bs.into_iter().enumerate() {
            let p = PartyId(i as u16 + 1);
            assert_eq!(bs.id(), p);
            let fs = SessionBuilder::from_bootstrap(&cfg, bs).unwrap();
            fs.mesh().links()[0]
                .transport
                .send(Message::EvalAck { round: p.0 as u64 })
                .unwrap();
            assert_eq!(
                session.mesh().transport(p).unwrap().recv().unwrap()
                    .round(),
                p.0 as u64
            );
        }
    }
}
